"""Quickstart: train a tiny LM through the full CMP stack in ~a minute.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.configs import get_config                      # noqa: E402
from repro.data.pipeline import DataPipeline              # noqa: E402
from repro.models import param_count                      # noqa: E402
from repro.training.optimizer import OptConfig            # noqa: E402
from repro.training.train_loop import Trainer             # noqa: E402


def main():
    cfg = get_config("yi-6b", smoke=True)  # reduced same-family config
    opt = OptConfig(lr=2e-3, warmup_steps=5, total_steps=60)
    # Producer threads feed the strict-FIFO CMP queue; the protection window
    # bounds pipeline memory and absorbs stalls (the paper's contribution,
    # working as the input layer).
    pipe = DataPipeline(batch=8, seq=64, vocab=cfg.vocab_size,
                        num_producers=2, window=32)
    tr = Trainer(cfg, opt)
    print(f"model: {cfg.name} ({param_count(tr.params):,} params)")
    tr.fit(iter(pipe), 60, data_pipe=pipe)
    pipe.close()
    print(f"loss: {tr.history[0]:.3f} -> {tr.history[-1]:.3f} over 60 steps")
    assert tr.history[-1] < tr.history[0]
    print("quickstart OK")


if __name__ == "__main__":
    main()
