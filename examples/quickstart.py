"""Quickstart: the whole CMP serving stack — class queues, scheduler
replicas, paged-KV engine — from one declarative config, in ~15 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.fabric import ClassSpec, Fabric, FabricConfig  # noqa: E402


def main():
    config = FabricConfig(classes=(ClassSpec("chat", slo_ms=60000.0),),
                          arch="glm4-9b", smoke=True, max_batch=2,
                          page_size=8, num_pages=32, kv_window=3, max_seq=48)
    with Fabric.open(config) as fab:
        uids = fab.submit_many([[i + 1, 7, 3] for i in range(4)],
                               max_new_tokens=4, qclass="chat")
        done = fab.drain(max_steps=200)
        for u in uids:
            print(f"req {u}: {done[u].output}")
        print(f"slo: {fab.stats_view().slo['chat']}")
        assert all(u in done for u in uids)
    print("quickstart OK")


if __name__ == "__main__":
    main()
