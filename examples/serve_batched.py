"""Serve a small model with batched requests through the CMP paged-KV
engine — one declarative config, one `Fabric` session — including an
overload phase that demonstrates preemption + window recovery.

  PYTHONPATH=src python examples/serve_batched.py
"""

import sys

sys.path.insert(0, "src")

from repro.fabric import Fabric, FabricConfig                # noqa: E402


def main():
    # Tight page pool on purpose: overload will trigger preemption, and the
    # CMP window recycles the preempted request's pages automatically.
    config = FabricConfig(arch="glm4-9b", smoke=True, max_batch=3,
                          page_size=8, num_pages=24, kv_window=3, max_seq=64)
    prompts = [[i + 1, (3 * i) % 40 + 2, 7] for i in range(9)]
    with Fabric.open(config) as fab:
        # One batched submission for the whole burst: a single
        # class-cycle-range fetch-add and one splice per shard.
        uids = fab.submit_many(prompts, max_new_tokens=6)
        done = fab.drain(max_steps=500)
        preempted = sum(done[u].preemptions for u in uids)
        for u in uids:
            print(f"req {u}: {done[u].output} "
                  f"(preemptions={done[u].preemptions})")
        pool = fab.engines[0].pool
        print(f"\nall {len(uids)} requests served; {preempted} preemptions "
              f"recovered via the protection window; "
              f"free pages {pool.free_pages()}/{pool.num_pages}")
        assert all(u in done for u in uids), "a request was dropped"


if __name__ == "__main__":
    main()
