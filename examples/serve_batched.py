"""Serve a small model with batched requests through the CMP paged-KV engine,
including an overload phase that demonstrates preemption + window recovery.

  PYTHONPATH=src python examples/serve_batched.py
"""

import sys

sys.path.insert(0, "src")

import jax                                                  # noqa: E402

from repro.configs import get_config                        # noqa: E402
from repro.models import init_params                        # noqa: E402
from repro.serving.engine import Engine                     # noqa: E402


def main():
    cfg = get_config("glm4-9b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))

    # Tight page pool on purpose: overload will trigger preemption, and the
    # CMP window recycles the preempted request's pages automatically.
    eng = Engine(cfg, params, max_batch=3, page_size=8, num_pages=24,
                 window=3, max_seq=64)
    prompts = [[i + 1, (3 * i) % 40 + 2, 7] for i in range(9)]
    # One batched submission for the whole burst: a single class-cycle-range
    # fetch-add and one splice per shard (Engine.submit_many).
    uids = eng.submit_many(prompts, max_new_tokens=6)
    done = eng.run_until_idle(max_steps=500)
    preempted = sum(done[u].preemptions for u in uids)
    for u in uids:
        print(f"req {u}: {done[u].output} (preemptions={done[u].preemptions})")
    print(f"\nall {len(uids)} requests served; {preempted} preemptions "
          f"recovered via the protection window; "
          f"free pages {eng.pool.free_pages()}/{eng.pool.num_pages}")


if __name__ == "__main__":
    main()
