"""Sharded engine replicas with steal-rebalanced drains and exact-seat
frontier checkpointing (DESIGN.md §9).

  PYTHONPATH=src python examples/serve_replicated.py [--replicas 2]

Two engine replicas serve a 3-class wave from one fabric: each replica owns
a seat subset of every class (its own lanes, its own page pool, its own
policy drain) and a starved replica steals a whole cycle-run with one CAS.
Mid-wave the demo takes an exact-seat frontier checkpoint, kills the whole
group (replica crash), restores from the snapshot, and finishes the wave —
every tenant resumes at its exact FIFO seat; nothing is lost or served
twice.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax                                                  # noqa: E402

from repro.checkpoint.checkpointer import (restore_aux,     # noqa: E402
                                           save)
from repro.configs import get_config                        # noqa: E402
from repro.models import init_params                        # noqa: E402
from repro.sched import QueueClass                          # noqa: E402
from repro.serving.engine import EngineReplicaGroup         # noqa: E402


def make_classes(num_shards):
    return [
        QueueClass("interactive", priority=2, weight=8.0,
                   num_shards=num_shards),
        QueueClass("batch", priority=1, weight=3.0, num_shards=num_shards),
        QueueClass("background", priority=0, weight=1.0,
                   num_shards=num_shards),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/serve_replicated_ckpt")
    args = ap.parse_args()

    cfg = get_config("glm4-9b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))

    grp = EngineReplicaGroup(cfg, params, num_replicas=args.replicas,
                             max_batch=2 * args.replicas, page_size=8,
                             num_pages=24 * args.replicas, window=3,
                             max_seq=64, classes=make_classes(args.replicas))

    t0 = time.time()
    uids, tenant_of = [], {}
    wave = [("interactive", 4), ("batch", 4), ("background", 4)]
    for name, n in wave:
        for u in grp.submit_many([[10 + i, 3, 7] for i in range(n)],
                                 max_new_tokens=4, qclass=name):
            uids.append(u)
            tenant_of[u] = name

    for _ in range(2):  # part of the wave decodes...
        grp.step()
    step, state = grp.step_count, grp.sched_state()
    save(args.ckpt_dir, step, {}, aux={"sched": state})  # ...then: snapshot,
    done_before = dict(grp.completed)
    del grp                                              # crash,

    ck_step, aux = restore_aux(args.ckpt_dir)            # restore.
    assert ck_step == step and aux is not None
    grp2 = EngineReplicaGroup.from_sched_state(
        cfg, params, aux["sched"], max_batch=2 * args.replicas, page_size=8,
        num_pages=24 * args.replicas, window=3, max_seq=64)
    pending = grp2.replica_set.pending()
    done_after = grp2.run_until_idle(max_steps=400)
    dt = time.time() - t0

    served = {**done_before, **done_after}
    missing = [u for u in uids if u not in served]
    dup = [u for u in done_before if u in done_after]
    assert not missing, f"lost across restore: {missing}"
    assert not dup, f"served twice across restore: {dup}"
    print(f"replicas={args.replicas}  wall={dt:.1f}s  "
          f"checkpoint@step {step} ({pending} seats resumed)")
    for name, _ in wave:
        mine = sorted(u for u in uids if tenant_of[u] == name)
        state_cls = aux["sched"]["classes"][name]
        print(f"  {name:12s} served={sum(1 for u in mine if u in served)}"
              f"/{len(mine)} ckpt(seq={state_cls['seq']} "
              f"frontier={state_cls['frontier']} "
              f"requeued={len(state_cls['requeue'])})")
    for rid, r in grp2.replica_stats().items():
        print(f"  replica {rid}: steals={r['steals']} "
              f"stolen_cycles={r['stolen_cycles']} "
              f"empty_drains={r['empty_drains']}")
    print("every tenant resumed at its exact FIFO seat; "
          f"{len(done_before)} served pre-crash, {len(done_after)} post-restore")


if __name__ == "__main__":
    main()
