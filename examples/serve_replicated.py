"""Live replica elasticity + in-loop frontier checkpointing through the
fabric API (DESIGN.md §9-10).

  PYTHONPATH=src python examples/serve_replicated.py [--replicas 2]

One declarative config opens a single-replica fabric serving a 3-class
wave; mid-wave it live-resizes to N replicas (a batch of seat claims plus a
lane/page budget re-split — producers never pause), the checkpoint cadence
writes exact-seat frontier snapshots as it runs, the whole group is killed
(replica crash), and `Fabric.restore` resumes from the cadence checkpoint
to finish the wave — every tenant at its exact FIFO seat; nothing lost or
served twice. Self-asserting.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.fabric import Fabric, FabricConfig, tiered_classes  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/serve_replicated_ckpt")
    args = ap.parse_args()

    config = FabricConfig(
        classes=tiered_classes(), replicas=1, max_replicas=args.replicas,
        arch="glm4-9b", smoke=True, max_batch=2 * args.replicas,
        page_size=8, num_pages=24 * args.replicas, kv_window=3, max_seq=64,
        checkpoint_dir=args.ckpt_dir, checkpoint_every_n_steps=2)
    fab = Fabric.open(config)

    t0 = time.time()
    uids, tenant_of = [], {}
    wave = [("interactive", 4), ("batch", 4), ("background", 4)]
    for name, n in wave:
        for u in fab.submit_many([[10 + i, 3, 7] for i in range(n)],
                                 max_new_tokens=4, qclass=name):
            uids.append(u)
            tenant_of[u] = name

    fab.step()                      # part of the wave decodes on 1 replica,
    fab.resize(args.replicas)       # ...then: live resize under load,
    fab.step()                      # cadence checkpoint fires (step 2),
    fab.step()
    fab.flush_checkpoints()         # snapshots durably on disk,
    ck_step = max(fab.stats_view().checkpoint["written"])
    done_before = dict(fab.completed)
    del fab                         # crash,

    fab2 = Fabric.restore(args.ckpt_dir)  # restore from the cadence ckpt.
    assert fab2.step_count == ck_step
    assert fab2.num_replicas == args.replicas, "resize survived checkpoint"
    pending = fab2.pending()
    done_after = fab2.drain(max_steps=400)
    dt = time.time() - t0

    served = {**done_before, **done_after}
    missing = [u for u in uids if u not in served]
    dup = [u for u in done_before if u in done_after]
    assert not missing, f"lost across restore: {missing}"
    assert not dup, f"served twice across restore: {dup}"
    print(f"replicas=1->{args.replicas} (live)  wall={dt:.1f}s  "
          f"cadence checkpoint@step {ck_step} ({pending} seats resumed)")
    view = fab2.stats_view()
    for name, _ in wave:
        mine = sorted(u for u in uids if tenant_of[u] == name)
        cs = view.classes[name]
        print(f"  {name:12s} served={sum(1 for u in mine if u in served)}"
              f"/{len(mine)} requeued-at-seat={cs.requeued}")
    for rid, r in view.replicas.items():
        print(f"  replica {rid}: steals={r['steals']} "
              f"stolen_cycles={r['stolen_cycles']} "
              f"empty_drains={r['empty_drains']}")
    fab2.close()
    print("every tenant resumed at its exact FIFO seat; "
          f"{len(done_before)} served pre-crash, {len(done_after)} "
          f"post-restore")


if __name__ == "__main__":
    main()
