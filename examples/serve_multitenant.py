"""Multi-tenant serving on the priority-class CMP queue fabric: mixed
interactive/batch/background traffic through one engine, class-aware
preemption, per-class admission telemetry.

  PYTHONPATH=src python examples/serve_multitenant.py [--policy strict|wfq|fifo]

Interactive requests preempt background lanes under pool pressure; the
victims re-enter their own class at their original cycle seat (strict FIFO
within the class survives preemption). Compare policies with --policy; the
scheduler benchmark (benchmarks/run.py --only sched) quantifies the
latency separation.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax                                                  # noqa: E402

from repro.configs import get_config                        # noqa: E402
from repro.models import init_params                        # noqa: E402
from repro.sched import QueueClass                          # noqa: E402
from repro.serving.engine import Engine                     # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="strict",
                    choices=("strict", "wfq", "fifo"))
    args = ap.parse_args()

    cfg = get_config("glm4-9b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))

    classes = [
        QueueClass("interactive", priority=2, weight=8.0),
        QueueClass("batch", priority=1, weight=3.0),
        # background gets a finite admission window: beyond 6 in flight the
        # class rejects (backpressure) instead of growing without bound
        QueueClass("background", priority=0, weight=1.0, admit_window=6),
    ]
    # Tight page pool on purpose: interactive arrivals preempt background
    # lanes, and the CMP window recycles the victims' pages automatically.
    eng = Engine(cfg, params, max_batch=3, page_size=8, num_pages=24,
                 window=3, max_seq=64, classes=classes, policy=args.policy)

    t0 = time.time()
    uids = {"interactive": [], "batch": [], "background": []}
    # background + batch load first, interactive bursts arriving on top
    for i in range(8):
        u = eng.submit([40 + i, 3, 7], max_new_tokens=5, qclass="background")
        if u is not None:
            uids["background"].append(u)
    uids["batch"] = [u for u in
                     eng.submit_many([[20 + i, 5, 9] for i in range(4)],
                                     max_new_tokens=5, qclass="batch")
                     if u is not None]
    for i in range(4):
        uids["interactive"].append(
            eng.submit([i + 1, 2, 3], max_new_tokens=4, qclass="interactive"))
        eng.step()  # interactive arrives mid-flight, not as a pre-load

    done = eng.run_until_idle(max_steps=800)
    dt = time.time() - t0

    rejected = 8 - len(uids["background"])
    print(f"policy={args.policy}  wall={dt:.1f}s  steps={eng.step_count}")
    for name, us in uids.items():
        served = [done[u] for u in us if u in done]
        pre = sum(r.preemptions for r in served)
        print(f"  {name:12s} served={len(served)}/{len(us)} "
              f"preemptions={pre}")
    print(f"  background rejected by admission window: {rejected}")
    for name, snap in eng.class_stats().items():
        print(f"  [{name}] submitted={snap['submitted']} "
              f"delivered={snap['delivered']} requeued={snap['requeued']} "
              f"rejected={snap['rejected']} "
              f"admit_p50_ms={snap['admit_p50_ms'] and round(snap['admit_p50_ms'], 2)} "
              f"admit_p99_ms={snap['admit_p99_ms'] and round(snap['admit_p99_ms'], 2)}")
    assert all(u in done for us in uids.values() for u in us), \
        "an admitted request was dropped"
    print("all admitted requests served; within-class FIFO kept through "
          "preemption; pages free "
          f"{eng.pool.free_pages()}/{eng.pool.num_pages}")


if __name__ == "__main__":
    main()
