"""Multi-tenant serving on the fabric API: mixed interactive/batch/
background traffic through one declarative config, class-aware preemption,
per-class admission telemetry and the SLO view.

  PYTHONPATH=src python examples/serve_multitenant.py [--policy strict|wfq|fifo]

Interactive requests preempt background lanes under pool pressure; the
victims re-enter their own class at their original cycle seat (strict FIFO
within the class survives preemption). Compare policies with --policy; the
scheduler benchmark (benchmarks/run.py --only sched) quantifies the
latency separation. Self-asserting.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.fabric import Fabric, FabricConfig, tiered_classes  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="strict",
                    choices=("strict", "wfq", "fifo"))
    args = ap.parse_args()

    # The standard 3-tier tenant set; background gets a finite admission
    # window — beyond 6 in flight the class rejects (backpressure) instead
    # of growing without bound. Tight page pool on purpose: interactive
    # arrivals preempt background lanes, the CMP window recycles the pages.
    config = FabricConfig(
        classes=tiered_classes(background_window=6,
                               interactive_slo_ms=30000.0,
                               batch_slo_ms=120000.0),
        policy=args.policy, arch="glm4-9b", smoke=True, max_batch=3,
        page_size=8, num_pages=24, kv_window=3, max_seq=64)
    fab = Fabric.open(config)

    t0 = time.time()
    uids = {"interactive": [], "batch": [], "background": []}
    # background + batch load first, interactive bursts arriving on top
    for i in range(8):
        u = fab.submit([40 + i, 3, 7], max_new_tokens=5, qclass="background")
        if u is not None:
            uids["background"].append(u)
    uids["batch"] = [u for u in
                     fab.submit_many([[20 + i, 5, 9] for i in range(4)],
                                     max_new_tokens=5, qclass="batch")
                     if u is not None]
    for i in range(4):
        uids["interactive"].append(
            fab.submit([i + 1, 2, 3], max_new_tokens=4, qclass="interactive"))
        fab.step()  # interactive arrives mid-flight, not as a pre-load

    done = fab.drain(max_steps=800)
    dt = time.time() - t0

    rejected = 8 - len(uids["background"])
    print(f"policy={args.policy}  wall={dt:.1f}s  steps={fab.step_count}")
    for name, us in uids.items():
        served = [done[u] for u in us if u in done]
        pre = sum(r.preemptions for r in served)
        print(f"  {name:12s} served={len(served)}/{len(us)} "
              f"preemptions={pre}")
    print(f"  background rejected by admission window: {rejected}")
    view = fab.stats_view()
    for name, cs in view.classes.items():
        slo = view.slo[name]
        print(f"  [{name}] submitted={cs.submitted} "
              f"delivered={cs.delivered} requeued={cs.requeued} "
              f"rejected={cs.rejected} "
              f"admit_p99_ms={cs.admit_p99_ms and round(cs.admit_p99_ms, 2)} "
              f"slo_target_ms={slo.target_ms} slo_ok={slo.ok}")
    assert all(u in done for us in uids.values() for u in us), \
        "an admitted request was dropped"
    # the SLO view is wired end to end: targets configured on the latency
    # tiers, measured p99 reported against them
    assert view.slo["interactive"].target_ms == 30000.0
    assert view.slo["interactive"].ok is not None
    assert view.slo["background"].target_ms is None
    pool = fab.engines[0].pool
    print("all admitted requests served; within-class FIFO kept through "
          f"preemption; pages free {pool.free_pages()}/{pool.num_pages}")
    fab.close()


if __name__ == "__main__":
    main()
