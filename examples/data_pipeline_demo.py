"""The CMP queue as a production input pipeline: coordination-free
producer/consumer flow, straggler absorption, bounded memory, exact resume.

  PYTHONPATH=src python examples/data_pipeline_demo.py
"""

import sys
import time

sys.path.insert(0, "src")

from repro.data.pipeline import DataPipeline               # noqa: E402


def main():
    # num_shards > 1: producers shard by batch_id hash, the consumer drains
    # its home shard and steals from the deepest sibling (DESIGN.md §8)
    pipe = DataPipeline(batch=4, seq=128, vocab=32000, num_producers=3,
                        window=32, num_shards=2)
    it = iter(pipe)

    print("== phase 1: steady state ==")
    t0 = time.time()
    for i in range(20):
        b = next(it)
    print(f"20 batches in {time.time()-t0:.3f}s; queue nodes: "
          f"{pipe.shards.live_nodes()} (bounded by window+backpressure); "
          f"steal stats: {pipe.steal_stats()}")

    print("== phase 2: producer 0 stalls 0.5s (straggler) ==")
    pipe.stall_producer(0, 0.5)
    t0 = time.time()
    got = [next(it)["batch_id"] for _ in range(15)]
    dt = time.time() - t0
    print(f"15 batches in {dt:.3f}s while producer 0 was stalled "
          f"({'NOT blocked' if dt < 0.5 else 'BLOCKED!'}) — the window "
          f"absorbed the straggler")

    print("== phase 3: checkpoint + exact resume ==")
    state = pipe.state()
    pipe.close()
    pipe2 = DataPipeline.from_state(state, batch=4, seq=128, vocab=32000,
                                    window=32)
    b = next(iter(pipe2))
    print(f"resumed; first batch id {b['batch_id']} continues the frontier "
          f"{state['cursors']}")
    pipe2.close()
    print("demo OK")


if __name__ == "__main__":
    main()
