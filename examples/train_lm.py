"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps with
checkpoint/restart through the full stack (CMP pipeline, async checkpointer,
straggler tracking).

Full run (the deliverable configuration — hours on 1 CPU core, minutes on a
TPU slice):
  PYTHONPATH=src python examples/train_lm.py --steps 300

CI-scale smoke of the same driver:
  PYTHONPATH=src python examples/train_lm.py --steps 20 --scale 0.25 --batch 4 --seq 64
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config                      # noqa: E402
from repro.data.pipeline import DataPipeline              # noqa: E402
from repro.models import param_count                      # noqa: E402
from repro.training.optimizer import OptConfig            # noqa: E402
from repro.training.train_loop import Trainer             # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="width multiplier on the ~100M base config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()

    # ~100M-class config: xlstm-125m at full published size.
    cfg = get_config("xlstm-125m")
    if args.scale != 1.0:
        d = max(64, int(cfg.d_model * args.scale) // 16 * 16)
        cfg = dataclasses.replace(cfg, d_model=d, head_dim=d // cfg.num_heads,
                                  ssm_head_dim=d // cfg.ssm_heads,
                                  num_layers=max(2, int(cfg.num_layers * args.scale) // 2 * 2))
    cfg = dataclasses.replace(cfg, dtype="float32", remat=False)

    opt = OptConfig(lr=6e-4, warmup_steps=max(10, args.steps // 20),
                    total_steps=args.steps)
    pipe = DataPipeline(batch=args.batch, seq=args.seq, vocab=cfg.vocab_size,
                        num_producers=2, window=32)
    tr = Trainer(cfg, opt, ckpt_dir=args.ckpt_dir, ckpt_every=50)
    if tr.try_restore(pipe):
        print(f"resumed from step {tr.step}")
    print(f"model: {cfg.name} ({param_count(tr.params):,} params), "
          f"{args.steps} steps of {args.batch}x{args.seq}")
    done = 0
    while done < args.steps:
        n = min(10, args.steps - done)
        tr.fit(iter(pipe), n, data_pipe=pipe)
        done += n
        print(f"step {tr.step:4d}  loss {tr.history[-1]:.4f}")
    pipe.close()
    if tr.async_ckpt:
        tr.async_ckpt.close()
    print(f"final: {tr.history[0]:.4f} -> {tr.history[-1]:.4f} "
          f"(stragglers={tr.stragglers})")


if __name__ == "__main__":
    main()
