from repro.models.model import (apply, decode_step, init_cache, init_params,
                                loss_fn, param_count, prefill)

__all__ = ["apply", "decode_step", "init_cache", "init_params", "loss_fn",
           "param_count", "prefill"]
