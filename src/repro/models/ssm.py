"""Recurrent / state-space blocks: xLSTM (mLSTM + sLSTM) and Mamba2-style SSD.

These are the sub-quadratic architectures (constant-size decode state), which
is why they — and only they — run the ``long_500k`` shape (DESIGN.md §4).

Forms implemented:
  * mLSTM  — stabilized matrix-memory recurrence, ``lax.scan`` over time for
             train/prefill; O(d_k x d_v) state step for decode.
  * sLSTM  — stabilized scalar-memory recurrence with block-diagonal
             (per-head) recurrent mixing; inherently sequential.
  * SSD    — chunkwise-parallel scalar-decay state space (Mamba2): quadratic
             within a chunk (matmul-friendly), recurrent across chunks.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _wsc(x, spec):
    """with_sharding_constraint that degrades to a no-op without a mesh."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def mlstm_scan(
    q: jax.Array,  # [B, H, S, d]
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,  # [B, H, S] input-gate preactivation
    f_pre: jax.Array,  # [B, H, S] forget-gate preactivation
    state: Optional[Tuple[jax.Array, jax.Array, jax.Array, jax.Array]] = None,
    unroll: int = 1,
    shard_axis: Optional[str] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array, jax.Array]]:
    """Stabilized mLSTM recurrence. Returns (h [B,H,S,d], final_state).

    State: (C [B,H,d,d], n [B,H,d], m [B,H]) + dummy for pytree symmetry.
    """
    B, H, S, d = q.shape
    k = k / jnp.sqrt(jnp.float32(d)).astype(k.dtype)
    if state is None:
        C0 = jnp.zeros((B, H, d, d), jnp.float32)
        n0 = jnp.zeros((B, H, d), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state[0], state[1], state[2]
    if shard_axis:
        # TP over the VALUE dim: the recurrence C = f C + i (k x v) and the
        # readout h = C^T q contract only the replicated key dim, so every
        # time step is collective-free (§Perf hillclimb, cell B).
        C0 = _wsc(C0, (None, None, None, shard_axis))
        v = _wsc(v, (None, None, None, shard_axis))

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs  # [B,H,d] x3, [B,H] x2
        log_f = jax.nn.log_sigmoid(ft.astype(jnp.float32))
        log_i = it.astype(jnp.float32)
        m_new = jnp.maximum(log_f + m, log_i)
        m_new = jnp.where(jnp.isinf(m_new), log_i, m_new)  # first step
        i_s = jnp.exp(log_i - m_new)
        f_s = jnp.exp(log_f + m - m_new)
        f_s = jnp.where(jnp.isinf(m), 0.0, f_s)
        kf, vf, qf = kt.astype(jnp.float32), vt.astype(jnp.float32), qt.astype(jnp.float32)
        C = f_s[..., None, None] * C + i_s[..., None, None] * (kf[..., :, None] * vf[..., None, :])
        if shard_axis:
            C = _wsc(C, (None, None, None, shard_axis))
        n = f_s[..., None] * n + i_s[..., None] * kf
        num = jnp.einsum("bhkv,bhk->bhv", C, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h.astype(q.dtype)

    xs = (
        jnp.moveaxis(q, 2, 0), jnp.moveaxis(k, 2, 0), jnp.moveaxis(v, 2, 0),
        jnp.moveaxis(i_pre, 2, 0), jnp.moveaxis(f_pre, 2, 0),
    )
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs, unroll=unroll)
    h = jnp.moveaxis(hs, 0, 2)  # [B,H,S,d]
    return h, (C, n, m, jnp.zeros((), jnp.float32))


def mlstm_block(x: jax.Array, p: dict, *, num_heads: int, state=None,
                unroll: int = 1, shard_axis: Optional[str] = None):
    """x: [B,S,D]. Params: wq/wk/wv [D,D], wi/wf [D,H], wo [D,D], ogate [D,D]."""
    B, S, D = x.shape
    hd = D // num_heads

    def split(y):
        return y.reshape(B, S, num_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split(x @ p["wq"]), split(x @ p["wk"]), split(x @ p["wv"])
    i_pre = (x @ p["wi"]).transpose(0, 2, 1)  # [B,H,S]
    f_pre = (x @ p["wf"]).transpose(0, 2, 1)
    h, new_state = mlstm_scan(q, k, v, i_pre, f_pre, state, unroll=unroll,
                              shard_axis=shard_axis)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, D)
    o = jax.nn.sigmoid(x @ p["ogate"])
    return (o * h) @ p["wo"], new_state


def mlstm_init_state(batch: int, num_heads: int, head_dim: int):
    return (
        jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32),
        jnp.zeros((batch, num_heads, head_dim), jnp.float32),
        jnp.full((batch, num_heads), -jnp.inf, jnp.float32),
        jnp.zeros((), jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_block(x: jax.Array, p: dict, *, num_heads: int, state=None,
                unroll: int = 1):
    """Stabilized sLSTM with block-diagonal recurrence.

    Params: wz/wi/wf/wo [D, D] input projections; rz/ri/rf/ro [H, hd, hd]
    recurrent per-head mixing; wout [D, D].
    State: (c, n, h, m) each [B, H, hd] (m: [B, H]).
    """
    B, S, D = x.shape
    H = num_heads
    hd = D // H
    if state is None:
        z0 = jnp.zeros((B, H, hd), jnp.float32)
        state = (z0, z0, z0, jnp.full((B, H), -jnp.inf, jnp.float32))

    zx = (x @ p["wz"]).reshape(B, S, H, hd)
    ix = (x @ p["wi"]).reshape(B, S, H, hd)
    fx = (x @ p["wf"]).reshape(B, S, H, hd)
    ox = (x @ p["wo"]).reshape(B, S, H, hd)

    def step(carry, xs):
        c, n, h, m = carry
        zt, it, ft, ot = [a.astype(jnp.float32) for a in xs]  # [B,H,hd]
        # recurrent contributions (block-diagonal per head)
        zr = jnp.einsum("bhd,hde->bhe", h, p["rz"].astype(jnp.float32))
        ir = jnp.einsum("bhd,hde->bhe", h, p["ri"].astype(jnp.float32))
        fr = jnp.einsum("bhd,hde->bhe", h, p["rf"].astype(jnp.float32))
        orr = jnp.einsum("bhd,hde->bhe", h, p["ro"].astype(jnp.float32))
        z = jnp.tanh(zt + zr)
        log_i = jnp.mean(it + ir, axis=-1)  # per-head scalar gates [B,H]
        log_f = jax.nn.log_sigmoid(jnp.mean(ft + fr, axis=-1))
        o = jax.nn.sigmoid(ot + orr)
        m_new = jnp.maximum(log_f + m, log_i)
        m_new = jnp.where(jnp.isinf(m_new), log_i, m_new)
        i_s = jnp.exp(log_i - m_new)[..., None]
        f_s = jnp.where(jnp.isinf(m), 0.0, jnp.exp(log_f + m - m_new))[..., None]
        c = f_s * c + i_s * z
        n = f_s * n + i_s
        h_new = o * c / jnp.maximum(n, 1.0)
        return (c, n, h_new, m_new), h_new.astype(x.dtype)

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (zx, ix, fx, ox))
    final, hs = jax.lax.scan(step, state, xs, unroll=unroll)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, D)
    return h @ p["wout"], final


def slstm_init_state(batch: int, num_heads: int, head_dim: int):
    z = jnp.zeros((batch, num_heads, head_dim), jnp.float32)
    return (z, z, z, jnp.full((batch, num_heads), -jnp.inf, jnp.float32))


# ---------------------------------------------------------------------------
# SSD (Mamba2-style, scalar per-head decay) — chunkwise parallel
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,      # [B, S, H, P]
    b: jax.Array,      # [B, S, H, N]
    c: jax.Array,      # [B, S, H, N]
    log_a: jax.Array,  # [B, S, H] (<= 0)
    *,
    chunk: int = 256,
    state: Optional[jax.Array] = None,  # [B, H, P, N]
    unroll: int = 1,
) -> Tuple[jax.Array, jax.Array]:
    """y[t] = C[t] . h[t],  h[t] = a[t] h[t-1] + B[t] (x) x[t].

    Quadratic within chunks (matmuls), linear across chunks (scan).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    if S % chunk != 0:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk

    def resh(t):  # [B, S, ...] -> [nc, B, chunk, ...]
        return jnp.moveaxis(t.reshape(B, nc, chunk, *t.shape[2:]), 1, 0)

    xc, bc, cc, lac = resh(x), resh(b), resh(c), resh(log_a)
    h0 = state if state is not None else jnp.zeros((B, H, P, N), jnp.float32)

    def chunk_step(h, xs):
        xk, bk, ck, lak = xs  # [B, chunk, H, ...]
        la = jnp.cumsum(lak.astype(jnp.float32), axis=1)  # [B, c, H] inclusive
        # intra-chunk: M[t,s] = exp(la_t - la_s) * (C_t . B_s), s <= t
        cb = jnp.einsum("bthn,bshn->bhts", ck.astype(jnp.float32), bk.astype(jnp.float32))
        decay = jnp.exp(la[:, :, None, :] - la[:, None, :, :])  # [B, t, s, H]
        decay = jnp.moveaxis(decay, 3, 1)  # [B, H, t, s]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        m = jnp.where(causal[None, None], cb * decay, 0.0)
        y_intra = jnp.einsum("bhts,bshp->bthp", m, xk.astype(jnp.float32))
        # inter-chunk: y_inter[t] = exp(la_t) * C_t . h
        y_inter = jnp.einsum("bthn,bhpn->bthp", ck.astype(jnp.float32), h) * jnp.exp(la)[..., None]
        # state update: h' = exp(la_end) h + sum_s exp(la_end - la_s) B_s (x) x_s
        la_end = la[:, -1, :]  # [B, H]
        w = jnp.exp(la_end[:, None, :] - la)  # [B, c, H]
        dstate = jnp.einsum("bsh,bshp,bshn->bhpn", w, xk.astype(jnp.float32), bk.astype(jnp.float32))
        h_new = jnp.exp(la_end)[:, :, None, None] * h + dstate
        y = (y_intra + y_inter).astype(x.dtype)
        return h_new, y

    h_fin, ys = jax.lax.scan(chunk_step, h0, (xc, bc, cc, lac), unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, H, P)[:, :S]
    return y, h_fin


def ssd_decode_step(x, b, c, log_a, state):
    """One-token recurrence. x:[B,H,P] b,c:[B,H,N] log_a:[B,H] state:[B,H,P,N]."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    state = a * state + jnp.einsum("bhp,bhn->bhpn", x.astype(jnp.float32), b.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", state, c.astype(jnp.float32))
    return y.astype(x.dtype), state


def mamba_block(x: jax.Array, p: dict, *, num_heads: int, ssm_state: int,
                chunk: int = 256, state=None, decode: bool = False,
                unroll: int = 1):
    """Mamba2-style block. Params: win [D, 2*Di + 2*H*N + H] fused input proj
    (x-path, z-gate, B, C, dt), a_log [H], d_skip [H], wout [Di, D],
    where Di = H * P (inner dim, P = Di/H)."""
    B, S, D = x.shape
    H, N = num_heads, ssm_state
    proj = x @ p["win"]
    Di = p["wout"].shape[0]
    P = Di // H
    xin, z, bc, dt = jnp.split(proj, [Di, 2 * Di, 2 * Di + 2 * H * N], axis=-1)
    bpart, cpart = jnp.split(bc, 2, axis=-1)
    xin = xin.reshape(B, S, H, P)
    bpart = bpart.reshape(B, S, H, N)
    cpart = cpart.reshape(B, S, H, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32))  # [B, S, H]
    log_a = -dt * jnp.exp(p["a_log"].astype(jnp.float32))[None, None, :]
    xin_dt = xin.astype(jnp.float32) * dt[..., None]

    if decode:
        y, new_state = ssd_decode_step(
            xin_dt[:, 0], bpart[:, 0], cpart[:, 0], log_a[:, 0], state
        )
        y = y[:, None]
    else:
        y, new_state = ssd_chunked(
            xin_dt.astype(x.dtype), bpart, cpart, log_a, chunk=min(chunk, S),
            state=state, unroll=unroll,
        )
    y = y + xin.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, Di).astype(x.dtype) * jax.nn.silu(z)
    return y @ p["wout"], new_state


def mamba_init_state(batch: int, num_heads: int, head_dim: int, ssm_state: int):
    return jnp.zeros((batch, num_heads, head_dim, ssm_state), jnp.float32)
