"""Model building blocks: norms, RoPE, GQA attention (full / sliding-window /
ring-buffer decode cache), SwiGLU MLP.

Attention dispatches through :mod:`repro.kernels.ops` so the same model code
runs the Pallas kernel on TPU (or in interpret mode in tests) and the pure-jnp
reference when lowering the dry-run.

KV caches carry an explicit per-slot position array, so a *ring buffer* cache
(sliding-window attention) and a linear cache are the same code path. The ring
is the CMP protection window made literal: a slot whose position falls out of
the window is reclaimed by the next insert, coordination-free (DESIGN.md §4).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm(x: jax.Array, p: dict, kind: str) -> jax.Array:
    if kind == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, num_heads, head_dim]; positions: [B, S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _sdpa(q, k, v, mask, softcap: float = 0.0):
    """q:[B,S,H,hd] k,v:[B,T,KV,hd] mask broadcastable to [B,rep,KV,S,T].

    GQA grouping is r-major (query head h uses KV head h % KV): the reshape
    H -> (rep, KV) then keeps a model-axis sharding of H expressible as a
    sharding of `rep`, so GSPMD shards attention over TP instead of
    replicating it (a 16x compute difference at KV=2, TP=16)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qh = q.reshape(B, S, rep, KV, hd)
    logits = jnp.einsum("bsrgd,btgd->brgst", qh, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(hd))
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("brgst,btgd->bsrgd", w.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def self_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    sliding_window: int = 0, softcap: float = 0.0, impl: str = "ref",
) -> jax.Array:
    """Causal self-attention over equal-length q/k/v (train & prefill)."""
    if impl == "pallas" and softcap == 0.0:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True, sliding_window=sliding_window)
    S, T = q.shape[1], k.shape[1]
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = q_pos >= k_pos
    if sliding_window > 0:
        mask = mask & (q_pos - k_pos < sliding_window)
    return _sdpa(q, k, v, mask[None, None, None], softcap=softcap)


def cache_attention(
    q: jax.Array,            # [B, S, H, hd] (S=1 decode, or prefill chunk)
    k: jax.Array, v: jax.Array,  # [B, T, KV, hd] cache contents
    q_pos: jax.Array,        # [B, S] absolute positions of queries
    k_pos: jax.Array,        # [B, T] absolute positions of cache slots (-1 invalid)
    *, sliding_window: int = 0, softcap: float = 0.0,
) -> jax.Array:
    mask = (k_pos[:, None, :] >= 0) & (q_pos[:, :, None] >= k_pos[:, None, :])
    if sliding_window > 0:
        mask = mask & (q_pos[:, :, None] - k_pos[:, None, :] < sliding_window)
    return _sdpa(q, k, v, mask[:, None, None], softcap=softcap)


def chunked_cache_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    q_pos: jax.Array, k_pos: jax.Array,
    *, sliding_window: int = 0, softcap: float = 0.0,
    block_k: int = 1024, unroll: int = 1, kv_block_axis=None,
    batch_axes=None,
) -> jax.Array:
    """Online-softmax attention over the cache in KV blocks — O(S*block_k)
    working set instead of O(S*T). Forward-only (used for prefill/decode, the
    pure-JAX equivalent of the Pallas flash kernel; grads go through the ref
    path under remat)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    rep = H // KV
    pad = (-T) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    nb = (T + pad) // block_k
    kb = jnp.moveaxis(k.reshape(B, nb, block_k, KV, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, block_k, KV, hd), 1, 0)
    pb = jnp.moveaxis(k_pos.reshape(B, nb, block_k), 1, 0)
    seq_parallel = False
    if kv_block_axis is not None:
        # Sequence-parallel attention: queries (and the running softmax
        # state) shard over ``kv_block_axis``; each scanned KV block is
        # broadcast (small) instead of scanning across a sharded time dim,
        # which would force either an involuntary full rematerialization of
        # the cache or a full-accumulator psum every step (both measured —
        # EXPERIMENTS.md §Perf cell A).
        from jax.sharding import PartitionSpec as P
        ba = tuple(batch_axes) if batch_axes else None
        try:
            kb = jax.lax.with_sharding_constraint(kb, P(None, ba, None, None, None))
            vb = jax.lax.with_sharding_constraint(vb, P(None, ba, None, None, None))
            pb = jax.lax.with_sharding_constraint(pb, P(None, ba, None))
            seq_parallel = True
        except (ValueError, RuntimeError):
            pass  # no ambient mesh
    qh = q.reshape(B, S, rep, KV, hd)  # r-major GQA (see _sdpa)
    if seq_parallel:
        from jax.sharding import PartitionSpec as P
        ba = tuple(batch_axes) if batch_axes else None
        qh = jax.lax.with_sharding_constraint(
            qh, P(ba, kv_block_axis, None, None, None))
        q_pos = jax.lax.with_sharding_constraint(q_pos, P(ba, kv_block_axis))
    scale = 1.0 / (hd ** 0.5)

    def body(carry, xs):
        acc, m, l = carry
        kc, vc, kp = xs  # [B, bk, KV, hd], [B, bk]
        s = jnp.einsum("bsrgd,btgd->bsrgt", qh, kc).astype(jnp.float32) * scale
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        mask = (kp[:, None, :] >= 0) & (q_pos[:, :, None] >= kp[:, None, :])
        if sliding_window > 0:
            mask = mask & (q_pos[:, :, None] - kp[:, None, :] < sliding_window)
        mask = mask[:, :, None, None, :]
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bsrgt,btgd->bsrgd", p.astype(vc.dtype), vc).astype(jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, S, rep, KV, hd), jnp.float32)
    m0 = jnp.full((B, S, rep, KV), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S, rep, KV), jnp.float32)
    if seq_parallel:
        from jax.sharding import PartitionSpec as P
        ba = tuple(batch_axes) if batch_axes else None
        acc0 = jax.lax.with_sharding_constraint(
            acc0, P(ba, kv_block_axis, None, None, None))
        m0 = jax.lax.with_sharding_constraint(m0, P(ba, kv_block_axis, None, None))
        l0 = jax.lax.with_sharding_constraint(l0, P(ba, kv_block_axis, None, None))
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, pb),
                                  unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, H, hd).astype(q.dtype)


def kv_chunks(seq: int, t_cache: int, block_k: int) -> int:
    """Number of chunked-attention scan steps (0 = direct path). Must mirror
    the dispatch condition in attention_block exactly (dry-run extrapolation
    depends on it)."""
    if block_k <= 0 or seq <= 1 or t_cache <= block_k:
        return 0
    return -(-t_cache // block_k)


class KVCache(NamedTuple):
    k: jax.Array    # [B, T, KV, hd]
    v: jax.Array    # [B, T, KV, hd]
    pos: jax.Array  # [B, T] int32, -1 = empty slot


def make_kv_cache(batch: int, t_cache: int, num_kv: int, head_dim: int, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, t_cache, num_kv, head_dim), dtype),
        v=jnp.zeros((batch, t_cache, num_kv, head_dim), dtype),
        pos=jnp.full((batch, t_cache), -1, jnp.int32),
    )


def cache_insert(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                 positions: jax.Array) -> KVCache:
    """Insert S new entries at ring slots ``positions % T``. For a full-
    attention cache T >= max position so the ring never wraps."""
    B, S = positions.shape
    T = cache.k.shape[1]
    if S >= T:  # only the last T entries survive (static shapes)
        k_new, v_new, positions = k_new[:, -T:], v_new[:, -T:], positions[:, -T:]
        S = T
    slots = positions % T  # [B, S]
    b_idx = jnp.arange(B)[:, None]
    return KVCache(
        k=cache.k.at[b_idx, slots].set(k_new.astype(cache.k.dtype)),
        v=cache.v.at[b_idx, slots].set(v_new.astype(cache.v.dtype)),
        pos=cache.pos.at[b_idx, slots].set(positions),
    )


def attention_block(
    x: jax.Array,  # [B, S, D]
    p: dict,       # wq [D, H*hd], wk/wv [D, KV*hd], wo [H*hd, D]
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    sliding_window: int = 0,
    softcap: float = 0.0,
    positions: Optional[jax.Array] = None,  # [B, S] absolute positions
    cache: Optional[KVCache] = None,
    impl: str = "ref",
    chunk_kv: int = 0,
    attn_unroll: int = 1,
    kv_block_axis=None,
    batch_axes=None,
) -> Tuple[jax.Array, Optional[KVCache]]:
    """Returns (out [B,S,D], new_cache|None). With a cache, RoPE is applied at
    insert time (keys rotated by absolute position) and attention runs against
    the full ring."""
    B, S, D = x.shape
    q = (x @ p["wq"]).reshape(B, S, num_heads, head_dim)
    kx = (x @ p["wk"]).reshape(B, S, num_kv_heads, head_dim)
    vx = (x @ p["wv"]).reshape(B, S, num_kv_heads, head_dim)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    q = apply_rope(q, positions, rope_theta)
    kx = apply_rope(kx, positions, rope_theta)

    if cache is None:
        out = self_attention(q, kx, vx, sliding_window=sliding_window,
                             softcap=softcap, impl=impl)
        new_cache = None
    else:
        new_cache = cache_insert(cache, kx, vx, positions)
        t_cache = new_cache.k.shape[1]
        if kv_chunks(S, t_cache, chunk_kv) > 0:
            out = chunked_cache_attention(
                q, new_cache.k, new_cache.v, positions, new_cache.pos,
                sliding_window=sliding_window, softcap=softcap,
                block_k=chunk_kv, unroll=attn_unroll,
                kv_block_axis=kv_block_axis, batch_axes=batch_axes)
        else:
            out = cache_attention(q, new_cache.k, new_cache.v, positions,
                                  new_cache.pos, sliding_window=sliding_window,
                                  softcap=softcap)
    out = out.reshape(B, S, num_heads * head_dim) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, p: dict, act: str = "silu") -> jax.Array:
    """Gated MLP: wg/wu [D, F], wd [F, D]."""
    g = x @ p["wg"]
    u = x @ p["wu"]
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return (a * u) @ p["wd"]
