"""Decoder block variants (dense / moe / mlstm / slstm / hymba) with a uniform
interface so the model can ``lax.scan`` over stacked per-kind parameters.

Each kind defines:
  init_<kind>(cfg, key)            -> param pytree for ONE layer
  apply_<kind>(x, p, cfg, ...)     -> (x', aux_loss, new_cache)

Caches are kind-specific NamedTuple/array pytrees; ``init_cache_<kind>``
builds the per-layer cache for decoding.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _norm_params(cfg: ModelConfig, d: int) -> dict:
    p = {"scale": jnp.ones((d,), _dt(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), _dt(cfg))
    return p


def _dense_init(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# attention + MLP params (shared by dense/moe/hymba kinds)
# ---------------------------------------------------------------------------


def _init_attn(cfg: ModelConfig, key) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    return {
        "wq": _dense_init(k1, (D, H * hd), _dt(cfg)),
        "wk": _dense_init(k2, (D, KV * hd), _dt(cfg)),
        "wv": _dense_init(k3, (D, KV * hd), _dt(cfg)),
        "wo": _dense_init(k4, (H * hd, D), _dt(cfg), out_scale),
    }


def _init_mlp(cfg: ModelConfig, key) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    return {
        "wg": _dense_init(k1, (D, F), _dt(cfg)),
        "wu": _dense_init(k2, (D, F), _dt(cfg)),
        "wd": _dense_init(k3, (F, D), _dt(cfg), out_scale),
    }


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------


def init_dense(cfg: ModelConfig, key) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": _norm_params(cfg, cfg.d_model),
        "attn": _init_attn(cfg, k1),
        "ln2": _norm_params(cfg, cfg.d_model),
        "mlp": _init_mlp(cfg, k2),
    }


def apply_dense(x, p, cfg: ModelConfig, positions=None, cache=None):
    h, new_cache = L.attention_block(
        L.norm(x, p["ln1"], cfg.norm), p["attn"],
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        sliding_window=cfg.sliding_window, softcap=cfg.attn_softcap,
        positions=positions, cache=cache, impl=cfg.attention_impl,
        chunk_kv=cfg.attn_chunk_kv, attn_unroll=cfg.attn_scan_unroll,
        kv_block_axis=cfg.kv_block_axis, batch_axes=cfg.batch_axes,
    )
    x = x + h
    x = x + L.swiglu(L.norm(x, p["ln2"], cfg.norm), p["mlp"], cfg.act)
    return x, jnp.zeros((), jnp.float32), new_cache


# ---------------------------------------------------------------------------
# moe
# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key) -> dict:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.expert_d_ff or cfg.d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    return {
        "ln1": _norm_params(cfg, D),
        "attn": _init_attn(cfg, k1),
        "ln2": _norm_params(cfg, D),
        "moe": {
            "router": _dense_init(k2, (D, E), jnp.float32),
            "wg": _dense_init(k3, (E, D, F), _dt(cfg)),
            "wu": _dense_init(k4, (E, D, F), _dt(cfg)),
            "wd": _dense_init(k5, (E, F, D), _dt(cfg), out_scale),
        },
    }


def apply_moe(x, p, cfg: ModelConfig, positions=None, cache=None):
    h, new_cache = L.attention_block(
        L.norm(x, p["ln1"], cfg.norm), p["attn"],
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        sliding_window=cfg.sliding_window, softcap=cfg.attn_softcap,
        positions=positions, cache=cache, impl=cfg.attention_impl,
        chunk_kv=cfg.attn_chunk_kv, attn_unroll=cfg.attn_scan_unroll,
        kv_block_axis=cfg.kv_block_axis, batch_axes=cfg.batch_axes,
    )
    x = x + h
    y, aux = M.moe_block(
        L.norm(x, p["ln2"], cfg.norm), p["moe"],
        num_experts=cfg.num_experts, top_k=cfg.num_experts_per_tok,
        capacity_factor=cfg.capacity_factor, act=cfg.act,
        groups=cfg.moe_groups,
    )
    return x + y, aux, new_cache


# ---------------------------------------------------------------------------
# mlstm / slstm (xLSTM)
# ---------------------------------------------------------------------------


def init_mlstm(cfg: ModelConfig, key) -> dict:
    D, H = cfg.d_model, cfg.ssm_heads or cfg.num_heads
    ks = jax.random.split(key, 7)
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    return {
        "ln1": _norm_params(cfg, D),
        "mlstm": {
            "wq": _dense_init(ks[0], (D, D), _dt(cfg)),
            "wk": _dense_init(ks[1], (D, D), _dt(cfg)),
            "wv": _dense_init(ks[2], (D, D), _dt(cfg)),
            "wi": _dense_init(ks[3], (D, H), _dt(cfg)),
            "wf": _dense_init(ks[4], (D, H), _dt(cfg)),
            "ogate": _dense_init(ks[5], (D, D), _dt(cfg)),
            "wo": _dense_init(ks[6], (D, D), _dt(cfg), out_scale),
        },
    }


def apply_mlstm(x, p, cfg: ModelConfig, positions=None, cache=None):
    H = cfg.ssm_heads or cfg.num_heads
    h, new_state = S.mlstm_block(L.norm(x, p["ln1"], cfg.norm), p["mlstm"],
                                 num_heads=H, state=cache,
                                 unroll=cfg.time_scan_unroll,
                                 shard_axis=cfg.ssm_shard_axis)
    return x + h, jnp.zeros((), jnp.float32), new_state


def init_slstm(cfg: ModelConfig, key) -> dict:
    D, H = cfg.d_model, cfg.ssm_heads or cfg.num_heads
    hd = D // H
    ks = jax.random.split(key, 9)
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    r = lambda k: _dense_init(k, (H, hd, hd), _dt(cfg))
    return {
        "ln1": _norm_params(cfg, D),
        "slstm": {
            "wz": _dense_init(ks[0], (D, D), _dt(cfg)),
            "wi": _dense_init(ks[1], (D, D), _dt(cfg)),
            "wf": _dense_init(ks[2], (D, D), _dt(cfg)),
            "wo": _dense_init(ks[3], (D, D), _dt(cfg)),
            "rz": r(ks[4]), "ri": r(ks[5]), "rf": r(ks[6]), "ro": r(ks[7]),
            "wout": _dense_init(ks[8], (D, D), _dt(cfg), out_scale),
        },
    }


def apply_slstm(x, p, cfg: ModelConfig, positions=None, cache=None):
    H = cfg.ssm_heads or cfg.num_heads
    h, new_state = S.slstm_block(L.norm(x, p["ln1"], cfg.norm), p["slstm"],
                                 num_heads=H, state=cache,
                                 unroll=cfg.time_scan_unroll)
    return x + h, jnp.zeros((), jnp.float32), new_state


# ---------------------------------------------------------------------------
# hymba (parallel attention + mamba heads, fused by mean of normed outputs)
# ---------------------------------------------------------------------------


def init_hymba(cfg: ModelConfig, key) -> dict:
    D = cfg.d_model
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Di = H * P
    k1, k2, k3, k4 = jax.random.split(key, 4)
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    return {
        "ln1": _norm_params(cfg, D),
        "attn": _init_attn(cfg, k1),
        "mamba": {
            "win": _dense_init(k2, (D, 2 * Di + 2 * H * N + H), _dt(cfg)),
            "a_log": jnp.zeros((H,), jnp.float32),
            "d_skip": jnp.ones((H,), jnp.float32),
            "wout": _dense_init(k3, (Di, D), _dt(cfg), out_scale),
        },
        "norm_attn": _norm_params(cfg, D),
        "norm_ssm": _norm_params(cfg, D),
        "ln2": _norm_params(cfg, D),
        "mlp": _init_mlp(cfg, k4),
    }


def apply_hymba(x, p, cfg: ModelConfig, positions=None, cache=None):
    xin = L.norm(x, p["ln1"], cfg.norm)
    kv_cache = cache[0] if cache is not None else None
    ssm_state = cache[1] if cache is not None else None
    attn_out, new_kv = L.attention_block(
        xin, p["attn"],
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        sliding_window=cfg.sliding_window, positions=positions,
        cache=kv_cache, impl=cfg.attention_impl,
        chunk_kv=cfg.attn_chunk_kv, attn_unroll=cfg.attn_scan_unroll,
        kv_block_axis=cfg.kv_block_axis, batch_axes=cfg.batch_axes,
    )
    ssm_out, new_state = S.mamba_block(
        xin, p["mamba"], num_heads=cfg.ssm_heads, ssm_state=cfg.ssm_state,
        chunk=cfg.ssd_chunk, state=ssm_state,
        decode=cache is not None and x.shape[1] == 1,
        unroll=cfg.time_scan_unroll,
    )
    fused = 0.5 * (L.norm(attn_out, p["norm_attn"], cfg.norm)
                   + L.norm(ssm_out, p["norm_ssm"], cfg.norm))
    x = x + fused
    x = x + L.swiglu(L.norm(x, p["ln2"], cfg.norm), p["mlp"], cfg.act)
    new_cache = (new_kv, new_state) if cache is not None else None
    return x, jnp.zeros((), jnp.float32), new_cache


# ---------------------------------------------------------------------------
# registry + cache builders
# ---------------------------------------------------------------------------

INIT = {"dense": init_dense, "moe": init_moe, "mlstm": init_mlstm,
        "slstm": init_slstm, "hymba": init_hymba}
APPLY = {"dense": apply_dense, "moe": apply_moe, "mlstm": apply_mlstm,
         "slstm": apply_slstm, "hymba": apply_hymba}


def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Ring size: the CMP window — SWA archs keep only the window."""
    if cfg.sliding_window > 0:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache_kind(kind: str, cfg: ModelConfig, batch: int, seq_len: int):
    dt = jnp.dtype(cfg.dtype)
    if kind in ("dense", "moe"):
        return L.make_kv_cache(batch, cache_len(cfg, seq_len), cfg.num_kv_heads,
                               cfg.resolved_head_dim, dt)
    if kind == "mlstm":
        H = cfg.ssm_heads or cfg.num_heads
        return S.mlstm_init_state(batch, H, cfg.d_model // H)
    if kind == "slstm":
        H = cfg.ssm_heads or cfg.num_heads
        return S.slstm_init_state(batch, H, cfg.d_model // H)
    if kind == "hymba":
        kv = L.make_kv_cache(batch, cache_len(cfg, seq_len), cfg.num_kv_heads,
                             cfg.resolved_head_dim, dt)
        st = S.mamba_init_state(batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
        return (kv, st)
    raise ValueError(f"unknown block kind {kind!r}")
