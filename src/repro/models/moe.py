"""Mixture-of-Experts with CMP-style capacity-slot dispatch.

Dispatch is the gather/scatter formulation (sort-by-expert + positional slot
assignment) rather than a [T, E, C] one-hot einsum: the one-hot materializes
tokens x experts x capacity and is infeasible at 1M-token global batches; the
gather form keeps memory at O(E x C x D) and lowers to all-to-all style
collectives under expert sharding.

CMP correspondence (DESIGN.md §4): expert capacity slots are a cyclic slot
pool — tokens claim slots in *token order* (earliest-claim FIFO property),
overflow tokens are dropped deterministically (bounded capacity = protection
window), and slots are implicitly reclaimed every step (window = 1 step).
``assign_slots`` is the deterministic analogue of the paper's claim CAS and is
also exercised against :mod:`repro.core.slotpool` in tests.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.domain import window_admit


def assign_slots(expert_ids: jax.Array, num_experts: int, capacity: int) -> Tuple[jax.Array, jax.Array]:
    """FIFO capacity-slot assignment.

    expert_ids: [A] int32 (A = tokens*k, flattened claim requests in token order).
    Returns (slot [A] int32 in [0, E*C) or E*C for dropped, keep [A] bool).
    Token order is claim order: the j-th request for expert e gets slot (e, j);
    requests beyond capacity are dropped (earliest-claim wins, as in the
    paper's AVAILABLE->CLAIMED transition).
    """
    e = num_experts
    a = expert_ids.shape[0]
    # Stable sort keeps token order within each expert => earliest-claim FIFO.
    order = jnp.argsort(expert_ids, stable=True)
    sorted_e = expert_ids[order]
    cnt = jnp.bincount(expert_ids, length=e)
    starts = jnp.cumsum(cnt) - cnt  # exclusive prefix
    pos_sorted = jnp.arange(a, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    pos = jnp.zeros((a,), jnp.int32).at[order].set(pos_sorted)
    # Bounded capacity IS the protection window (domain.window_admit): the
    # j-th claim on an expert is admitted iff j < C, exactly as a slot whose
    # position fell outside the window is not.
    keep = window_admit(pos, capacity)
    slot = jnp.where(keep, expert_ids * capacity + pos, e * capacity)
    return slot.astype(jnp.int32), keep


def moe_block(
    x: jax.Array,  # [B, S, D]
    p: dict,       # router [D, E]; wg/wu [E, D, F]; wd [E, F, D]
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    min_capacity: int = 8,
    act: str = "silu",
    groups: int = 1,
) -> jax.Array:
    B, S, D = x.shape
    if groups > 1 and B % groups == 0:
        # Group-local dispatch (§Perf): sort/gather/scatter stay within a
        # token group, so under batch sharding they never cross shards —
        # the all-concat gathers of global dispatch disappear. Capacity is
        # per-group (slightly higher drop variance, standard trade).
        xg = x.reshape(groups, B // groups, S, D)
        yg, aux = jax.vmap(
            lambda xx: moe_block(xx, p, num_experts=num_experts, top_k=top_k,
                                 capacity_factor=capacity_factor,
                                 min_capacity=min_capacity, act=act, groups=1)
        )(xg)
        return yg.reshape(B, S, D), jnp.mean(aux)
    T = B * S
    E, k = num_experts, top_k
    xt = x.reshape(T, D)

    # --- routing ---
    logits = (xt @ p["router"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)  # [T, k]
    gates = gates / jnp.clip(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # --- slot claim (CMP earliest-claim) ---
    # Capacity floor keeps tiny decode batches dropless; cap at T*k (dropless
    # upper bound) keeps small-model shapes tight.
    capacity = min(T * k, max(min_capacity, int(T * k * capacity_factor / E)))
    flat_ids = ids.reshape(-1)  # [T*k], token-major = claim order
    slot, keep = assign_slots(flat_ids, E, capacity)

    # --- dispatch: gather token rows into [E*C, D] expert buffers ---
    flat_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    token_for_slot = jnp.full((E * capacity,), T, dtype=jnp.int32)
    token_for_slot = token_for_slot.at[slot].set(flat_token, mode="drop")
    x_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
    xin = x_pad[token_for_slot].reshape(E, capacity, D)

    # --- expert MLPs (grouped over E; shards over the expert/model axis) ---
    g = jnp.einsum("ecd,edf->ecf", xin, p["wg"])
    u = jnp.einsum("ecd,edf->ecf", xin, p["wu"])
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    out_ec = jnp.einsum("ecf,efd->ecd", a * u, p["wd"])  # [E, C, D]

    # --- combine: gather each request's slot output, weight, scatter-add ---
    out_pad = jnp.concatenate(
        [out_ec.reshape(E * capacity, D), jnp.zeros((1, D), out_ec.dtype)], axis=0
    )
    per_req = out_pad[slot]  # [T*k, D] (dropped -> zeros row)
    per_req = per_req * gates.reshape(-1)[:, None].astype(per_req.dtype)
    y = jnp.zeros((T, D), per_req.dtype).at[flat_token].add(per_req)

    # --- aux: load-balancing loss term (Switch-style) ---
    me = jnp.mean(probs, axis=0)  # [E]
    ce = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    return y.reshape(B, S, D).astype(x.dtype), aux
