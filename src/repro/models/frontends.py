"""Modality-frontend STUBS (per the assignment: ``[vlm]``/``[audio]`` entries
specify the transformer backbone only; ``input_specs()`` provides precomputed
frame/patch embeddings).

These helpers generate deterministic synthetic embeddings for smoke tests and
ShapeDtypeStructs for the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def vision_patch_embeds(cfg: ModelConfig, batch: int, num_patches: int,
                        key: jax.Array) -> jax.Array:
    """Anyres patch embeddings a real CLIP tower + projector would produce."""
    return (jax.random.normal(key, (batch, num_patches, cfg.d_model), jnp.float32)
            * 0.02).astype(jnp.dtype(cfg.dtype))


def audio_frame_tokens(cfg: ModelConfig, batch: int, seq: int, key: jax.Array) -> jax.Array:
    """EnCodec token ids (codebook vocab) a real encoder would produce."""
    return jax.random.randint(key, (batch, seq), 0, cfg.vocab_size, jnp.int32)


def num_frontend_embeds(cfg: ModelConfig) -> int:
    if cfg.frontend == "vision":
        from repro.configs.llava_next import NUM_IMAGE_EMBEDS
        return NUM_IMAGE_EMBEDS
    return 0
