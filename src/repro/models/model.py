"""Model assembly: scan-over-stacked-layers causal LM supporting every
assigned architecture family (dense / moe / ssm / hybrid / vlm / audio).

Layers are stacked per block-pattern position and iterated with ``lax.scan``
(small HLO, fast multi-pod compiles, remat-friendly). Multimodal frontends are
stubs per the assignment: ``extra_embeds`` (precomputed patch/frame
embeddings) are prepended to the token embeddings.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models import layers as L


def _shard_batch(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Re-anchor the batch sharding after the embedding gather (whose output
    sharding is ambiguous under 2-D sharded embeddings — see ModelConfig
    .batch_axes). No-op when no mesh/batch_axes configured."""
    if cfg.batch_axes and x.shape[0] % 2 == 0:
        from jax.sharding import PartitionSpec as P
        spec = P(tuple(cfg.batch_axes), *([None] * (x.ndim - 1)))
        try:
            x = jax.lax.with_sharding_constraint(x, spec)
        except (ValueError, RuntimeError):
            pass  # no ambient mesh (single-device tests)
    return x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "final_norm": B._norm_params(cfg, cfg.d_model),
    }
    r = cfg.pattern_repeats
    blocks = {}
    keys = jax.random.split(k_blocks, r)
    for j, kind in enumerate(cfg.block_pattern):
        sub = jax.vmap(lambda k: B.INIT[kind](cfg, jax.random.fold_in(k, j)))(keys)
        blocks[str(j)] = sub
    params["blocks"] = blocks
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab_size), jnp.float32) * 0.02).astype(dt)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def active_param_count(cfg: ModelConfig, params) -> int:
    """Active params per token (MoE: top_k of num_experts routed)."""
    total = param_count(params)
    if cfg.num_experts == 0:
        return total
    expert = 0
    for j, kind in enumerate(cfg.block_pattern):
        if kind == "moe":
            sub = params["blocks"][str(j)]["moe"]
            expert += sum(x.size for k, x in sub.items() if k != "router")
    active_frac = cfg.num_experts_per_tok / cfg.num_experts
    return int(total - expert + expert * active_frac)


# ---------------------------------------------------------------------------
# forward (train / prefill-style full sequence)
# ---------------------------------------------------------------------------


def _logits(x: jax.Array, params, cfg: ModelConfig) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head).astype(jnp.float32)


def apply(
    params,
    tokens: jax.Array,  # [B, S] int32
    cfg: ModelConfig,
    *,
    extra_embeds: Optional[jax.Array] = None,  # [B, n_extra, D] (vlm/audio stubs)
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits [B, S', V] float32, aux_loss)."""
    x = params["embed"][tokens]
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    x = _shard_batch(x, cfg)

    def super_fn(x, layer_p):
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(cfg.block_pattern):
            x, a, _ = B.APPLY[kind](x, layer_p[str(j)], cfg)
            aux = aux + a
        return x, aux

    f = jax.checkpoint(super_fn) if cfg.remat else super_fn
    x, auxs = jax.lax.scan(lambda c, p: f(c, p), x, params["blocks"],
                           unroll=cfg.scan_unroll)
    x = L.norm(x, params["final_norm"], cfg.norm)
    return _logits(x, params, cfg), jnp.sum(auxs)


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    """Stacked per-pattern-position caches + shared position counter."""
    r = cfg.pattern_repeats
    blocks = {}
    for j, kind in enumerate(cfg.block_pattern):
        one = B.init_cache_kind(kind, cfg, batch, seq_len)
        blocks[str(j)] = jax.tree_util.tree_map(
            lambda x: jnp.tile(x[None], (r,) + (1,) * x.ndim), one)
    return {"blocks": blocks, "pos": jnp.zeros((batch,), jnp.int32)}


def _run_with_cache(params, x, cfg: ModelConfig, cache, positions):
    def step(x, xs):
        layer_p, layer_c = xs
        new_c = {}
        for j, kind in enumerate(cfg.block_pattern):
            x, _, nc = B.APPLY[kind](x, layer_p[str(j)], cfg,
                                     positions=positions, cache=layer_c[str(j)])
            new_c[str(j)] = nc
        return x, new_c

    x, new_blocks = jax.lax.scan(step, x, (params["blocks"], cache["blocks"]),
                                 unroll=cfg.scan_unroll)
    x = L.norm(x, params["final_norm"], cfg.norm)
    return x, new_blocks


def prefill(params, tokens: jax.Array, cfg: ModelConfig, cache,
            *, extra_embeds: Optional[jax.Array] = None):
    """Process a full prompt, filling the cache. Returns (last-token logits
    [B, V], cache')."""
    x = params["embed"][tokens]
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    x = _shard_batch(x, cfg)
    Bsz, S = x.shape[0], x.shape[1]
    positions = cache["pos"][:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    x, new_blocks = _run_with_cache(params, x, cfg, cache, positions)
    logits = _logits(x[:, -1:], params, cfg)[:, 0]
    return logits, {"blocks": new_blocks, "pos": cache["pos"] + S}


def decode_step(params, tokens: jax.Array, cfg: ModelConfig, cache):
    """One-token decode. tokens [B, 1] -> (logits [B, V], cache')."""
    x = _shard_batch(params["embed"][tokens], cfg)
    positions = cache["pos"][:, None]
    x, new_blocks = _run_with_cache(params, x, cfg, cache, positions)
    logits = _logits(x, params, cfg)[:, 0]
    return logits, {"blocks": new_blocks, "pos": cache["pos"] + 1}


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            aux_weight: float = 0.01):
    """Next-token cross-entropy. batch: {"tokens": [B, S]} (+"extra_embeds").
    Loss is computed on token positions only (frontend embeds are unlabelled)."""
    tokens = batch["tokens"]
    extra = batch.get("extra_embeds")
    logits, aux = apply(params, tokens[:, :-1], cfg, extra_embeds=extra)
    n_extra = 0 if extra is None else extra.shape[1]
    logits = logits[:, n_extra:]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    metrics = {"loss": loss, "aux_loss": aux, "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}
    return loss + aux_weight * aux, metrics
