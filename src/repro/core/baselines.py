"""Baseline queues the paper evaluates against, on the same atomic substrate
as CMPQueue so atomic-op counts are directly comparable.

* ``MSQueue``      — Michael & Scott with the full helping mechanism (paper
                     Alg 2) and *hazard-pointer* reclamation ("Boost-like").
                     Exhibits the O(P x K) scan cost the paper targets.
* ``SegmentedQueue`` — per-producer segmented sub-queues with relaxed (per-
                     producer-only) FIFO ("Moodycamel-like").
* ``MutexQueue``   — lock-based unbounded queue ("TBB/folly-like").
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Any, List, Optional

from repro.core.atomics import AtomicCell, _count

# ---------------------------------------------------------------------------
# Hazard pointers (Michael 2004)
# ---------------------------------------------------------------------------


class HazardPointers:
    """K hazard slots per registered thread + per-thread retire lists.

    Reclamation scans ALL slots of ALL threads — the O(P x K) coordination
    cost CMP eliminates.
    """

    def __init__(self, k: int = 2, scan_threshold: Optional[int] = None):
        self.k = k
        self._slots: List[AtomicCell] = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._scan_threshold = scan_threshold
        self.stats = {"scans": 0, "scan_comparisons": 0, "freed": 0}

    def _my_base(self) -> int:
        base = getattr(self._tls, "base", None)
        if base is None:
            with self._lock:
                base = len(self._slots)
                for _ in range(self.k):
                    self._slots.append(AtomicCell(None))
            self._tls.base = base
            self._tls.retired = []
        return base

    def protect(self, idx: int, ptr: Any) -> None:
        self._slots[self._my_base() + idx].store(ptr)

    def clear(self, idx: int) -> None:
        self._slots[self._my_base() + idx].store(None)

    def clear_all(self) -> None:
        base = self._my_base()
        for i in range(self.k):
            self._slots[base + i].store(None)

    def retire(self, node: Any, free_fn) -> None:
        self._my_base()
        retired = self._tls.retired
        retired.append(node)
        threshold = self._scan_threshold or max(16, 2 * len(self._slots))
        if len(retired) >= threshold:
            self.scan(free_fn)

    def scan(self, free_fn) -> None:
        """The coordination step: read every thread's every hazard slot."""
        self.stats["scans"] += 1
        hazards = set()
        for slot in list(self._slots):
            self.stats["scan_comparisons"] += 1
            p = slot.load()
            if p is not None:
                hazards.add(id(p))
        retired = self._tls.retired
        keep = []
        for node in retired:
            if id(node) in hazards:
                keep.append(node)
            else:
                free_fn(node)
                self.stats["freed"] += 1
        self._tls.retired = keep


# ---------------------------------------------------------------------------
# Michael & Scott queue with helping + hazard pointers
# ---------------------------------------------------------------------------


class _MSNode:
    __slots__ = ("data", "next")

    def __init__(self, data: Any = None):
        self.data = AtomicCell(data)
        self.next = AtomicCell(None)


class MSQueue:
    """Classic M&S MPMC queue, full helping mechanism, HP reclamation."""

    def __init__(self, hp_slots: int = 2, scan_threshold: Optional[int] = None):
        dummy = _MSNode()
        self.head = AtomicCell(dummy)
        self.tail = AtomicCell(dummy)
        self.hp = HazardPointers(hp_slots, scan_threshold)
        self._free: List[_MSNode] = []  # recycled nodes (type-stable-ish)
        self._free_lock = threading.Lock()

    def _alloc(self, data: Any) -> _MSNode:
        _count("lock")
        with self._free_lock:
            if self._free:
                n = self._free.pop()
                n.data.store(data)
                n.next.store(None)
                return n
        return _MSNode(data)

    def _free_node(self, node: _MSNode) -> None:
        node.data.store(None)
        node.next.store(None)
        _count("lock")
        with self._free_lock:
            self._free.append(node)

    def enqueue(self, data: Any) -> bool:
        node = self._alloc(data)
        while True:
            tail = self.tail.load()
            self.hp.protect(0, tail)
            if tail is not self.tail.load():  # revalidate after publish
                continue
            nxt = tail.next.load()
            if tail is self.tail.load():  # paper Alg 2 line 5 revalidation
                if nxt is not None:
                    self.tail.cas(tail, nxt)  # HELP advance (possibly stale)
                    continue
                if tail.next.cas(None, node):
                    break
        self.tail.cas(tail, node)
        self.hp.clear(0)
        return True

    def dequeue(self) -> Optional[Any]:
        while True:
            head = self.head.load()
            self.hp.protect(0, head)
            if head is not self.head.load():
                continue
            tail = self.tail.load()
            nxt = head.next.load()
            self.hp.protect(1, nxt)
            if head is not self.head.load():
                continue
            if nxt is None:
                self.hp.clear_all()
                return None
            if head is tail:
                self.tail.cas(tail, nxt)  # help
                continue
            data = nxt.data.load()
            if self.head.cas(head, nxt):
                self.hp.clear_all()
                self.hp.retire(head, self._free_node)
                return data


# ---------------------------------------------------------------------------
# Per-producer segmented queue (relaxed FIFO, "Moodycamel-like")
# ---------------------------------------------------------------------------

_SEG_SIZE = 256


class _SubQueue:
    """Single-producer sub-queue: producer-local tail, CAS-claimed head."""

    __slots__ = ("slots", "tail", "head")

    def __init__(self):
        self.slots: List[Any] = []
        self.tail = AtomicCell(0)  # published count (release store)
        self.head = AtomicCell(0)  # consumer claim cursor

    def push(self, data: Any) -> None:
        self.slots.append(data)  # producer-exclusive
        self.tail.store(len(self.slots))  # publish

    def try_pop(self) -> Optional[Any]:
        while True:
            h = self.head.load()
            t = self.tail.load()
            if h >= t:
                return None
            if self.head.cas(h, h + 1):
                data = self.slots[h]
                self.slots[h] = None  # allow GC of payload
                return data


class SegmentedQueue:
    """Relaxed-FIFO MPMC: strict order within a producer, interleaving between
    producers unspecified — the trade-off the paper calls out in Moodycamel."""

    def __init__(self):
        self._subs: List[_SubQueue] = []
        self._lock = threading.Lock()
        self._tls = threading.local()

    def _mine(self) -> _SubQueue:
        sub = getattr(self._tls, "sub", None)
        if sub is None:
            sub = _SubQueue()
            with self._lock:
                self._subs.append(sub)
            self._tls.sub = sub
            self._tls.rr = 0
        return sub

    def enqueue(self, data: Any) -> bool:
        self._mine().push(data)
        return True

    def dequeue(self) -> Optional[Any]:
        self._mine()
        subs = self._subs
        n = len(subs)
        if n == 0:
            return None
        start = self._tls.rr
        for i in range(n):
            sub = subs[(start + i) % n]
            data = sub.try_pop()
            if data is not None:
                self._tls.rr = (start + i) % n
                return data
        return None


# ---------------------------------------------------------------------------
# Mutex queue
# ---------------------------------------------------------------------------


class MutexQueue:
    """Blocking baseline: one lock around a deque (TBB/folly-style hybrid
    designs reduce to this under contention)."""

    def __init__(self):
        self._q: deque = deque()
        self._lock = threading.Lock()

    def enqueue(self, data: Any) -> bool:
        _count("lock")
        with self._lock:
            self._q.append(data)
        return True

    def dequeue(self) -> Optional[Any]:
        _count("lock")
        with self._lock:
            if not self._q:
                return None
            return self._q.popleft()


ALL_QUEUES = {
    "cmp": "repro.core.cmp.CMPQueue",
    "ms_hp": "repro.core.baselines.MSQueue",
    "segmented": "repro.core.baselines.SegmentedQueue",
    "mutex": "repro.core.baselines.MutexQueue",
}


def make_queue(kind: str, **kwargs):
    from repro.core.cmp import CMPQueue

    if kind == "cmp":
        return CMPQueue(**kwargs)
    if kind == "ms_hp":
        return MSQueue(**kwargs)
    if kind == "segmented":
        return SegmentedQueue(**kwargs)
    if kind == "mutex":
        return MutexQueue(**kwargs)
    raise ValueError(f"unknown queue kind {kind!r}; one of {sorted(ALL_QUEUES)}")
