"""Protection-window sizing and invariant math (paper §3.1).

    W = max(MIN_WINDOW, OPS x R)

where OPS is the expected dequeue rate (ops/s) and R the resilience — the
maximum tolerated stall of any consumer, in seconds.  Memory retained by the
window is bounded by ``W x node_size`` regardless of queue capacity; a stalled
or crashed participant can delay reclamation of at most W nodes and can never
block progress (paper's bounded-reclamation guarantee).

The same formula sizes every CMP embodiment in this framework:

* host data-pipeline queue: OPS = batches/s consumed by the train loop,
  R = tolerated producer/consumer stall (preemption, GC pause),
* paged KV-cache block pool: OPS = decode steps/s, R = max request-preemption
  latency before its blocks may be recycled,
* async checkpoint buffers: OPS = checkpoint events/s, R = max writer lag.
"""

from __future__ import annotations

MIN_WINDOW = 64


def compute_window(ops_per_sec: float, resilience_s: float, min_window: int = MIN_WINDOW) -> int:
    """W = max(MIN_WINDOW, OPS x R), rounded up to an integer cycle count."""
    if ops_per_sec < 0 or resilience_s < 0:
        raise ValueError("ops_per_sec and resilience_s must be non-negative")
    w = int(ops_per_sec * resilience_s + 0.5)
    return max(int(min_window), w)


def retained_bytes(window: int, node_size_bytes: int) -> int:
    """Upper bound on memory retained by the protection window."""
    return int(window) * int(node_size_bytes)


def max_reclaim_delay_cycles(window: int, gc_period: int) -> int:
    """A CLAIMED node is recycled within at most W + N dequeue cycles
    (window plus the conditional-reclamation trigger period)."""
    return int(window) + int(gc_period)
