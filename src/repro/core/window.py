"""Deprecated shim — the window arithmetic lives in :mod:`repro.core.domain`
(the unified protection-domain core, DESIGN.md §1). Import from there."""

from __future__ import annotations

from repro.core.domain import (  # noqa: F401  (re-exports)
    MIN_WINDOW,
    compute_window,
    max_reclaim_delay_cycles,
    retained_bytes,
)

__all__ = ["MIN_WINDOW", "compute_window", "max_reclaim_delay_cycles",
           "retained_bytes"]
