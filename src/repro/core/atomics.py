"""Atomic primitives for the host-side (shared-memory) queue implementations.

CPython has no native CAS on arbitrary fields.  ``AtomicCell`` emulates one
atomic machine word with a per-cell lock: a CAS on a cell contends only with
other operations on the *same* cell, which structurally mirrors cache-line
contention on real hardware.  Plain loads/stores are GIL-atomic and lock-free.

Every atomic operation is counted per-thread so benchmarks can report the
paper's scheduler-independent metric (atomic ops / queue operation: CMP claims
3-5 enq, 4-9 deq) and a *chaos hook* may be installed to inject delays or
yields at atomic boundaries for interleaving fuzz tests.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Optional

import numpy as np

# ---------------------------------------------------------------------------
# instrumentation
# ---------------------------------------------------------------------------

_tls = threading.local()

# Optional callable invoked before every atomic op: hook(kind: str) -> None.
_chaos_hook: Optional[Callable[[str], None]] = None


def set_chaos_hook(hook: Optional[Callable[[str], None]]) -> None:
    global _chaos_hook
    _chaos_hook = hook


def reset_op_counts() -> None:
    _tls.ops = {}


def op_counts() -> dict:
    """Per-thread atomic-op counts since last reset (for the calling thread)."""
    return dict(getattr(_tls, "ops", {}))


def total_ops() -> int:
    return sum(getattr(_tls, "ops", {}).values())


def _count(kind: str) -> None:
    if _chaos_hook is not None:
        _chaos_hook(kind)
    ops = getattr(_tls, "ops", None)
    if ops is None:
        ops = {}
        _tls.ops = ops
    ops[kind] = ops.get(kind, 0) + 1


# ---------------------------------------------------------------------------
# atomic cell
# ---------------------------------------------------------------------------


class AtomicCell:
    """One atomic variable (pointer- or integer-valued)."""

    __slots__ = ("_v", "_lock")

    def __init__(self, value: Any = None):
        self._v = value
        self._lock = threading.Lock()

    # Loads/stores are single bytecode ops under the GIL -> atomic.
    def load(self) -> Any:
        _count("load")
        return self._v

    def store(self, value: Any) -> None:
        _count("store")
        self._v = value

    def cas(self, expected: Any, new: Any) -> bool:
        """Compare-and-swap by identity (pointers) or equality (ints)."""
        _count("cas")
        with self._lock:
            cur = self._v
            ok = cur is expected or cur == expected
            if ok:
                self._v = new
            return ok

    def fetch_inc(self) -> int:
        """Atomically increment; returns the *new* value (paper: INCREMENT)."""
        _count("faa")
        with self._lock:
            self._v += 1
            return self._v

    def fetch_add(self, delta: int) -> int:
        """Atomically add; returns the *old* value."""
        _count("faa")
        with self._lock:
            old = self._v
            self._v = old + delta
            return old

    def fetch_max(self, value: int) -> int:
        """Monotone max-publish (CMP Phase 5 boundary update). Counted as its
        own ``"max"`` kind so the paper's op-kind breakdown separates the
        boundary publish from true compare-and-swaps."""
        _count("max")
        with self._lock:
            if value > self._v:
                self._v = value
            return self._v


# ---------------------------------------------------------------------------
# atomic array
# ---------------------------------------------------------------------------


class AtomicArray:
    """``n`` int64 atomic words backed by one numpy array under striped locks.

    Scalar ops mirror :class:`AtomicCell` per index and contend only on the
    stripe covering that index. Range ops sweep the covering stripes — each
    stripe's segment is transformed in one critical section — and are counted
    as ONE atomic op of their kind: a fused batch RMW is a single coordination
    event whose cost is shared by the whole range, so dividing total ops by
    items yields the amortized (fractional) per-item atomics the batched
    benchmarks report (DESIGN.md §12).

    Atomicity granularity: scalar ops and single-stripe ranges are atomic; a
    multi-stripe range op is atomic per stripe, not as a whole. Per-index
    exactly-once arbitration (the AVAILABLE -> CLAIMED claim/rescue race) only
    needs per-index atomicity, which striping delivers with room to spare.
    """

    __slots__ = ("_a", "_locks", "_stripe")

    def __init__(self, n: int, init: int = 0, stripes: Optional[int] = None):
        n = int(n)
        self._a = np.full(n, init, dtype=np.int64)
        if stripes is None:
            stripes = max(1, min(8, n // 512))
        stripes = max(1, min(int(stripes), n)) if n else 1
        self._stripe = -(-n // stripes) if n else 1  # indices per stripe (ceil)
        self._locks = [threading.Lock() for _ in range(stripes)]

    def __len__(self) -> int:
        return len(self._a)

    def _spans(self, lo: int, hi: int):
        """Yield (lock, a, b) covering [lo, hi) one stripe at a time."""
        w = self._stripe
        a = lo
        while a < hi:
            s = a // w
            b = min(hi, (s + 1) * w)
            yield self._locks[s], a, b
            a = b

    # -- scalar ops (one counted atomic each) ---------------------------
    def load(self, i: int) -> int:
        _count("load")
        return int(self._a[i])

    def store(self, i: int, value: int) -> None:
        _count("store")
        self._a[i] = value

    def cas(self, i: int, expected: int, new: int) -> bool:
        _count("cas")
        with self._locks[i // self._stripe]:
            if self._a[i] == expected:
                self._a[i] = new
                return True
            return False

    def fetch_add(self, i: int, delta: int) -> int:
        """Atomically add at index ``i``; returns the *old* value."""
        _count("faa")
        with self._locks[i // self._stripe]:
            old = int(self._a[i])
            self._a[i] = old + delta
            return old

    def fetch_max(self, i: int, value: int) -> int:
        _count("max")
        with self._locks[i // self._stripe]:
            if value > self._a[i]:
                self._a[i] = value
            return int(self._a[i])

    # -- range ops (one counted atomic per call) ------------------------
    def fill(self, lo: int, hi: int, value: int) -> None:
        """Store ``value`` into every index of [lo, hi)."""
        _count("store")
        for lock, a, b in self._spans(lo, hi):
            with lock:
                self._a[a:b] = value

    def load_range(self, lo: int, hi: int):
        """Snapshot of [lo, hi) (per-stripe consistent)."""
        _count("load")
        out = np.empty(hi - lo, dtype=np.int64)
        for lock, a, b in self._spans(lo, hi):
            with lock:
                out[a - lo:b - lo] = self._a[a:b]
        return out

    def exchange_where(self, lo: int, hi: int, expected: int, new: int):
        """Vectorized multi-CAS: for every index of [lo, hi) holding
        ``expected``, install ``new``. Returns the per-index success mask
        (numpy bool array of length hi-lo). Per index this is exactly one
        CAS — two racing exchanges can never both win the same index."""
        _count("cas")
        won = np.zeros(hi - lo, dtype=bool)
        for lock, a, b in self._spans(lo, hi):
            with lock:
                seg = self._a[a:b]
                m = seg == expected
                seg[m] = new
                won[a - lo:b - lo] = m
        return won

    def count_equal(self, lo: int, hi: int, value: int) -> int:
        """Number of indices in [lo, hi) currently holding ``value``."""
        _count("load")
        n = 0
        for lock, a, b in self._spans(lo, hi):
            with lock:
                n += int((self._a[a:b] == value).sum())
        return n


def cpu_pause() -> None:
    """Paper's CPU_PAUSE: yield the core briefly under contention."""
    _count("pause")
    # time.sleep(0) releases the GIL, the closest analogue to `pause`.
    import time

    time.sleep(0)
