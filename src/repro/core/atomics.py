"""Atomic primitives for the host-side (shared-memory) queue implementations.

CPython has no native CAS on arbitrary fields.  ``AtomicCell`` emulates one
atomic machine word with a per-cell lock: a CAS on a cell contends only with
other operations on the *same* cell, which structurally mirrors cache-line
contention on real hardware.  Plain loads/stores are GIL-atomic and lock-free.

Every atomic operation is counted per-thread so benchmarks can report the
paper's scheduler-independent metric (atomic ops / queue operation: CMP claims
3-5 enq, 4-9 deq) and a *chaos hook* may be installed to inject delays or
yields at atomic boundaries for interleaving fuzz tests.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Optional

# ---------------------------------------------------------------------------
# instrumentation
# ---------------------------------------------------------------------------

_tls = threading.local()

# Optional callable invoked before every atomic op: hook(kind: str) -> None.
_chaos_hook: Optional[Callable[[str], None]] = None


def set_chaos_hook(hook: Optional[Callable[[str], None]]) -> None:
    global _chaos_hook
    _chaos_hook = hook


def reset_op_counts() -> None:
    _tls.ops = {}


def op_counts() -> dict:
    """Per-thread atomic-op counts since last reset (for the calling thread)."""
    return dict(getattr(_tls, "ops", {}))


def total_ops() -> int:
    return sum(getattr(_tls, "ops", {}).values())


def _count(kind: str) -> None:
    if _chaos_hook is not None:
        _chaos_hook(kind)
    ops = getattr(_tls, "ops", None)
    if ops is None:
        ops = {}
        _tls.ops = ops
    ops[kind] = ops.get(kind, 0) + 1


# ---------------------------------------------------------------------------
# atomic cell
# ---------------------------------------------------------------------------


class AtomicCell:
    """One atomic variable (pointer- or integer-valued)."""

    __slots__ = ("_v", "_lock")

    def __init__(self, value: Any = None):
        self._v = value
        self._lock = threading.Lock()

    # Loads/stores are single bytecode ops under the GIL -> atomic.
    def load(self) -> Any:
        _count("load")
        return self._v

    def store(self, value: Any) -> None:
        _count("store")
        self._v = value

    def cas(self, expected: Any, new: Any) -> bool:
        """Compare-and-swap by identity (pointers) or equality (ints)."""
        _count("cas")
        with self._lock:
            cur = self._v
            ok = cur is expected or cur == expected
            if ok:
                self._v = new
            return ok

    def fetch_inc(self) -> int:
        """Atomically increment; returns the *new* value (paper: INCREMENT)."""
        _count("faa")
        with self._lock:
            self._v += 1
            return self._v

    def fetch_add(self, delta: int) -> int:
        """Atomically add; returns the *old* value."""
        _count("faa")
        with self._lock:
            old = self._v
            self._v = old + delta
            return old

    def fetch_max(self, value: int) -> int:
        """Monotone max-publish (CMP Phase 5 boundary update)."""
        _count("cas")
        with self._lock:
            if value > self._v:
                self._v = value
            return self._v


def cpu_pause() -> None:
    """Paper's CPU_PAUSE: yield the core briefly under contention."""
    _count("pause")
    # time.sleep(0) releases the GIL, the closest analogue to `pause`.
    import time

    time.sleep(0)
