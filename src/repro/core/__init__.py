"""CMP core: the paper's contribution.

Host side (faithful shared-memory reproduction):
  - :class:`repro.core.cmp.CMPQueue` — Algorithms 1, 3, 4.
  - :mod:`repro.core.baselines` — M&S+hazard-pointers, segmented, mutex.

Device side (TPU-native adaptation, DESIGN.md §2):
  - :mod:`repro.core.slotpool` — cyclic slot pool with window reclamation.
"""

from repro.core.cmp import AVAILABLE, CLAIMED, CMPQueue
from repro.core.window import compute_window

__all__ = ["CMPQueue", "AVAILABLE", "CLAIMED", "compute_window"]
