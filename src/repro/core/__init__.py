"""CMP core: the paper's contribution.

Unified protection domain (single source of truth, DESIGN.md §1):
  - :mod:`repro.core.domain` — state constants, window arithmetic, monotone
    boundary publish, reclamation predicates, quiesced invariant checkers.

Host side (faithful shared-memory reproduction):
  - :class:`repro.core.cmp.CMPQueue` — Algorithms 1, 3, 4 + batched ops.
  - :mod:`repro.core.baselines` — M&S+hazard-pointers, segmented, mutex.

Device side (TPU-native adaptation, DESIGN.md §2):
  - :mod:`repro.core.slotpool` — cyclic slot pool with window reclamation.
"""

from repro.core.cmp import CMPQueue
from repro.core.domain import AVAILABLE, CLAIMED, FREE, compute_window

__all__ = ["CMPQueue", "FREE", "AVAILABLE", "CLAIMED", "compute_window"]
