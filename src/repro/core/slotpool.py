"""Device-side CMP: a cyclic slot pool as a pure-functional JAX structure.

This is the TPU-native embodiment of the unified protection domain
(:mod:`repro.core.domain`, DESIGN.md §2) — state constants, window math and
both reclamation predicates are imported from there, so the host queue and
this pool provably share one protocol. TPU SPMD has no CAS and no intra-step
races, so the paper's *claim CAS* becomes a deterministic earliest-cycle
selection computed by the tiled Pallas kernel (:mod:`repro.kernels.cmp_claim`
via :mod:`repro.kernels.ops`), while everything else carries over exactly:

* three-state lifecycle  FREE -> AVAILABLE -> CLAIMED -> (window) -> FREE,
* immutable monotone ``cycle`` assigned when a slot becomes AVAILABLE,
* monotone ``deque_cycle`` published by claims (fetch-max, coordination-free),
* reclamation predicate  (state == CLAIMED) & (cycle < deque_cycle - W).

Concurrency on device exists *between* asynchronous actors (decode steps in
flight, host prefetch, checkpoint writers); the window invariant — not CAS —
is what makes reuse safe there, exactly the paper's argument.

Two reclamation predicates are provided (both defined in the domain core):

* ``reclaim``         — the paper's: enqueue-cycle vs window (FIFO lifetimes:
                        MoE capacity slots, microbatch buffers).
* ``reclaim_retired`` — generalized for non-FIFO lifetimes (paged KV blocks):
                        the window counts from the *retire* cycle, preserving
                        the guarantee that any actor which observed the slot
                        live gets >= W cycles of grace. Documented adaptation.

All ops are fixed-shape, jittable, vmappable and shardable; invalid lanes are
signalled with id == num_slots and dropped by scatters (mode='drop').
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import domain
from repro.core.domain import AVAILABLE, CLAIMED, FREE

_INT_MAX = jnp.iinfo(jnp.int32).max


class SlotPool(NamedTuple):
    state: jax.Array        # [N] int32 in {FREE, AVAILABLE, CLAIMED}
    cycle: jax.Array        # [N] int32 — cycle at AVAILABLE-transition (immutable until realloc)
    retire_cycle: jax.Array  # [N] int32 — deque_cycle observed at claim
    enq_cycle: jax.Array    # []  int32 — global monotone enqueue counter
    deque_cycle: jax.Array  # []  int32 — highest claimed cycle (monotone publish)

    @property
    def num_slots(self) -> int:
        return self.state.shape[-1]


def make(num_slots: int) -> SlotPool:
    z = jnp.zeros((num_slots,), jnp.int32)
    return SlotPool(state=z, cycle=z, retire_cycle=z,
                    enq_cycle=jnp.int32(0), deque_cycle=jnp.int32(0))


# ---------------------------------------------------------------------------
# produce: FREE -> AVAILABLE (enqueue / block allocation)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=1)
def produce(pool: SlotPool, k: int) -> Tuple[SlotPool, jax.Array, jax.Array]:
    """Move up to ``k`` FREE slots to AVAILABLE, assigning fresh cycles.

    Returns (pool', ids[k], valid[k]). Lowest-index-first selection (the pool
    is type-stable: a slot id permanently names the same buffer).
    """
    n = pool.num_slots
    key = jnp.where(pool.state == FREE, jnp.arange(n, dtype=jnp.int32), _INT_MAX)
    neg, ids = jax.lax.top_k(-key, min(k, n))
    if k > n:  # over-ask: pad with invalid lanes
        neg = jnp.concatenate([neg, jnp.full((k - n,), -_INT_MAX, neg.dtype)])
        ids = jnp.concatenate([ids, jnp.full((k - n,), n, ids.dtype)])
    valid = neg != -_INT_MAX
    ids = jnp.where(valid, ids, n).astype(jnp.int32)  # n => dropped by scatter
    # Paper Phase 1: each produced slot gets the next monotone cycle.
    new_cycles = pool.enq_cycle + jnp.cumsum(valid.astype(jnp.int32))
    state = pool.state.at[ids].set(AVAILABLE, mode="drop")
    cycle = pool.cycle.at[ids].set(new_cycles, mode="drop")
    enq_cycle = pool.enq_cycle + jnp.sum(valid.astype(jnp.int32))
    return pool._replace(state=state, cycle=cycle, enq_cycle=enq_cycle), ids, valid


# ---------------------------------------------------------------------------
# claim: AVAILABLE -> CLAIMED (dequeue / block release)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=1)
def claim(pool: SlotPool, k: int) -> Tuple[SlotPool, jax.Array, jax.Array]:
    """Claim up to ``k`` earliest-cycle AVAILABLE slots (strict FIFO).

    The earliest-claim property (paper §3.7 FIFO invariant 3) is realized by
    the tiled Pallas claim kernel (block-local k-way min + cross-block merge,
    :func:`repro.kernels.ops.claim`), which fuses the selection with the
    AVAILABLE -> CLAIMED transition; ``deque_cycle`` is then advanced by the
    domain's monotone max-publish exactly as in dequeue Phase 5.
    """
    from repro.kernels import ops as kops  # deferred: kernels build on core

    n = pool.num_slots
    state, ids = kops.claim(pool.state, pool.cycle, k=k)
    valid = ids < n
    claimed_cycles = jnp.where(valid, pool.cycle[jnp.clip(ids, 0, n - 1)], 0)
    claimed_max = jnp.max(claimed_cycles).astype(jnp.int32)
    deque_cycle = domain.publish_boundary(pool.deque_cycle, claimed_max)
    retire = pool.retire_cycle.at[ids].set(deque_cycle, mode="drop")
    return pool._replace(state=state, retire_cycle=retire, deque_cycle=deque_cycle), ids, valid


@jax.jit
def claim_ids(pool: SlotPool, ids: jax.Array, valid: jax.Array) -> SlotPool:
    """Claim *specific* slots (e.g. a finishing request retiring its KV
    blocks). Invalid lanes must carry id == num_slots."""
    ids = jnp.where(valid, ids, pool.num_slots).astype(jnp.int32)
    state = pool.state.at[ids].set(CLAIMED, mode="drop")
    retire = pool.retire_cycle.at[ids].set(pool.deque_cycle, mode="drop")
    claimed_max = jnp.max(jnp.where(valid, pool.cycle[jnp.clip(ids, 0, pool.num_slots - 1)], 0))
    deque_cycle = domain.publish_boundary(pool.deque_cycle, claimed_max)
    return pool._replace(state=state, retire_cycle=retire, deque_cycle=deque_cycle)


# ---------------------------------------------------------------------------
# boundary publish + reclamation (domain predicates)
# ---------------------------------------------------------------------------


@jax.jit
def advance(pool: SlotPool, observed_cycle: jax.Array) -> SlotPool:
    """Unilateral monotone boundary publish (paper dequeue Phase 5)."""
    return pool._replace(
        deque_cycle=domain.publish_boundary(pool.deque_cycle, observed_cycle))


@functools.partial(jax.jit, static_argnums=1)
def reclaim(pool: SlotPool, window: int) -> Tuple[SlotPool, jax.Array]:
    """Paper §3.6 predicate (domain.reclaim_enqueue_mask):
    (state == CLAIMED) & (cycle < deque_cycle - W).

    Returns (pool', num_reclaimed). Coordination-free: a pure function of
    locally observed state; AVAILABLE slots are absolutely protected.
    """
    mask = domain.reclaim_enqueue_mask(pool.state, pool.cycle,
                                       pool.deque_cycle, window)
    state = jnp.where(mask, FREE, pool.state)
    return pool._replace(state=state), jnp.sum(mask.astype(jnp.int32))


@functools.partial(jax.jit, static_argnums=1)
def reclaim_retired(pool: SlotPool, window: int) -> Tuple[SlotPool, jax.Array]:
    """Generalized predicate for non-FIFO lifetimes (paged KV blocks,
    domain.reclaim_retired_mask): (state == CLAIMED) & (retire_cycle <
    deque_cycle - W)."""
    mask = domain.reclaim_retired_mask(pool.state, pool.retire_cycle,
                                       pool.deque_cycle, window)
    state = jnp.where(mask, FREE, pool.state)
    return pool._replace(state=state), jnp.sum(mask.astype(jnp.int32))


@functools.partial(jax.jit, static_argnums=(1, 2))
def produce_with_reclaim(pool: SlotPool, k: int, window: int):
    """Paper Alg 1 Phase 1: allocation failure triggers immediate reclamation
    and a retry — automatic memory-pressure relief."""
    pool, ids, valid = produce(pool, k)
    need_retry = ~jnp.all(valid)

    def _retry(p):
        p, _ = reclaim_retired(p, window)
        p, ids2, valid2 = produce(p, k)
        return p, ids2, valid2

    return jax.lax.cond(need_retry, _retry, lambda p: (p, ids, valid), pool)


# ---------------------------------------------------------------------------
# diagnostics / invariants (used by hypothesis property tests)
# ---------------------------------------------------------------------------


def counts(pool: SlotPool) -> dict:
    return {
        "free": int(jnp.sum(pool.state == FREE)),
        "available": int(jnp.sum(pool.state == AVAILABLE)),
        "claimed": int(jnp.sum(pool.state == CLAIMED)),
        "enq_cycle": int(pool.enq_cycle),
        "deque_cycle": int(pool.deque_cycle),
    }


def check_invariants(pool: SlotPool, window: int) -> None:
    """Raises AssertionError if any CMP invariant is violated (delegates to
    the domain's quiesced checker shared with the host queue)."""
    domain.check_quiesced(jax.device_get(pool.state),
                          jax.device_get(pool.cycle),
                          int(pool.enq_cycle), int(pool.deque_cycle), window)
