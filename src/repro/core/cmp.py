"""Cyclic Memory Protection (CMP) queue — faithful implementation of the paper's
Algorithms 1 (enqueue), 3 (dequeue) and 4 (coordination-free reclamation).

Properties implemented exactly as in the paper:

* strict global FIFO (append-only linking + cursor minimality + earliest claim),
* unbounded capacity (nodes allocated on demand, recycled via a type-stable pool),
* two-state node lifecycle AVAILABLE -> CLAIMED,
* immutable monotone per-node ``cycle`` assigned at enqueue,
* unilateral monotone publication of ``deque_cycle`` (no handshakes),
* sliding protection window  P = [deque_cycle - W, deque_cycle]  — a node is
  reclaimed iff  (state != AVAILABLE) and (cycle < deque_cycle - W),
* reclamation triggered every N enqueues (cycle % N == 0), single reclaimer at
  a time, batched head advancement, stalled-thread tolerance (a CLAIMED node
  from a dead thread is reclaimed after at most W further dequeue cycles).

The Michael & Scott *helping* mechanism is deliberately absent (paper §3.4):
on observing a stale tail the enqueuer retries with fresh state instead of
CAS-ing the tail forward from a stale observation.
"""

from __future__ import annotations

import threading
from typing import Any, List, Optional

from repro.core.atomics import AtomicCell, cpu_pause
from repro.core.window import compute_window

# Node states.
AVAILABLE = 1
CLAIMED = 2

_RETRY_PAUSE_THRESHOLD = 3  # paper Alg 1 line 17


class Node:
    """Queue node. ``cycle`` is immutable after enqueue-publication; ``next``,
    ``data`` and ``state`` are atomic. Nodes are recycled, never freed (type-
    stable pool), so any stale pointer still references a valid Node."""

    __slots__ = ("cycle", "next", "data", "state")

    def __init__(self):
        self.cycle = 0
        self.next = AtomicCell(None)
        self.data = AtomicCell(None)
        self.state = AtomicCell(CLAIMED)

    def __repr__(self):  # pragma: no cover - debug aid
        return f"<Node cycle={self.cycle} state={self.state._v}>"


class NodePool:
    """Type-stable node pool: a Treiber stack of recycled nodes. Nodes are
    never returned to the OS; pool underflow allocates fresh nodes (unbounded
    capacity). ``next`` is reused as the free-list link."""

    def __init__(self, prealloc: int = 0):
        self._top = AtomicCell(None)
        self.allocated = 0  # total Nodes ever constructed (monotone)
        self._alloc_lock = threading.Lock()
        for _ in range(prealloc):
            self.put(self._fresh())

    def _fresh(self) -> Node:
        with self._alloc_lock:
            self.allocated += 1
        return Node()

    def get(self) -> Node:
        while True:
            top = self._top.load()
            if top is None:
                return self._fresh()
            nxt = top.next.load()
            if self._top.cas(top, nxt):
                top.next.store(None)
                return top

    def put(self, node: Node) -> None:
        while True:
            top = self._top.load()
            node.next.store(top)
            if self._top.cas(top, node):
                return

    def size(self) -> int:
        """O(n) free-list length (diagnostics only)."""
        n, cur = 0, self._top.load()
        while cur is not None:
            n += 1
            cur = cur.next.load()
        return n


class CMPQueue:
    """Lock-free MPMC FIFO queue with Cyclic Memory Protection.

    Args:
      window: protection window W (cycles). If None, derived via
        ``compute_window(ops_per_sec, resilience_s)``.
      reclaim_period: N — reclamation trigger every N enqueues.
      min_batch: MIN_BATCH_SIZE for batched reclamation.
      prealloc: nodes to pre-populate the type-stable pool with.
    """

    def __init__(
        self,
        window: Optional[int] = None,
        *,
        ops_per_sec: float = 1e6,
        resilience_s: float = 0.001,
        reclaim_period: int = 64,
        min_batch: int = 8,
        prealloc: int = 0,
        cursor_to_claimed: bool = True,
    ):
        self.window = int(window) if window is not None else compute_window(ops_per_sec, resilience_s)
        self.reclaim_period = int(reclaim_period)
        self.min_batch = int(min_batch)
        # Beyond-paper fix (EXPERIMENTS.md §Perf host iteration): the paper's
        # Alg 3 Phase 4 advances scan_cursor only to current.next, so when
        # the claimed node is the tail (next == NULL) the cursor stays put
        # and strict-alternation workloads re-walk the whole retained window
        # (O(W) per dequeue, measured 583us at W=1000). Advancing to the
        # claimed node itself preserves cursor minimality (everything at or
        # before it is non-AVAILABLE) and restores O(1). Set False for the
        # paper-faithful behavior.
        self.cursor_to_claimed = bool(cursor_to_claimed)
        self.pool = NodePool(prealloc)

        dummy = self.pool.get()
        dummy.cycle = 0
        dummy.state.store(CLAIMED)  # dummy is never claimable
        self.head = AtomicCell(dummy)
        self.tail = AtomicCell(dummy)
        self.scan_cursor = AtomicCell(dummy)
        self.cycle = AtomicCell(0)        # global enqueue cycle counter
        self.deque_cycle = AtomicCell(0)  # highest claimed cycle (monotone)
        self._reclaiming = AtomicCell(0)  # single-reclaimer guard (try-lock)

        # Diagnostics (non-atomic; approximate under races, exact when quiesced).
        self.stats = {"enq_retries": 0, "deq_scans": 0, "reclaimed": 0, "reclaim_passes": 0}

    # ------------------------------------------------------------------
    # Algorithm 1: lock-free enqueue
    # ------------------------------------------------------------------
    def enqueue(self, data: Any) -> bool:
        if data is None:
            raise ValueError("CMPQueue payloads must be non-None (None marks empty slots)")
        # Phase 1: node allocation and cycle assignment.
        node = self.pool.get()
        node.data.store(data)
        node.next.store(None)
        node.state.store(AVAILABLE)
        cycle = self.cycle.fetch_inc()
        node.cycle = cycle  # immutable from here on

        # Phase 2: lock-free insertion (M&S minus helping).
        retry_count = 0
        while True:
            tail = self.tail.load()
            nxt = tail.next.load()
            if nxt is not None:
                # Tail is stale: retry with fresh state (no helping, §3.4).
                retry_count += 1
                self.stats["enq_retries"] += 1
                if retry_count > _RETRY_PAUSE_THRESHOLD:
                    cpu_pause()
                continue
            if tail.next.cas(None, node):
                # Optional tail advancement; failure is benign.
                self.tail.cas(tail, node)
                break
            retry_count += 1
            self.stats["enq_retries"] += 1

        # Phase 3: conditional reclamation (deterministic modulo policy).
        if cycle % self.reclaim_period == 0:
            self.reclaim()
        return True

    # ------------------------------------------------------------------
    # Algorithm 3: lock-free dequeue
    # ------------------------------------------------------------------
    def dequeue(self) -> Optional[Any]:
        current = self.head.load()  # non-NULL (dummy)
        last_deque_cycle = -1       # force initial cursor load
        last_cursor = current
        cursor_cycle = current.cycle

        # Phases 1+2: scan-cursor load and atomic node claiming.
        while current is not None:
            deque_cycle = self.deque_cycle.load()
            if deque_cycle != last_deque_cycle:
                # Other threads progressed: re-accelerate from the cursor.
                last_deque_cycle = deque_cycle
                current = self.scan_cursor.load()
                last_cursor = current
                cursor_cycle = last_cursor.cycle
            if current.state.cas(AVAILABLE, CLAIMED):
                break
            self.stats["deq_scans"] += 1
            current = current.next.load()

        if current is None:
            return None  # empty dequeue linearizes at cursor reaching null

        # Phase 3: claim data with CAS (guards vs stalled-thread ABA reuse).
        if current.state.load() == AVAILABLE:
            return None  # node was recycled underneath us (we were stalled)
        data = current.data.load()
        if data is None or not current.data.cas(data, None):
            return None

        advance_boundary = True
        # Phase 4: opportunistic scan-cursor advance (pointer+cycle dual check
        # eliminates ABA: cycles are monotone, so a recycled same-address node
        # can never satisfy both conditions).
        sc = self.scan_cursor.load()
        if sc is last_cursor and cursor_cycle == sc.cycle:
            nxt = current.next.load()
            if nxt is None and self.cursor_to_claimed:
                nxt = current  # tail claimed: park cursor on it (see __init__)
            advance_boundary = False
            if nxt is None or self.scan_cursor.cas(last_cursor, nxt):
                advance_boundary = True

        # Phase 5: protection boundary update (monotone max publish).
        if advance_boundary:
            cyc = self.deque_cycle.load()
            while cyc < current.cycle:
                if self.deque_cycle.cas(cyc, current.cycle):
                    break
                cyc = self.deque_cycle.load()

        return data

    # ------------------------------------------------------------------
    # Algorithm 4: coordination-free memory reclamation
    # ------------------------------------------------------------------
    def reclaim(self) -> int:
        """Batched, lock-free reclamation. Returns number of nodes recycled.
        Non-blocking: if another thread is reclaiming, returns immediately."""
        if not self._reclaiming.cas(0, 1):
            return 0
        reclaimed = 0
        try:
            self.stats["reclaim_passes"] += 1
            # Phase 1: protection boundary.
            cycle = self.deque_cycle.load()
            safe_cycle = max(0, cycle - self.window)
            head = self.head.load()
            current = head.next.load()

            while current is not None:
                original_next = current
                new_next = current
                batch: List[Node] = []
                # Phases 2-4: collect a batch of safely reclaimable nodes.
                while current is not None:
                    if current.cycle >= safe_cycle:
                        break  # cycle-based protection (immutable, plain read)
                    if current.state.load() == AVAILABLE:
                        break  # state-based protection
                    batch.append(current)
                    nxt = current.next.load()
                    new_next = nxt
                    current = nxt
                if len(batch) < self.min_batch:
                    break
                # Phase 5: single CAS advances head.next across the batch.
                if head.next.cas(original_next, new_next):
                    for node in batch:
                        # Terminate stale traversals, then recycle.
                        node.next.store(None)
                        node.data.store(None)
                        self.pool.put(node)
                    reclaimed += len(batch)
                else:
                    break  # concurrent modification: abandon, retry later
        finally:
            self._reclaiming.store(0)
        self.stats["reclaimed"] += reclaimed
        return reclaimed

    # ------------------------------------------------------------------
    # diagnostics
    # ------------------------------------------------------------------
    def live_nodes(self) -> int:
        """Nodes currently linked from head (incl. dummy). O(n), diagnostics."""
        n, cur = 0, self.head.load()
        while cur is not None:
            n += 1
            cur = cur.next.load()
        return n

    def snapshot_invariants(self) -> dict:
        """Checked by tests: window safety + cursor minimality (quiesced)."""
        dc = self.deque_cycle.load()
        safe = max(0, dc - self.window)
        head = self.head.load()
        cur = head.next.load()
        min_linked_cycle = None
        while cur is not None:
            if min_linked_cycle is None:
                min_linked_cycle = cur.cycle
            cur = cur.next.load()
        return {
            "deque_cycle": dc,
            "safe_cycle": safe,
            "min_linked_cycle": min_linked_cycle,
            "enq_cycle": self.cycle.load(),
        }
