"""Cyclic Memory Protection (CMP) queue — faithful implementation of the paper's
Algorithms 1 (enqueue), 3 (dequeue) and 4 (coordination-free reclamation).

This is the *host* embodiment of the unified protection domain
(:mod:`repro.core.domain`, DESIGN.md §1-2): state constants, window
arithmetic and the reclamation predicate are imported from there — the device
slot pool and the paged KV pool share the exact same definitions.

Properties implemented exactly as in the paper:

* strict global FIFO (append-only linking + cursor minimality + earliest claim),
* unbounded capacity (nodes allocated on demand, recycled via a type-stable pool),
* two-state node lifecycle AVAILABLE -> CLAIMED,
* immutable monotone per-node ``cycle`` assigned at enqueue,
* unilateral monotone publication of ``deque_cycle`` (no handshakes),
* sliding protection window  P = [deque_cycle - W, deque_cycle]  — a node is
  reclaimed iff  (state != AVAILABLE) and (cycle < deque_cycle - W),
* reclamation triggered every N enqueues (cycle % N == 0), single reclaimer at
  a time, batched head advancement, stalled-thread tolerance (a CLAIMED node
  from a dead thread is reclaimed after at most W further dequeue cycles).

Beyond the paper (DESIGN.md §3): batched ``enqueue_many``/``dequeue_many``
amortize the per-operation atomics — one cycle-range fetch-add and one linked
splice per enqueue batch, one boundary publish and one cursor advance per
dequeue batch — the amortization move bounded-memory designs like wCQ/SCQ use
to earn their throughput.

The Michael & Scott *helping* mechanism is deliberately absent (paper §3.4):
on observing a stale tail the enqueuer retries with fresh state instead of
CAS-ing the tail forward from a stale observation.
"""

from __future__ import annotations

import threading
from typing import Any, Iterable, List, Optional

from repro.core.atomics import AtomicCell, cpu_pause
from repro.core.domain import (
    AVAILABLE,
    CLAIMED,
    compute_window,
    reclaim_enqueue_mask,
    safe_cycle,
)

_RETRY_PAUSE_THRESHOLD = 3  # paper Alg 1 line 17


class Node:
    """Queue node. ``cycle`` is immutable after enqueue-publication; ``next``,
    ``data`` and ``state`` are atomic. Nodes are recycled, never freed (type-
    stable pool), so any stale pointer still references a valid Node."""

    __slots__ = ("cycle", "next", "data", "state")

    def __init__(self):
        self.cycle = 0
        self.next = AtomicCell(None)
        self.data = AtomicCell(None)
        self.state = AtomicCell(CLAIMED)

    def __repr__(self):  # pragma: no cover - debug aid
        return f"<Node cycle={self.cycle} state={self.state._v}>"


class NodePool:
    """Type-stable node pool: a Treiber stack of recycled nodes. Nodes are
    never returned to the OS; pool underflow allocates fresh nodes (unbounded
    capacity). ``next`` is reused as the free-list link.

    The top is a *version-tagged* pointer ``(head, version)`` — the classic
    counted-pointer fix: every successful push/pop installs a fresh tag, so
    a stale observation can never CAS successfully (no ABA), which is what
    makes the multi-node walk of ``get_many`` safe. ``get_many``/``put_many``
    move a whole chain with a single CAS — the free-list half of the
    batched-op amortization (DESIGN.md §3)."""

    def __init__(self, prealloc: int = 0):
        self._top = AtomicCell((None, 0))  # (head node, monotone version)
        self.allocated = 0  # total Nodes ever constructed (monotone)
        self._alloc_lock = threading.Lock()
        for _ in range(prealloc):
            self.put(self._fresh())

    def _fresh(self) -> Node:
        with self._alloc_lock:
            self.allocated += 1
        return Node()

    def get(self) -> Node:
        while True:
            top = self._top.load()
            head, ver = top
            if head is None:
                return self._fresh()
            nxt = head.next.load()
            if self._top.cas(top, (nxt, ver + 1)):
                head.next.store(None)
                return head

    def put(self, node: Node) -> None:
        while True:
            top = self._top.load()
            node.next.store(top[0])
            if self._top.cas(top, (node, top[1] + 1)):
                return

    def get_many(self, n: int) -> List[Node]:
        """Pop up to ``n`` recycled nodes with one CAS per attempt (walk the
        chain, CAS the tagged top past it — the tag makes the walk ABA-safe);
        underflow allocates fresh nodes."""
        got: List[Node] = []
        while len(got) < n:
            top = self._top.load()
            head, ver = top
            if head is None:
                break
            chain: List[Node] = []
            cur: Optional[Node] = head
            while cur is not None and len(chain) < n - len(got):
                chain.append(cur)
                cur = cur.next.load()
            if self._top.cas(top, (cur, ver + 1)):
                for nd in chain:
                    nd.next.store(None)
                got.extend(chain)
        while len(got) < n:
            got.append(self._fresh())
        return got

    def put_many(self, nodes: List[Node]) -> None:
        """Push a privately-linked chain with a single CAS."""
        if not nodes:
            return
        for a, b in zip(nodes, nodes[1:]):
            a.next.store(b)
        while True:
            top = self._top.load()
            nodes[-1].next.store(top[0])
            if self._top.cas(top, (nodes[0], top[1] + 1)):
                return

    def size(self) -> int:
        """O(n) free-list length (diagnostics only)."""
        n, cur = 0, self._top.load()[0]
        while cur is not None:
            n += 1
            cur = cur.next.load()
        return n


class CMPQueue:
    """Lock-free MPMC FIFO queue with Cyclic Memory Protection.

    Args:
      window: protection window W (cycles). If None, derived via
        ``domain.compute_window(ops_per_sec, resilience_s)``.
      reclaim_period: N — reclamation trigger every N enqueues.
      min_batch: MIN_BATCH_SIZE for batched reclamation.
      prealloc: nodes to pre-populate the type-stable pool with.
    """

    def __init__(
        self,
        window: Optional[int] = None,
        *,
        ops_per_sec: float = 1e6,
        resilience_s: float = 0.001,
        reclaim_period: int = 64,
        min_batch: int = 8,
        prealloc: int = 0,
        cursor_to_claimed: bool = True,
    ):
        self.window = int(window) if window is not None else compute_window(ops_per_sec, resilience_s)
        self.reclaim_period = int(reclaim_period)
        self.min_batch = int(min_batch)
        # Beyond-paper fix (DESIGN.md §5): the paper's Alg 3 Phase 4 advances
        # scan_cursor only to current.next, so when the claimed node is the
        # tail (next == NULL) the cursor stays put and strict-alternation
        # workloads re-walk the whole retained window (O(W) per dequeue,
        # measured 583us at W=1000). Advancing to the claimed node itself
        # preserves cursor minimality (everything at or before it is
        # non-AVAILABLE) and restores O(1). Set False for the paper-faithful
        # behavior.
        self.cursor_to_claimed = bool(cursor_to_claimed)
        self.pool = NodePool(prealloc)

        dummy = self.pool.get()
        dummy.cycle = 0
        dummy.state.store(CLAIMED)  # dummy is never claimable
        self.head = AtomicCell(dummy)
        self.tail = AtomicCell(dummy)
        self.scan_cursor = AtomicCell(dummy)
        self.cycle = AtomicCell(0)        # global enqueue cycle counter
        self.deque_cycle = AtomicCell(0)  # highest claimed cycle (monotone)
        self._reclaiming = AtomicCell(0)  # single-reclaimer guard (try-lock)

        # Diagnostics (non-atomic; approximate under races, exact when quiesced).
        self.stats = {"enq_retries": 0, "deq_scans": 0, "reclaimed": 0, "reclaim_passes": 0}

    # ------------------------------------------------------------------
    # Algorithm 1: lock-free enqueue
    # ------------------------------------------------------------------
    def enqueue(self, data: Any) -> bool:
        if data is None:
            raise ValueError("CMPQueue payloads must be non-None (None marks empty slots)")
        # Phase 1: node allocation and cycle assignment.
        node = self.pool.get()
        node.data.store(data)
        node.next.store(None)
        node.state.store(AVAILABLE)
        cycle = self.cycle.fetch_inc()
        node.cycle = cycle  # immutable from here on

        # Phase 2: lock-free insertion (M&S minus helping).
        self._splice(node, node)

        # Phase 3: conditional reclamation (deterministic modulo policy).
        if cycle % self.reclaim_period == 0:
            self.reclaim()
        return True

    def enqueue_many(self, items: Iterable[Any]) -> int:
        """Batched enqueue (DESIGN.md §3): one cycle-range fetch-add and one
        linked splice for the whole batch instead of per item. The batch is
        pre-linked locally, so readers observe it fully formed the instant
        the single tail CAS lands. Returns the number of items enqueued."""
        batch = list(items)
        if not batch:
            return 0
        if any(d is None for d in batch):
            raise ValueError("CMPQueue payloads must be non-None (None marks empty slots)")
        n = len(batch)
        nodes = self.pool.get_many(n)
        # Phase 1 (batched): one fetch-add reserves the cycle range
        # [base+1, base+n]; cycles stay immutable and monotone.
        base = self.cycle.fetch_add(n)
        for i, (node, data) in enumerate(zip(nodes, batch)):
            node.data.store(data)
            node.cycle = base + 1 + i
            node.next.store(nodes[i + 1] if i + 1 < n else None)
            node.state.store(AVAILABLE)

        # Phase 2: one splice publishes the whole chain.
        self._splice(nodes[0], nodes[-1])

        # Phase 3: reclaim once if the range crossed a trigger multiple.
        if (base + n) // self.reclaim_period > base // self.reclaim_period:
            self.reclaim()
        return n

    def _splice(self, first: Node, last: Node) -> None:
        """Lock-free insertion of a pre-linked chain (M&S minus helping)."""
        retry_count = 0
        while True:
            tail = self.tail.load()
            nxt = tail.next.load()
            if nxt is not None:
                # Tail is stale: retry with fresh state (no helping, §3.4).
                retry_count += 1
                self.stats["enq_retries"] += 1
                if retry_count > _RETRY_PAUSE_THRESHOLD:
                    cpu_pause()
                continue
            if tail.next.cas(None, first):
                # Optional tail advancement; failure is benign.
                self.tail.cas(tail, last)
                return
            retry_count += 1
            self.stats["enq_retries"] += 1

    # ------------------------------------------------------------------
    # Algorithm 3: lock-free dequeue
    # ------------------------------------------------------------------
    def dequeue(self) -> Optional[Any]:
        out = self.dequeue_many(1)
        return out[0] if out else None

    def dequeue_many(self, k: int) -> List[Any]:
        """Claim up to ``k`` items in FIFO order. For k == 1 this is exactly
        the paper's Algorithm 3. For k > 1 the per-item work is only the
        claim CASes (Phases 1-3); the scan-cursor advance (Phase 4) and the
        monotone boundary publish (Phase 5) run once for the whole batch
        (DESIGN.md §3)."""
        out: List[Any] = []
        if k <= 0:
            return out
        current = self.head.load()  # non-NULL (dummy)
        last_deque_cycle = -1       # force initial cursor load
        last_cursor = current
        cursor_cycle = current.cycle
        last_claimed: Optional[Node] = None
        max_cycle = -1

        # Phases 1+2: scan-cursor load and atomic node claiming.
        while len(out) < k and current is not None:
            deque_cycle = self.deque_cycle.load()
            if deque_cycle != last_deque_cycle:
                # Other threads progressed: re-accelerate from the cursor.
                last_deque_cycle = deque_cycle
                current = self.scan_cursor.load()
                last_cursor = current
                cursor_cycle = last_cursor.cycle
            if current.state.cas(AVAILABLE, CLAIMED):
                # Phase 3: claim data with CAS (guards vs stalled-thread ABA
                # reuse). A lost race means the node was recycled underneath
                # us while we stalled — its ``next`` is no longer trustworthy,
                # so restart the scan instead of following a stale pointer.
                if (current.state.load() == AVAILABLE
                        or (data := current.data.load()) is None
                        or not current.data.cas(data, None)):
                    last_deque_cycle = -1  # force cursor re-acceleration
                    current = self.head.load()
                    continue
                out.append(data)
                last_claimed = current
                if current.cycle > max_cycle:
                    max_cycle = current.cycle
                if len(out) >= k:
                    break
            else:
                self.stats["deq_scans"] += 1
            current = current.next.load()

        if last_claimed is None:
            return out  # empty dequeue linearizes at cursor reaching null

        advance_boundary = True
        # Phase 4 (once per batch): opportunistic scan-cursor advance
        # (pointer+cycle dual check eliminates ABA: cycles are monotone, so a
        # recycled same-address node can never satisfy both conditions).
        # Everything at or before the last claimed node is non-AVAILABLE, so
        # cursor minimality is preserved.
        sc = self.scan_cursor.load()
        if sc is last_cursor and cursor_cycle == sc.cycle:
            nxt = last_claimed.next.load()
            if nxt is None and self.cursor_to_claimed:
                nxt = last_claimed  # tail claimed: park cursor on it (see __init__)
            advance_boundary = False
            if nxt is None or self.scan_cursor.cas(last_cursor, nxt):
                advance_boundary = True

        # Phase 5 (once per batch): protection boundary update — the domain's
        # monotone max-publish, realized as an atomic fetch-max.
        if advance_boundary:
            self.deque_cycle.fetch_max(max_cycle)

        return out

    # ------------------------------------------------------------------
    # Algorithm 4: coordination-free memory reclamation
    # ------------------------------------------------------------------
    def reclaim(self) -> int:
        """Batched, lock-free reclamation. Returns number of nodes recycled.
        Non-blocking: if another thread is reclaiming, returns immediately."""
        if not self._reclaiming.cas(0, 1):
            return 0
        reclaimed = 0
        try:
            self.stats["reclaim_passes"] += 1
            # Phase 1: protection boundary (domain.safe_cycle).
            dc = self.deque_cycle.load()
            head = self.head.load()
            current = head.next.load()

            while current is not None:
                original_next = current
                new_next = current
                batch: List[Node] = []
                # Phases 2-4: collect a batch of safely reclaimable nodes —
                # the domain predicate (state == CLAIMED) & (cycle < dc - W).
                # The cycle is immutable (plain read); the state load is the
                # atomic half of the check.
                while current is not None:
                    if not reclaim_enqueue_mask(current.state.load(),
                                                current.cycle, dc, self.window):
                        break
                    batch.append(current)
                    nxt = current.next.load()
                    new_next = nxt
                    current = nxt
                if len(batch) < self.min_batch:
                    break
                # Phase 5: single CAS advances head.next across the batch.
                if head.next.cas(original_next, new_next):
                    rescued: List[Any] = []
                    for node in batch:
                        # Beyond-paper fix (DESIGN.md §5): a claimer that was
                        # descheduled between its state CAS and its data CAS
                        # still owns undelivered data here. The paper destroys
                        # it (silent loss under a W-cycle stall); we steal it
                        # with one CAS and re-publish it instead. The claimer's
                        # own data CAS then fails and it rescans — exactly-once
                        # either way, still coordination-free, memory still
                        # bounded (the node is recycled regardless).
                        d = node.data.load()
                        if d is not None and node.data.cas(d, None):
                            rescued.append(d)
                        # Terminate stale traversals, then recycle.
                        node.next.store(None)
                        node.data.store(None)
                    self.pool.put_many(batch)
                    reclaimed += len(batch)
                    if rescued:
                        # Re-enqueue at the tail (the nested reclaim trigger
                        # no-ops on the _reclaiming guard we hold).
                        self.enqueue_many(rescued)
                else:
                    break  # concurrent modification: abandon, retry later
        finally:
            self._reclaiming.store(0)
        self.stats["reclaimed"] += reclaimed
        return reclaimed

    # ------------------------------------------------------------------
    # diagnostics
    # ------------------------------------------------------------------
    def live_nodes(self) -> int:
        """Nodes currently linked from head (incl. dummy). O(n), diagnostics."""
        n, cur = 0, self.head.load()
        while cur is not None:
            n += 1
            cur = cur.next.load()
        return n

    def snapshot_invariants(self) -> dict:
        """Checked by tests: window safety + cursor minimality (quiesced)."""
        dc = self.deque_cycle.load()
        head = self.head.load()
        cur = head.next.load()
        min_linked_cycle = None
        while cur is not None:
            if min_linked_cycle is None:
                min_linked_cycle = cur.cycle
            cur = cur.next.load()
        return {
            "deque_cycle": dc,
            "safe_cycle": safe_cycle(dc, self.window),
            "min_linked_cycle": min_linked_cycle,
            "enq_cycle": self.cycle.load(),
        }

    def check_quiesced(self) -> None:
        """Run the domain's quiesced invariant checker over the linked list
        (the host analogue of ``slotpool.check_invariants``)."""
        from repro.core import domain

        states, cycles = [], []
        cur = self.head.load().next.load()
        while cur is not None:
            states.append(cur.state.load())
            cycles.append(cur.cycle)
            cur = cur.next.load()
        domain.check_quiesced(states, cycles, self.cycle.load(),
                              self.deque_cycle.load(), self.window)
