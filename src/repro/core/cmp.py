"""Cyclic Memory Protection (CMP) queue — faithful implementation of the paper's
Algorithms 1 (enqueue), 3 (dequeue) and 4 (coordination-free reclamation).

This is the *host* embodiment of the unified protection domain
(:mod:`repro.core.domain`, DESIGN.md §1-2): state constants, window
arithmetic and the reclamation predicate are imported from there — the device
slot pool and the paged KV pool share the exact same definitions.

Properties implemented exactly as in the paper:

* strict global FIFO (append-only linking + cursor minimality + earliest claim),
* unbounded capacity (nodes allocated on demand, recycled via a type-stable pool),
* two-state node lifecycle AVAILABLE -> CLAIMED,
* immutable monotone per-node ``cycle`` assigned at enqueue,
* unilateral monotone publication of ``deque_cycle`` (no handshakes),
* sliding protection window  P = [deque_cycle - W, deque_cycle]  — a node is
  reclaimed iff  (state != AVAILABLE) and (cycle < deque_cycle - W),
* reclamation triggered every N enqueues (cycle % N == 0), single reclaimer at
  a time, batched head advancement, stalled-thread tolerance (a CLAIMED node
  from a dead thread is reclaimed after at most W further dequeue cycles).

Beyond the paper (DESIGN.md §3): batched ``enqueue_many``/``dequeue_many``
amortize the per-operation atomics — one cycle-range fetch-add and one linked
splice per enqueue batch, one boundary publish and one cursor advance per
dequeue batch — the amortization move bounded-memory designs like wCQ/SCQ use
to earn their throughput.

The Michael & Scott *helping* mechanism is deliberately absent (paper §3.4):
on observing a stale tail the enqueuer retries with fresh state instead of
CAS-ing the tail forward from a stale observation.
"""

from __future__ import annotations

import threading
from typing import Any, Iterable, List, Optional

from repro.core.atomics import AtomicArray, AtomicCell, cpu_pause
from repro.core.domain import (
    AVAILABLE,
    CLAIMED,
    compute_window,
    reclaim_enqueue_mask,
    safe_cycle,
)

_RETRY_PAUSE_THRESHOLD = 3  # paper Alg 1 line 17


class Node:
    """Queue node. ``cycle`` is immutable after enqueue-publication; ``next``,
    ``data`` and ``state`` are atomic. Nodes are recycled, never freed (type-
    stable pool), so any stale pointer still references a valid Node."""

    __slots__ = ("cycle", "next", "data", "state")

    def __init__(self):
        self.cycle = 0
        self.next = AtomicCell(None)
        self.data = AtomicCell(None)
        self.state = AtomicCell(CLAIMED)

    def __repr__(self):  # pragma: no cover - debug aid
        return f"<Node cycle={self.cycle} state={self.state._v}>"


class BlockNode:
    """A batch segment (DESIGN.md §12): ONE linked-list node carrying ``n``
    items with the contiguous cycle range [base+1, base+n]. This is the
    BlockFIFO/SCQ move applied inside the CMP list — the batch's protection
    state lives in one counted :class:`AtomicArray` instead of ``n`` cells,
    so stamping, claiming and recycling the whole batch are single fused
    array ops.

    Layout of ``ctl`` (length n+1): indices [0, n) hold the per-item state
    (FREE until armed, then AVAILABLE -> CLAIMED, monotone — blocks are never
    recycled, so no ABA is possible through a stale block reference); index
    [n] is the claim cursor, advanced by one fetch-add per dequeue batch.

    ``cycle`` is the LAST item's cycle (base + n): it is the window key — the
    block leaves the protection window only when its newest item does, which
    is conservative and keeps the Phase-4 pointer+cycle dual check valid
    (cycles stay monotone along the chain). ``data`` is written before the
    splice publishes the block and never mutated afterwards, so claim winners
    can read it without a data CAS."""

    __slots__ = ("base", "n", "cycle", "next", "data", "ctl")

    def __init__(self, data: List[Any], base: int, n: int):
        self.base = base
        self.n = n
        self.cycle = base + n  # immutable window key (last item's cycle)
        self.next = AtomicCell(None)
        self.data = data
        self.ctl = AtomicArray(n + 1)  # [0,n): item states; [n]: claim cursor

    def take(self, want: int):
        """Claim up to ``want`` items past the block cursor with one cursor
        fetch-add and one vectorized exchange for the whole run. Returns
        ``(items, hi_cycle, exhausted)`` where ``hi_cycle`` is the highest
        cycle of the attempted range (every index in it is CLAIMED after the
        exchange — by us or by a reclaim rescue — so publishing it is safe).
        ``exhausted`` means the cursor has passed the end of the block."""
        n = self.n
        ctl = self.ctl
        if ctl.load(n) >= n:
            return [], -1, True
        old = ctl.fetch_add(n, want)
        start = old if old < n else n
        end = old + want if old + want < n else n
        if start >= end:
            return [], -1, True
        won = ctl.exchange_where(start, end, AVAILABLE, CLAIMED)
        if won.all():
            items = self.data[start:end]
        else:
            # A reclaim rescue beat us to behind-window holes in the range;
            # deliver only the indices our exchange won (exactly-once).
            items = [d for d, w in zip(self.data[start:end], won) if w]
        return items, self.base + end, end >= n

    def __repr__(self):  # pragma: no cover - debug aid
        return f"<BlockNode base={self.base} n={self.n} cursor={self.ctl._a[self.n]}>"


class NodePool:
    """Type-stable node pool: a Treiber stack of recycled nodes. Nodes are
    never returned to the OS; pool underflow allocates fresh nodes (unbounded
    capacity). ``next`` is reused as the free-list link.

    The top is a *version-tagged* pointer ``(head, version)`` — the classic
    counted-pointer fix: every successful push/pop installs a fresh tag, so
    a stale observation can never CAS successfully (no ABA), which is what
    makes the multi-node walk of ``get_many`` safe. ``get_many``/``put_many``
    move a whole chain with a single CAS — the free-list half of the
    batched-op amortization (DESIGN.md §3)."""

    def __init__(self, prealloc: int = 0):
        self._top = AtomicCell((None, 0))  # (head node, monotone version)
        self.allocated = 0  # total Nodes ever constructed (monotone)
        self._alloc_lock = threading.Lock()
        for _ in range(prealloc):
            self.put(self._fresh())

    def _fresh(self) -> Node:
        with self._alloc_lock:
            self.allocated += 1
        return Node()

    def get(self) -> Node:
        while True:
            top = self._top.load()
            head, ver = top
            if head is None:
                return self._fresh()
            nxt = head.next.load()
            if self._top.cas(top, (nxt, ver + 1)):
                head.next.store(None)
                return head

    def put(self, node: Node) -> None:
        while True:
            top = self._top.load()
            node.next.store(top[0])
            if self._top.cas(top, (node, top[1] + 1)):
                return

    def get_many(self, n: int) -> List[Node]:
        """Pop up to ``n`` recycled nodes with one CAS per attempt (walk the
        chain, CAS the tagged top past it — the tag makes the walk ABA-safe);
        underflow allocates fresh nodes."""
        got: List[Node] = []
        while len(got) < n:
            top = self._top.load()
            head, ver = top
            if head is None:
                break
            chain: List[Node] = []
            cur: Optional[Node] = head
            while cur is not None and len(chain) < n - len(got):
                chain.append(cur)
                cur = cur.next.load()
            if self._top.cas(top, (cur, ver + 1)):
                for nd in chain:
                    nd.next.store(None)
                got.extend(chain)
        while len(got) < n:
            got.append(self._fresh())
        return got

    def put_many(self, nodes: List[Node]) -> None:
        """Push a privately-linked chain with a single CAS."""
        if not nodes:
            return
        for a, b in zip(nodes, nodes[1:]):
            a.next.store(b)
        while True:
            top = self._top.load()
            nodes[-1].next.store(top[0])
            if self._top.cas(top, (nodes[0], top[1] + 1)):
                return

    def size(self) -> int:
        """O(n) free-list length (diagnostics only)."""
        n, cur = 0, self._top.load()[0]
        while cur is not None:
            n += 1
            cur = cur.next.load()
        return n


class CMPQueue:
    """Lock-free MPMC FIFO queue with Cyclic Memory Protection.

    Args:
      window: protection window W (cycles). If None, derived via
        ``domain.compute_window(ops_per_sec, resilience_s)``.
      reclaim_period: N — reclamation trigger every N enqueues.
      min_batch: MIN_BATCH_SIZE for batched reclamation.
      prealloc: nodes to pre-populate the type-stable pool with.
    """

    def __init__(
        self,
        window: Optional[int] = None,
        *,
        ops_per_sec: float = 1e6,
        resilience_s: float = 0.001,
        reclaim_period: int = 64,
        min_batch: int = 8,
        prealloc: int = 0,
        cursor_to_claimed: bool = True,
    ):
        self.window = int(window) if window is not None else compute_window(ops_per_sec, resilience_s)
        self.reclaim_period = int(reclaim_period)
        self.min_batch = int(min_batch)
        # Beyond-paper fix (DESIGN.md §5): the paper's Alg 3 Phase 4 advances
        # scan_cursor only to current.next, so when the claimed node is the
        # tail (next == NULL) the cursor stays put and strict-alternation
        # workloads re-walk the whole retained window (O(W) per dequeue,
        # measured 583us at W=1000). Advancing to the claimed node itself
        # preserves cursor minimality (everything at or before it is
        # non-AVAILABLE) and restores O(1). Set False for the paper-faithful
        # behavior.
        self.cursor_to_claimed = bool(cursor_to_claimed)
        self.pool = NodePool(prealloc)

        dummy = self.pool.get()
        dummy.cycle = 0
        dummy.state.store(CLAIMED)  # dummy is never claimable
        self.head = AtomicCell(dummy)
        self.tail = AtomicCell(dummy)
        self.scan_cursor = AtomicCell(dummy)
        self.cycle = AtomicCell(0)        # global enqueue cycle counter
        self.deque_cycle = AtomicCell(0)  # highest claimed cycle (monotone)
        self._reclaiming = AtomicCell(0)  # single-reclaimer guard (try-lock)

        # Diagnostics (non-atomic; approximate under races, exact when quiesced).
        self.stats = {"enq_retries": 0, "deq_scans": 0, "reclaimed": 0,
                      "reclaim_passes": 0, "reclaim_contended": 0,
                      "rescued": 0}

    # flight-recorder attachment (repro.obs): set externally by a
    # MetricsHub; rescues are rare control events, recorded when attached.
    _obs = None
    _obs_cls = "?"

    # ------------------------------------------------------------------
    # Algorithm 1: lock-free enqueue
    # ------------------------------------------------------------------
    def enqueue(self, data: Any) -> bool:
        if data is None:
            raise ValueError("CMPQueue payloads must be non-None (None marks empty slots)")
        # Phase 1: node allocation and cycle assignment.
        node = self.pool.get()
        node.data.store(data)
        node.next.store(None)
        node.state.store(AVAILABLE)
        cycle = self.cycle.fetch_inc()
        node.cycle = cycle  # immutable from here on

        # Phase 2: lock-free insertion (M&S minus helping).
        self._splice(node, node)

        # Phase 3: conditional reclamation (deterministic modulo policy).
        if cycle % self.reclaim_period == 0:
            self.reclaim()
        return True

    def enqueue_many(self, items: Iterable[Any]) -> int:
        """Vectorized batched enqueue (DESIGN.md §3/§12): one cycle-range
        fetch-add, one striped state fill and one linked splice for the whole
        batch — the batch becomes a single :class:`BlockNode`, so the cost is
        O(1) Python bytecodes and a handful of counted atomics per *batch*,
        not per item. Data and cycles are private until the single tail CAS
        publishes the block fully formed. Returns the number enqueued."""
        batch = list(items)
        if not batch:
            return 0
        if any(d is None for d in batch):
            raise ValueError("CMPQueue payloads must be non-None (None marks empty slots)")
        n = len(batch)
        if n == 1:
            self.enqueue(batch[0])
            return 1
        # Phase 1 (fused): one fetch-add reserves the cycle range
        # [base+1, base+n]; one fill arms every item state.
        base = self.cycle.fetch_add(n)
        block = BlockNode(batch, base, n)
        block.ctl.fill(0, n, AVAILABLE)

        # Phase 2: one splice publishes the whole block.
        self._splice(block, block)

        # Phase 3: reclaim once if the range crossed a trigger multiple.
        if (base + n) // self.reclaim_period > base // self.reclaim_period:
            self.reclaim()
        return n

    def _splice(self, first: Node, last: Node) -> None:
        """Lock-free insertion of a pre-linked chain (M&S minus helping)."""
        retry_count = 0
        while True:
            tail = self.tail.load()
            nxt = tail.next.load()
            if nxt is not None:
                # Tail is stale: retry with fresh state (no helping, §3.4).
                retry_count += 1
                self.stats["enq_retries"] += 1
                if retry_count > _RETRY_PAUSE_THRESHOLD:
                    cpu_pause()
                continue
            if tail.next.cas(None, first):
                # Optional tail advancement; failure is benign.
                self.tail.cas(tail, last)
                return
            retry_count += 1
            self.stats["enq_retries"] += 1

    # ------------------------------------------------------------------
    # Algorithm 3: lock-free dequeue
    # ------------------------------------------------------------------
    def dequeue(self) -> Optional[Any]:
        out = self.dequeue_many(1)
        return out[0] if out else None

    def dequeue_many(self, k: int) -> List[Any]:
        """Claim up to ``k`` items in FIFO order. For k == 1 this is exactly
        the paper's Algorithm 3. For k > 1 the per-item work is only the
        claim CASes (Phases 1-3); the scan-cursor advance (Phase 4) and the
        monotone boundary publish (Phase 5) run once for the whole batch
        (DESIGN.md §3)."""
        out: List[Any] = []
        if k <= 0:
            return out
        current = self.head.load()  # non-NULL (dummy)
        last_deque_cycle = -1       # force initial cursor load
        last_cursor = current
        cursor_cycle = current.cycle
        last_claimed = None
        max_cycle = -1
        park = None  # partially-consumed block to park the scan cursor on

        # Phases 1+2: scan-cursor load and atomic node claiming.
        while len(out) < k and current is not None:
            deque_cycle = self.deque_cycle.load()
            if deque_cycle != last_deque_cycle:
                # Other threads progressed: re-accelerate from the cursor.
                last_deque_cycle = deque_cycle
                current = self.scan_cursor.load()
                last_cursor = current
                cursor_cycle = last_cursor.cycle
            if type(current) is BlockNode:
                # Vectorized claim: the whole remaining want in one cursor
                # fetch-add + one exchange (Phases 1-3 fused per block run).
                got, hi, exhausted = current.take(k - len(out))
                if got:
                    out.extend(got)
                    last_claimed = current
                    if hi > max_cycle:
                        max_cycle = hi
                else:
                    self.stats["deq_scans"] += 1
                if not exhausted:
                    if len(out) >= k:
                        # Items remain past the block cursor: the scan cursor
                        # parks ON the block (everything claimed or skipped
                        # before it is non-AVAILABLE; its internal cursor
                        # tracks the intra-block position).
                        park = current
                        break
                    # A rescue stole part of our range: retake from the same
                    # block — the cursor advanced, so this terminates.
                    continue
                current = current.next.load()
                continue
            if current.state.cas(AVAILABLE, CLAIMED):
                # Phase 3: claim data with CAS (guards vs stalled-thread ABA
                # reuse). A lost race means the node was recycled underneath
                # us while we stalled — its ``next`` is no longer trustworthy,
                # so restart the scan instead of following a stale pointer.
                if (current.state.load() == AVAILABLE
                        or (data := current.data.load()) is None
                        or not current.data.cas(data, None)):
                    last_deque_cycle = -1  # force cursor re-acceleration
                    current = self.head.load()
                    continue
                out.append(data)
                last_claimed = current
                if current.cycle > max_cycle:
                    max_cycle = current.cycle
                if len(out) >= k:
                    break
            else:
                self.stats["deq_scans"] += 1
            current = current.next.load()

        if last_claimed is None:
            return out  # empty dequeue linearizes at cursor reaching null

        advance_boundary = True
        # Phase 4 (once per batch): opportunistic scan-cursor advance
        # (pointer+cycle dual check eliminates ABA: cycles are monotone, so a
        # recycled same-address node can never satisfy both conditions).
        # Everything at or before the last claimed node is non-AVAILABLE, so
        # cursor minimality is preserved.
        sc = self.scan_cursor.load()
        if sc is last_cursor and cursor_cycle == sc.cycle:
            if park is not None:
                nxt = park  # partially-consumed block: cursor points at it
            else:
                nxt = last_claimed.next.load()
                if nxt is None and self.cursor_to_claimed:
                    nxt = last_claimed  # tail claimed: park cursor on it (see __init__)
            advance_boundary = False
            if nxt is None or self.scan_cursor.cas(last_cursor, nxt):
                advance_boundary = True

        # Phase 5 (once per batch): protection boundary update — the domain's
        # monotone max-publish, realized as an atomic fetch-max.
        if advance_boundary:
            self.deque_cycle.fetch_max(max_cycle)

        return out

    # ------------------------------------------------------------------
    # Algorithm 4: coordination-free memory reclamation
    # ------------------------------------------------------------------
    def reclaim(self) -> int:
        """Batched, lock-free reclamation. Returns number of nodes recycled.
        Non-blocking: if another thread is reclaiming, returns immediately."""
        if not self._reclaiming.cas(0, 1):
            # another thread holds the reclaim try-lock: this pass stalls
            # (retried at the next trigger) — the "reclaim stall" gauge
            self.stats["reclaim_contended"] += 1
            return 0
        reclaimed = 0
        try:
            self.stats["reclaim_passes"] += 1
            # Phase 1: protection boundary (domain.safe_cycle).
            dc = self.deque_cycle.load()
            head = self.head.load()
            current = head.next.load()

            rescued: List[Any] = []
            while current is not None:
                original_next = current
                new_next = current
                batch: List[Any] = []
                # Phases 2-4: collect a batch of safely reclaimable nodes —
                # the domain predicate (state == CLAIMED) & (cycle < dc - W).
                # The cycle is immutable (plain read); the state load is the
                # atomic half of the check. Block segments additionally get a
                # hole rescue (see _block_rescue) before the check.
                while current is not None:
                    if type(current) is BlockNode:
                        self._block_rescue(current, dc, rescued)
                        if not self._block_reclaimable(current, dc):
                            break
                    elif not reclaim_enqueue_mask(current.state.load(),
                                                  current.cycle, dc, self.window):
                        break
                    batch.append(current)
                    nxt = current.next.load()
                    new_next = nxt
                    current = nxt
                if len(batch) < self.min_batch:
                    break
                # Phase 5: single CAS advances head.next across the batch.
                if head.next.cas(original_next, new_next):
                    scalars: List[Node] = []
                    for node in batch:
                        if type(node) is BlockNode:
                            # Blocks are never pooled: no ABA is possible
                            # through a stale block reference, and ``data``
                            # must stay readable for a claimer racing the
                            # unlink, so the block simply drops to GC.
                            node.next.store(None)
                            reclaimed += node.n
                            continue
                        # Beyond-paper fix (DESIGN.md §5): a claimer that was
                        # descheduled between its state CAS and its data CAS
                        # still owns undelivered data here. The paper destroys
                        # it (silent loss under a W-cycle stall); we steal it
                        # with one CAS and re-publish it instead. The claimer's
                        # own data CAS then fails and it rescans — exactly-once
                        # either way, still coordination-free, memory still
                        # bounded (the node is recycled regardless).
                        d = node.data.load()
                        if d is not None and node.data.cas(d, None):
                            rescued.append(d)
                        # Terminate stale traversals, then recycle.
                        node.next.store(None)
                        node.data.store(None)
                        scalars.append(node)
                        reclaimed += 1
                    self.pool.put_many(scalars)
                else:
                    break  # concurrent modification: abandon, retry later
            if rescued:
                # Re-enqueue at the tail regardless of unlink success — block
                # hole rescues happen during collection, so their items are
                # already stolen. (The nested reclaim trigger no-ops on the
                # _reclaiming guard we hold.)
                self.stats["rescued"] += len(rescued)
                if self._obs is not None:
                    self._obs.emit("rescue", self._obs_cls, -1,
                                   arg=len(rescued))
                self.enqueue_many(rescued)
        finally:
            self._reclaiming.store(0)
        self.stats["reclaimed"] += reclaimed
        return reclaimed

    def _block_rescue(self, block: BlockNode, dc: int, rescued: List[Any]) -> None:
        """Steal behind-window AVAILABLE holes below the block's claim cursor
        — claim attempts that stalled between the cursor fetch-add and the
        exchange (the block analogue of the scalar data rescue). One
        vectorized exchange arbitrates against the waking claimer, so each
        hole is delivered exactly once. Backlog items at or past the cursor
        are never touched: AVAILABLE nodes stay absolutely protected."""
        n = block.n
        cursor = block.ctl.load(n)
        if cursor <= 0:
            return
        lim = safe_cycle(dc, self.window) - block.base - 1
        if lim > cursor:
            lim = cursor
        if lim > n:
            lim = n
        if lim <= 0:
            return
        won = block.ctl.exchange_where(0, lim, AVAILABLE, CLAIMED)
        if won.any():
            data = block.data
            rescued.extend(data[i] for i in won.nonzero()[0])

    def _block_reclaimable(self, block: BlockNode, dc: int) -> bool:
        """A block is reclaimable iff its newest cycle left the window and no
        AVAILABLE item remains (states are monotone AVAILABLE -> CLAIMED and
        blocks are never recycled, so once true this stays true — the unlink
        can never race a late claim win)."""
        if block.cycle >= safe_cycle(dc, self.window):
            return False
        return block.ctl.count_equal(0, block.n, AVAILABLE) == 0

    # ------------------------------------------------------------------
    # diagnostics
    # ------------------------------------------------------------------
    def live_nodes(self) -> int:
        """Nodes currently linked from head (incl. dummy). O(n), diagnostics."""
        n, cur = 0, self.head.load()
        while cur is not None:
            n += 1
            cur = cur.next.load()
        return n

    def snapshot_invariants(self) -> dict:
        """Checked by tests: window safety + cursor minimality (quiesced)."""
        dc = self.deque_cycle.load()
        head = self.head.load()
        cur = head.next.load()
        min_linked_cycle = None
        while cur is not None:
            if min_linked_cycle is None:
                min_linked_cycle = (cur.base + 1 if type(cur) is BlockNode
                                    else cur.cycle)
            cur = cur.next.load()
        return {
            "deque_cycle": dc,
            "safe_cycle": safe_cycle(dc, self.window),
            "min_linked_cycle": min_linked_cycle,
            "enq_cycle": self.cycle.load(),
        }

    def check_quiesced(self) -> None:
        """Run the domain's quiesced invariant checker over the linked list
        (the host analogue of ``slotpool.check_invariants``)."""
        from repro.core import domain

        states, cycles = [], []
        cur = self.head.load().next.load()
        while cur is not None:
            if type(cur) is BlockNode:
                # Expand the block into per-item states/cycles so the domain
                # checker sees the same shape as scalar nodes.
                snap = cur.ctl.load_range(0, cur.n)
                states.extend(int(s) for s in snap)
                cycles.extend(range(cur.base + 1, cur.base + 1 + cur.n))
            else:
                states.append(cur.state.load())
                cycles.append(cur.cycle)
            cur = cur.next.load()
        domain.check_quiesced(states, cycles, self.cycle.load(),
                              self.deque_cycle.load(), self.window)
