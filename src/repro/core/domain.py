"""The unified CMP protection domain (DESIGN.md §1) — single source of truth
for the paper's mechanism, shared by every embodiment in this framework.

The paper's central claim is that one simple protocol replaces every
coordination scheme:

  * a three-state slot/node lifecycle  FREE -> AVAILABLE -> CLAIMED,
  * an immutable monotone ``cycle`` assigned at enqueue/produce time,
  * a unilaterally published monotone boundary ``deque_cycle`` (fetch-max,
    no handshakes),
  * a sliding protection window  P = [deque_cycle - W, deque_cycle]: a slot
    is reclaimable iff it is CLAIMED and its cycle fell behind the window.

This module holds that protocol once. The three embodiments layer on it:

  * :mod:`repro.core.cmp`       — host shared-memory queue (Algorithms 1/3/4);
    atomics are CAS/FAA cells, the lifecycle runs AVAILABLE -> CLAIMED on
    linked nodes (FREE is the type-stable pool).
  * :mod:`repro.core.slotpool`  — device slot pool; the claim CAS becomes a
    deterministic earliest-cycle selection, everything else is identical.
  * :class:`repro.serving.kv_cache.PagedKVPool` — paged KV blocks on the slot
    pool with the *retire-cycle* reclamation predicate (non-FIFO lifetimes).

Every function below is substrate-generic: it accepts Python ints (host hot
path — no array-library dispatch cost) and ``jax.numpy`` arrays/tracers
(device hot path — fully jittable) through the same arithmetic.
"""

from __future__ import annotations

from typing import Optional

# ---------------------------------------------------------------------------
# state constants — the lifecycle FREE -> AVAILABLE -> CLAIMED -> (window) -> FREE
# ---------------------------------------------------------------------------

FREE = 0        # reclaimed / never produced (device pools; host: node in NodePool)
AVAILABLE = 1   # produced, holds live data, claimable
CLAIMED = 2    # consumed; protected until the window slides past its cycle

STATE_NAMES = {FREE: "FREE", AVAILABLE: "AVAILABLE", CLAIMED: "CLAIMED"}

# ---------------------------------------------------------------------------
# window arithmetic (paper §3.1) — W = max(MIN_WINDOW, OPS x R)
# ---------------------------------------------------------------------------

MIN_WINDOW = 64


def compute_window(ops_per_sec: float, resilience_s: float,
                   min_window: int = MIN_WINDOW) -> int:
    """W = max(MIN_WINDOW, OPS x R), rounded up to an integer cycle count.

    OPS is the expected dequeue/claim rate (ops/s) and R the resilience — the
    maximum tolerated stall of any participant, in seconds. The same formula
    sizes every embodiment: host data-pipeline queues (OPS = batches/s,
    R = tolerated producer/consumer stall), paged KV pools (OPS = decode
    steps/s, R = max request-preemption latency), async checkpoint buffers
    (OPS = checkpoint events/s, R = max writer lag).
    """
    if ops_per_sec < 0 or resilience_s < 0:
        raise ValueError("ops_per_sec and resilience_s must be non-negative")
    w = int(ops_per_sec * resilience_s + 0.5)
    return max(int(min_window), w)


def retained_bytes(window: int, node_size_bytes: int) -> int:
    """Upper bound on memory retained by the protection window."""
    return int(window) * int(node_size_bytes)


def max_reclaim_delay_cycles(window: int, gc_period: int) -> int:
    """A CLAIMED node is recycled within at most W + N dequeue cycles
    (window plus the conditional-reclamation trigger period)."""
    return int(window) + int(gc_period)


# ---------------------------------------------------------------------------
# protection boundary + reclamation predicates (paper §3.6)
# ---------------------------------------------------------------------------


def safe_cycle(deque_cycle, window):
    """Reclamation boundary max(0, deque_cycle - W).

    Written as ``s * (s > 0)`` so one definition serves Python ints (host)
    and jnp arrays/tracers (device) without an array-library dispatch.
    """
    s = deque_cycle - window
    return s * (s > 0)


def publish_boundary(current, observed):
    """Unilateral monotone max-publish of the protection boundary (dequeue
    Phase 5). Pure-value form for the device embodiment; the host embodiment
    applies the same max through ``AtomicCell.fetch_max``."""
    grow = observed > current
    return current + (observed - current) * grow


def reclaim_enqueue_mask(state, cycle, deque_cycle, window):
    """The paper's reclamation predicate (FIFO lifetimes — queue nodes, MoE
    capacity slots, microbatch buffers):

        reclaimable  iff  (state == CLAIMED) and (cycle < deque_cycle - W)

    AVAILABLE slots are absolutely protected; the window counts from the
    *enqueue* cycle.
    """
    return (state == CLAIMED) & (cycle < safe_cycle(deque_cycle, window))


def reclaim_retired_mask(state, retire_cycle, deque_cycle, window):
    """Generalized predicate for non-FIFO lifetimes (paged KV blocks): the
    window counts from the *retire* cycle (the boundary observed at claim
    time), preserving the guarantee that any actor which observed the slot
    live gets >= W cycles of grace. Documented adaptation (DESIGN.md §2)."""
    return (state == CLAIMED) & (retire_cycle < safe_cycle(deque_cycle, window))


def window_admit(position, window):
    """Bounded-capacity admission: the j-th claim on a resource is admitted
    iff j < W. This is the protection window read as a capacity bound — MoE
    expert capacity slots (drop beyond capacity) and checkpoint write-behind
    buffers (drop beyond writer lag) are both this predicate."""
    return position < window


# ---------------------------------------------------------------------------
# quiesced invariant checkers (shared by tests of every embodiment)
# ---------------------------------------------------------------------------


def check_quiesced(state, cycle, enq_cycle: int, deque_cycle: int,
                   window: int, retire_cycle=None) -> None:
    """Assert the CMP invariants on a quiesced snapshot.

    ``state``/``cycle`` (and optionally ``retire_cycle``) are parallel
    sequences/arrays over slots; scalars are the global counters. Raises
    AssertionError on any violation:

      1. boundary sanity: deque_cycle <= enq_cycle (the boundary can only be
         published from cycles that were actually issued);
      2. AVAILABLE slots carry issued cycles (cycle <= enq_cycle);
      3. live (AVAILABLE) cycles are unique — monotone assignment;
      4. retire monotonicity: retire_cycle <= deque_cycle everywhere.
    """
    import numpy as np

    state = np.asarray(state)
    cycle = np.asarray(cycle)
    dc, eq = int(deque_cycle), int(enq_cycle)
    assert dc <= eq, f"deque_cycle {dc} ran ahead of enq_cycle {eq}"
    avail = state == AVAILABLE
    if avail.any():
        assert cycle[avail].max() <= eq, "AVAILABLE slot carries unissued cycle"
    av_cycles = cycle[avail]
    assert len(set(av_cycles.tolist())) == len(av_cycles), "duplicate live cycles"
    if retire_cycle is not None:
        rc = np.asarray(retire_cycle)
        assert (rc <= dc).all(), "retire_cycle published past the boundary"


def snapshot(state, cycle, enq_cycle: int, deque_cycle: int, window: int,
             min_linked_cycle: Optional[int] = None) -> dict:
    """Uniform diagnostic snapshot used by every embodiment's tests."""
    import numpy as np

    state = np.asarray(state)
    sc = int(safe_cycle(deque_cycle, window))
    return {
        "deque_cycle": int(deque_cycle),
        "enq_cycle": int(enq_cycle),
        "safe_cycle": sc,
        "min_linked_cycle": min_linked_cycle,
        "free": int((state == FREE).sum()),
        "available": int((state == AVAILABLE).sum()),
        "claimed": int((state == CLAIMED).sum()),
    }
