"""AdamW + schedules, implemented directly in JAX (no external deps).

Scale features:
  * optimizer-moment dtype is configurable (bf16 moments for 100B+ models —
    used by the llama4-maverick config);
  * state is a pytree mirroring params, so it inherits the 2-D FSDP x TP
    sharding (ZeRO-3-equivalent partitioned optimizer state);
  * global-norm clipping; cosine schedule with linear warmup.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params, cfg: OptConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state: OptState, cfg: OptConfig
                  ) -> Tuple[Any, OptState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(state.step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = mu32 / bc1
        nhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mu32.astype(mdt), nu32.astype(mdt))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_mu, new_nu), {"grad_norm": gnorm, "lr": lr}
