"""Fault-tolerant training loop.

Scale posture (1000+ nodes):
  * deterministic resume — checkpoint carries (params, opt state, step, data
    frontier, RNG); restart reproduces the exact step sequence;
  * async write-behind checkpoints (never block the step; CMP-bounded lag);
  * straggler mitigation — the CMP data pipeline absorbs slow producers
    (window); slow *steps* are detected by a robust median filter and
    surfaced to the orchestrator (here: logged + counted);
  * elastic re-mesh — restore() takes target shardings, so a job can restart
    on a different mesh shape;
  * optional int8 error-feedback compression on the cross-pod axis.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer as ckpt
from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training import optimizer as O


def make_train_step(cfg: ModelConfig, opt_cfg: O.OptConfig,
                    mesh=None, donate: bool = True) -> Callable:
    """Returns jitted (params, opt_state, batch) -> (params, opt_state, metrics)."""

    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            M.loss_fn, has_aux=True)(params, batch, cfg)
        params, opt_state, opt_m = O.apply_updates(params, grads, opt_state, opt_cfg)
        metrics.update(opt_m)
        return params, opt_state, metrics

    kw: Dict[str, Any] = {}
    if donate:
        kw["donate_argnums"] = (0, 1)
    if mesh is not None:
        from repro.parallel import sharding as S

        def shard_params(p):
            return S.param_shardings(p, mesh)

        # in_shardings resolved lazily at first call via jax.jit auto;
        # callers that want explicit layouts use launch/dryrun.py.
    return jax.jit(step_fn, **kw)


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: O.OptConfig, *,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 ckpt_window: int = 2, seed: int = 0,
                 straggler_factor: float = 3.0):
        self.cfg, self.opt_cfg = cfg, opt_cfg
        self.ckpt_dir, self.ckpt_every = ckpt_dir, ckpt_every
        self.seed = seed
        self.straggler_factor = straggler_factor
        self.step = 0
        self.params = M.init_params(cfg, jax.random.PRNGKey(seed))
        self.opt_state = O.init(self.params, opt_cfg)
        self.train_step = make_train_step(cfg, opt_cfg)
        self.async_ckpt = (ckpt.AsyncCheckpointer(ckpt_dir, window=ckpt_window)
                           if ckpt_dir else None)
        self.stragglers = 0
        self.step_times: list = []
        self.history: list = []

    # ------------------------------------------------------------- recovery
    def try_restore(self, data_pipe=None) -> bool:
        if not self.ckpt_dir:
            return False
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return False
        template = {"params": self.params, "opt_state": self.opt_state,
                    "data_state": data_pipe.state() if data_pipe else {}}
        step, state = ckpt.restore(self.ckpt_dir, template)
        self.params = state["params"]
        self.opt_state = state["opt_state"]
        self.step = step
        self._restored_data_state = state.get("data_state")
        return True

    # ------------------------------------------------------------- main loop
    def fit(self, data_iter, num_steps: int,
            failure_hook: Optional[Callable[[int], None]] = None,
            data_pipe=None) -> Dict[str, Any]:
        """Runs ``num_steps`` more steps. ``failure_hook(step)`` may raise to
        simulate a node failure — the loop checkpoints such that a fresh
        Trainer + try_restore continues exactly."""
        for _ in range(num_steps):
            batch = next(data_iter)
            jb = {"tokens": jnp.asarray(batch["tokens"])}
            if "extra_embeds" in batch:
                jb["extra_embeds"] = jnp.asarray(batch["extra_embeds"])
            if failure_hook is not None:
                failure_hook(self.step)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, jb)
            metrics["loss"].block_until_ready()
            dt = time.perf_counter() - t0
            self._track_straggler(dt)
            self.step += 1
            self.history.append(float(metrics["loss"]))
            if self.async_ckpt and self.step % self.ckpt_every == 0:
                self._save(data_pipe)
        if self.async_ckpt:
            self._save(data_pipe)
            self.async_ckpt.drain()
        return {"final_loss": self.history[-1] if self.history else None,
                "stragglers": self.stragglers,
                "ckpt_dropped": self.async_ckpt.dropped if self.async_ckpt else 0}

    def _save(self, data_pipe=None) -> None:
        state = {"params": self.params, "opt_state": self.opt_state,
                 "data_state": data_pipe.state() if data_pipe else {}}
        self.async_ckpt.submit(self.step, state)

    def _track_straggler(self, dt: float) -> None:
        self.step_times.append(dt)
        if len(self.step_times) >= 8:
            med = sorted(self.step_times[-32:])[len(self.step_times[-32:]) // 2]
            if dt > self.straggler_factor * med:
                self.stragglers += 1
