"""Declarative fabric configuration (DESIGN.md §10).

The paper's thesis is that one mechanism — cycle clock + bounded window —
replaces a zoo of coordination schemes. The public API should read the same
way: standing up the whole serving fabric (class queues, scheduler replicas,
engine group, checkpoint cadence) is *one* frozen config handed to
:meth:`repro.fabric.Fabric.open`, not hand-wired ``QueueClass`` /
``ReplicaSet`` / ``EngineReplicaGroup`` plumbing repeated in every driver.

Everything here is host-only plain data: no jax import, JSON round-trip via
:meth:`FabricConfig.to_json` / :meth:`FabricConfig.from_json` (the same dict
rides checkpoint aux channels, so a fabric restores from its own snapshot
without the caller re-declaring anything).

Validation is eager (``__post_init__``) and actionable: combinations that
the old flag-wired serve.py accepted silently — a cross-class policy with a
single class, a checkpoint cadence with nowhere to write, frontier snapshots
shadowing the params checkpoint — raise :class:`FabricConfigError` naming
the fix.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.control.config import ControlConfig
from repro.obs.recorder import ObsConfig
from repro.sched.tenants import TIERS, group_class_name

_POLICIES = ("strict", "wfq", "fifo", "hier")


class FabricConfigError(ValueError):
    """An invalid or self-contradictory :class:`FabricConfig`."""


@dataclasses.dataclass(frozen=True)
class ClassSpec:
    """One tenant/priority class, declaratively.

    ``slo_ms`` is a per-class admission-latency target (p99, milliseconds):
    telemetry-only for now — :meth:`Fabric.stats` reports measured
    ``admit_p99_ms`` against it under the ``"slo"`` key (groundwork for the
    SLO-aware policy ROADMAP item; no policy behavior changes).
    """

    name: str
    priority: int = 0
    weight: float = 1.0
    admit_window: Optional[int] = None
    slo_ms: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """Tenant-scale knobs (DESIGN.md §16): declare O(10k) tenants, pay for
    the active ones.

    Setting ``tenants=TenantSpec(...)`` on a :class:`FabricConfig` derives
    the class grid — ``num_groups`` groups x 3 tiers (interactive / batch /
    background, the serve.py tier semantics) — and tenants hash onto the
    groups deterministically (FNV-1a with ``salt``; stable across
    resize / fail_host / snapshot-restore). The hot path then costs
    O(active classes): the scheduler's active-set index skips idle groups
    entirely.

    num_tenants: declared tenant population (capacity-planning input and
      the bench's churn universe; the grid size does NOT depend on it).
    num_groups: class-groups tenants hash onto. The real class count is
      ``3 * num_groups`` — bounded no matter how many tenants exist.
    salt: routing-hash salt (re-shuffles tenant->group placement).
    group_window: per-(group, tier) admission window — the window-pressure
      input to overload shedding; None = unbounded (disables pressure
      shedding, quota shedding still applies).
    page_quota: per-tenant KV page quota; None = no quota ledger.
    quota_total: fabric-wide aggregate page cap, carved per transport host
      with the host-first split. Defaults to ``num_pages`` on serving
      fabrics and ``num_groups * page_quota`` on scheduler-only ones.
    admit_pressure: group occupancy fraction (of the summed tier windows)
      beyond which lowest-tier submissions shed with a 429-style reject.
    quota_hosts: ledger host-cap split override; None = ``config.hosts``.
      Pin it when comparing layouts (``--verify-single-host``) so quota
      admission decisions stay identical at hosts=N and hosts=1.
    stats_capacity / stats_top_k: lazy per-tenant stats table bound and
      the top-K-by-backlog emitted in stats()/Prometheus.
    """

    num_tenants: int
    num_groups: int = 32
    salt: int = 0
    group_window: Optional[int] = 512
    page_quota: Optional[int] = None
    quota_total: Optional[int] = None
    admit_pressure: float = 0.85
    quota_hosts: Optional[int] = None
    stats_capacity: int = 1024
    stats_top_k: int = 8


def tenant_grid_classes(spec: TenantSpec) -> Tuple[ClassSpec, ...]:
    """The derived class grid for a tenant fabric: ``num_groups`` groups x
    the 3 standard tiers, group-major, named ``g{gid:03d}:{tier}`` (the
    group rides the class *name*, so every name-keyed path — snapshots,
    wire codec, seats, stats — works unchanged). Same priority/weight/SLO
    shape per tier as :func:`tiered_classes`."""
    tiers = (
        (TIERS[0], 2, 8.0, 50.0),
        (TIERS[1], 1, 3.0, 500.0),
        (TIERS[2], 0, 1.0, None),
    )
    return tuple(
        ClassSpec(group_class_name(g, tier), priority=pr, weight=w,
                  admit_window=spec.group_window, slo_ms=slo)
        for g in range(spec.num_groups)
        for tier, pr, w, slo in tiers)


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """Everything needed to open (or restore) a fabric session.

    Scheduler half (always active):
      classes: the tenant/priority classes (at least one).
      replicas: scheduler/engine replicas to start with.
      hosts: transport hosts the replicas spread over (round-robin,
        ``rid % hosts``). 1 = single-host; >1 requires the sim transport.
      transport: seat-protocol transport — local (in-process, zero-copy) |
        sim (N simulated hosts, serialized wire envelopes, chaos knobs) |
        wire (N real host worker processes over TCP sockets, DESIGN.md
        §15: framed wire codec, batched claim frames, prefetch credit).
      transport_drop / transport_delay / transport_reorder /
      transport_seed: transport chaos — message-drop and in-flight
        delay probabilities, batch reordering (sim only; TCP cannot
        reorder within a connection), and the deterministic seed.
        Order/exactness are transport-chaos-invariant (the seat cursor
        drives delivery); only latency pays.
      transport_rtt_ms: deterministic injected round-trip time charged to
        every seat-protocol op (sim: a sleep per op — the wire bench's
        sim-at-RTT baseline; wire: a server-side response delay that
        pipelined fetches overlap). 0 = no injection.
      transport_credit: wire-transport prefetch credit — fetches kept in
        flight per home shard (1 = synchronous fetch, no look-ahead).
      max_replicas: live-resize ceiling — seats are provisioned per class at
        open (one shard per potential replica), so ``Fabric.resize(n)`` up
        to this count needs no re-shard. Defaults to ``replicas``.
      shards_per_class: CMP shards per class; defaults to ``max_replicas``
        (every replica needs at least one seat per class).
      policy: cross-class drain policy — strict | wfq | fifo.
      queue_window / reclaim_period: each shard's CMPQueue protection
        window and reclaim cadence.
      min_steal: smallest backlog worth a seat steal.
      drain_k: per-replica drain batch size (scheduler-only fabrics).

    Serving half (``arch`` set -> a full engine group; ``None`` -> a
    scheduler-only fabric, e.g. for benchmarks):
      arch/smoke/param_seed: model config + deterministic init.
      params_dir: optional params checkpoint to restore weights from.
      max_batch / num_pages: fabric-wide lane and page budgets, partitioned
        across replicas (and re-partitioned on resize).
      page_size / max_seq / kv_window: paged-KV pool geometry + protection
        window.
      device_admission: route engine admission through the device-resident
        CMP ring (DESIGN.md §12) — ``False`` (host path), ``True`` (force
        the ring; on CPU hosts the jit'd oracle runs in place of the Pallas
        kernel), or ``"auto"`` (ring only when a TPU is attached).

    Checkpoint cadence:
      checkpoint_dir: frontier-snapshot directory (exact-seat resume).
      checkpoint_every_n_steps: write one snapshot via the async
        checkpointer every N ``Fabric.step`` calls — the running fabric's
        bounded recovery point. ``None`` = only on ``close()``.
      checkpoint_window: async writer's bounded retention (CMP window).
    """

    classes: Tuple[ClassSpec, ...] = (ClassSpec("default"),)
    replicas: int = 1
    max_replicas: Optional[int] = None
    shards_per_class: Optional[int] = None
    hosts: int = 1
    transport: str = "local"
    transport_drop: float = 0.0
    transport_delay: float = 0.0
    transport_reorder: bool = False
    transport_seed: int = 0
    transport_rtt_ms: float = 0.0
    transport_credit: int = 4
    policy: str = "strict"
    queue_window: int = 4096
    reclaim_period: int = 32
    min_steal: int = 1
    drain_k: int = 8
    # serving half
    arch: Optional[str] = None
    smoke: bool = True
    param_seed: int = 0
    params_dir: Optional[str] = None
    max_batch: int = 4
    page_size: int = 16
    num_pages: int = 64
    max_seq: int = 128
    kv_window: int = 4
    device_admission: object = False  # False | True | "auto"
    # checkpoint cadence
    checkpoint_dir: Optional[str] = None
    checkpoint_every_n_steps: Optional[int] = None
    checkpoint_window: int = 2
    # observability plane (repro.obs): None = no hub, no recorders, zero
    # overhead; an ObsConfig stands up the fabric-wide MetricsHub + flight
    # recorders (stats_view().obs, Fabric.obs exporters)
    obs: Optional[ObsConfig] = None
    # control plane (repro.control): None = no closed loop (the
    # fabric.control actuation handle still exists for manual typed
    # actions); a ControlConfig arms the SLO-driven autoscaler inside
    # Fabric.step (DESIGN.md §14). Requires obs (its sensor input).
    control: Optional[ControlConfig] = None
    # tenant scale (DESIGN.md §16): None = classes are what you declared;
    # a TenantSpec derives the bounded group x tier class grid, arms
    # hashed tenant routing + O(active) tracking + admission shedding in
    # Fabric, and auto-selects the hierarchical drain policy.
    tenants: Optional[TenantSpec] = None

    def __post_init__(self):
        # normalize: accept any iterable of ClassSpec (or spec dicts), then
        # resolve the replica/seat defaults so validation and JSON output
        # always see concrete numbers
        specs = tuple(c if isinstance(c, ClassSpec) else ClassSpec(**c)
                      for c in self.classes)
        object.__setattr__(self, "classes", specs)
        if isinstance(self.obs, dict):  # JSON round-trip form
            object.__setattr__(self, "obs", ObsConfig(**self.obs))
        if isinstance(self.control, dict):  # JSON round-trip form
            object.__setattr__(self, "control", ControlConfig(**self.control))
        if isinstance(self.tenants, dict):  # JSON round-trip form
            object.__setattr__(self, "tenants", TenantSpec(**self.tenants))
        if self.tenants is not None:
            # Derive the grid. A default classes field is replaced; a
            # snapshot round trip (to_json emits the derived grid) passes
            # the grid back in, which must match; anything else is a
            # contradiction caught by validate().
            if self.classes == (ClassSpec("default"),):
                object.__setattr__(self, "classes",
                                   tenant_grid_classes(self.tenants))
            if self.policy == "strict":
                # strict across 3*G grid classes would starve whole groups;
                # the tenant fabric's native policy is hierarchical WFQ
                object.__setattr__(self, "policy", "hier")
        if self.max_replicas is None:
            object.__setattr__(self, "max_replicas", self.replicas)
        if self.shards_per_class is None:
            object.__setattr__(self, "shards_per_class", self.max_replicas)
        self.validate()

    # ------------------------------------------------------------ validation
    def validate(self) -> None:
        def bad(msg: str) -> None:
            raise FabricConfigError(f"FabricConfig: {msg}")

        if not self.classes:
            bad("declare at least one class (classes=() serves nobody)")
        names = [c.name for c in self.classes]
        if len(set(names)) != len(names):
            bad(f"duplicate class names {names}: every class needs a "
                f"unique name (it is the policy and telemetry key)")
        for c in self.classes:
            if not c.name:
                bad("empty class name")
            if c.weight <= 0:
                bad(f"class {c.name!r}: weight must be > 0 "
                    f"(got {c.weight}); weights are fair-share ratios")
            if c.admit_window is not None and c.admit_window < 1:
                bad(f"class {c.name!r}: admit_window must be >= 1 or None "
                    f"(got {c.admit_window})")
            if c.slo_ms is not None and c.slo_ms <= 0:
                bad(f"class {c.name!r}: slo_ms must be > 0 or None "
                    f"(got {c.slo_ms})")
        if self.policy not in _POLICIES:
            bad(f"unknown policy {self.policy!r}; choose from "
                f"{list(_POLICIES)}")
        if self.tenants is not None:
            t = self.tenants
            if t.num_tenants < 1:
                bad(f"tenants.num_tenants must be >= 1 "
                    f"(got {t.num_tenants})")
            if not (1 <= t.num_groups <= 4096):
                bad(f"tenants.num_groups must be in [1, 4096] "
                    f"(got {t.num_groups}); the class grid is "
                    f"3*num_groups real queues")
            if t.group_window is not None and t.group_window < 1:
                bad(f"tenants.group_window must be >= 1 or None "
                    f"(got {t.group_window})")
            if t.page_quota is not None and t.page_quota < 1:
                bad(f"tenants.page_quota must be >= 1 or None "
                    f"(got {t.page_quota})")
            if t.quota_total is not None and t.page_quota is None:
                bad("tenants.quota_total without page_quota: the aggregate "
                    "cap only exists inside the quota ledger — set "
                    "page_quota or drop quota_total")
            if not (0.0 < t.admit_pressure <= 1.0):
                bad(f"tenants.admit_pressure must be in (0, 1] "
                    f"(got {t.admit_pressure})")
            if t.quota_hosts is not None and t.quota_hosts < 1:
                bad(f"tenants.quota_hosts must be >= 1 or None "
                    f"(got {t.quota_hosts})")
            if t.stats_capacity < 1 or t.stats_top_k < 0:
                bad(f"tenants stats bounds invalid (stats_capacity="
                    f"{t.stats_capacity}, stats_top_k={t.stats_top_k})")
            derived = tenant_grid_classes(t)
            if self.classes != derived:
                bad("tenants=TenantSpec(...) derives the class grid "
                    "(num_groups x 3 tiers) itself — drop the explicit "
                    "classes field (or keep the default) so the grid and "
                    "the tenant routing cannot disagree")
            if self.policy == "strict":
                bad("tenants with policy='strict': strict priority across "
                    "the whole grid starves entire groups — use 'hier' "
                    "(the default with tenants), 'wfq' or 'fifo'")
        if len(self.classes) == 1 and self.policy != "strict":
            bad(f"cross-class policy {self.policy!r} has no effect with the "
                f"single class {names[0]!r}: declare multiple classes "
                f"(serve.py: --multitenant) or drop the policy override")
        if self.replicas < 1:
            bad(f"replicas must be >= 1 (got {self.replicas})")
        if self.max_replicas < self.replicas:
            bad(f"max_replicas={self.max_replicas} < replicas="
                f"{self.replicas}: raise max_replicas (the resize ceiling) "
                f"or start with fewer replicas")
        if self.shards_per_class < self.max_replicas:
            bad(f"shards_per_class={self.shards_per_class} < max_replicas="
                f"{self.max_replicas}: every replica needs at least one "
                f"seat per class — raise shards_per_class or lower "
                f"max_replicas")
        if self.transport not in ("local", "sim", "wire"):
            bad(f"unknown transport {self.transport!r}; choose from "
                f"['local', 'sim', 'wire']")
        if self.hosts < 1:
            bad(f"hosts must be >= 1 (got {self.hosts})")
        if self.transport == "local" and self.hosts != 1:
            bad(f"hosts={self.hosts} with the local transport: the local "
                f"transport is single-host by definition — set "
                f"transport='sim' or 'wire' for multi-host layouts")
        if self.hosts > self.max_replicas:
            bad(f"hosts={self.hosts} > max_replicas={self.max_replicas}: "
                f"a host with no replica drains nothing — raise "
                f"max_replicas or lower hosts")
        if self.transport == "local" and (
                self.transport_drop or self.transport_delay
                or self.transport_reorder or self.transport_rtt_ms):
            bad("transport chaos knobs (transport_drop/delay/reorder/"
                "rtt_ms) require transport='sim' or 'wire': the local "
                "transport has no wire to be lossy on")
        if self.transport == "wire" and self.transport_reorder:
            bad("transport_reorder requires transport='sim': the wire "
                "transport's per-connection TCP framing delivers responses "
                "in order by construction")
        for knob in ("transport_drop", "transport_delay"):
            p = getattr(self, knob)
            if not (0.0 <= p < 1.0):
                bad(f"{knob} must be in [0, 1) (got {p})")
        if not (0.0 <= self.transport_rtt_ms < 10_000.0):
            bad(f"transport_rtt_ms must be in [0, 10000) "
                f"(got {self.transport_rtt_ms})")
        if self.transport_credit < 1:
            bad(f"transport_credit must be >= 1 "
                f"(got {self.transport_credit}); credit is the number of "
                f"fetches kept in flight — 1 means synchronous")
        for field, lo in (("queue_window", 1), ("reclaim_period", 1),
                          ("min_steal", 1), ("drain_k", 1),
                          ("checkpoint_window", 1)):
            if getattr(self, field) < lo:
                bad(f"{field} must be >= {lo} (got {getattr(self, field)})")
        if self.arch is not None:
            if self.max_batch < self.max_replicas:
                bad(f"lane budget max_batch={self.max_batch} cannot give "
                    f"every replica a lane at max_replicas="
                    f"{self.max_replicas}: raise max_batch or lower "
                    f"max_replicas")
            if self.num_pages < 2 * self.max_replicas:
                bad(f"page budget num_pages={self.num_pages} cannot give "
                    f"every replica a scratch page plus one live page at "
                    f"max_replicas={self.max_replicas}: raise num_pages")
            if self.page_size < 1 or self.max_seq < self.page_size:
                bad(f"need max_seq >= page_size >= 1 (got max_seq="
                    f"{self.max_seq}, page_size={self.page_size})")
            if self.kv_window < 1:
                bad(f"kv_window must be >= 1 (got {self.kv_window})")
            if self.device_admission not in (True, False, "auto"):
                bad(f"device_admission must be True, False or 'auto' "
                    f"(got {self.device_admission!r})")
        elif self.device_admission:
            bad("device_admission without arch: a scheduler-only fabric has "
                "no engine admission path — set arch or drop "
                "device_admission")
        elif self.params_dir is not None:
            bad("params_dir without arch: a scheduler-only fabric has no "
                "model params to restore — set arch or drop params_dir")
        if (self.checkpoint_every_n_steps is not None
                and self.checkpoint_every_n_steps < 1):
            bad(f"checkpoint_every_n_steps must be >= 1 or None "
                f"(got {self.checkpoint_every_n_steps})")
        if self.checkpoint_every_n_steps is not None \
                and self.checkpoint_dir is None:
            bad("checkpoint cadence with nowhere to write: set "
                "checkpoint_dir or drop checkpoint_every_n_steps")
        if self.checkpoint_dir is not None \
                and self.checkpoint_dir == self.params_dir:
            bad("checkpoint_dir (frontier snapshots) must differ from "
                "params_dir (model params): a frontier-only step would "
                "shadow the params checkpoint's `latest`")
        if self.obs is not None:
            try:
                self.obs.validate()
            except ValueError as e:
                bad(f"obs: {e}")
        if self.control is not None and self.control.enabled:
            try:
                self.control.validate()
            except ValueError as e:
                bad(f"control: {e}")
            if self.obs is None or not self.obs.enabled:
                bad("control=ControlConfig(...) needs the obs plane for "
                    "its signals (the rolling gauge window): also set "
                    "obs=ObsConfig() — serve.py --autoscale does this "
                    "automatically")
            if self.control.min_replicas > self.replicas:
                bad(f"control.min_replicas={self.control.min_replicas} > "
                    f"replicas={self.replicas}: the shrink floor cannot "
                    f"start above the opening replica count")
            if (self.control.replicas_per_host is not None
                    and self.transport != "sim"):
                bad("control.replicas_per_host (grow-a-host preference) "
                    "requires transport='sim': the local transport is "
                    "single-host by definition")

    # ------------------------------------------------------------------ JSON
    def to_json(self) -> dict:
        """Plain-dict encoding; ``from_json(to_json())`` reproduces the
        config exactly (asserted in tests). This dict rides checkpoint aux
        channels so a fabric restores from its own snapshot."""
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, data: dict) -> "FabricConfig":
        data = dict(data)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - known)
        if unknown:
            raise FabricConfigError(
                f"FabricConfig.from_json: unknown keys {unknown} "
                f"(snapshot from a newer/older build?)")
        if "classes" in data:
            data["classes"] = tuple(
                c if isinstance(c, ClassSpec) else ClassSpec(**c)
                for c in data["classes"])
        return cls(**data)


def tiered_classes(*, background_window: Optional[int] = None,
                   interactive_slo_ms: float = 50.0,
                   batch_slo_ms: float = 500.0) -> Tuple[ClassSpec, ...]:
    """The standard 3-tier tenant set (interactive/batch/background) used by
    serve.py --multitenant, the examples, and the benchmarks: strict-priority
    ranks with 8:3:1 fair-share weights, SLO targets on the latency-sensitive
    tiers, and an optional admission window bounding background in-flight."""
    return (
        ClassSpec("interactive", priority=2, weight=8.0,
                  slo_ms=interactive_slo_ms),
        ClassSpec("batch", priority=1, weight=3.0, slo_ms=batch_slo_ms),
        ClassSpec("background", priority=0, weight=1.0,
                  admit_window=background_window),
    )
