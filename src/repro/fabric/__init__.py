"""`repro.fabric` — one declarative session API over queues, scheduler,
replicas, and serving (DESIGN.md §10).

  - :mod:`repro.fabric.config`  — :class:`FabricConfig` / :class:`ClassSpec`
    (frozen, validated, JSON round-trip) + the standard
    :func:`tiered_classes` tenant set.
  - :mod:`repro.fabric.session` — :class:`Fabric`: ``open`` / ``submit`` /
    ``step`` / ``drain`` / ``stats_view`` / ``snapshot`` / ``restore`` /
    ``resize`` (live elasticity) / ``close``, with an in-loop checkpoint
    cadence for a bounded recovery point, the versioned
    :class:`StatsView` telemetry surface, and the ``fabric.control``
    actuation handle (DESIGN.md §14).
  - :mod:`repro.fabric.stats`   — the frozen, versioned stats schema read
    by the controller, serve.py and the exporters.
"""

from repro.fabric.config import (ClassSpec, FabricConfig, FabricConfigError,
                                 TenantSpec, tenant_grid_classes,
                                 tiered_classes)
from repro.fabric.session import Fabric
from repro.fabric.stats import (SCHEMA_VERSION, ClassStatsView, SloView,
                                StatsView)

__all__ = ["ClassSpec", "ClassStatsView", "Fabric", "FabricConfig",
           "FabricConfigError", "SCHEMA_VERSION", "SloView", "StatsView",
           "TenantSpec", "tenant_grid_classes", "tiered_classes"]

_REMOVED = {
    "compat": "the repro.fabric.compat shim module",
    "open_engine": "compat.open_engine",
    "open_replica_group": "compat.open_replica_group",
    "open_replica_set": "compat.open_replica_set",
}


def __getattr__(name):
    # The PR-4 deprecation shims warned for four PRs and are now gone;
    # fail loudly with the replacement instead of an opaque AttributeError.
    if name in _REMOVED:
        raise AttributeError(
            f"{_REMOVED[name]} was removed in PR 8: construct sessions "
            f"with Fabric.open(FabricConfig(...)) (see DESIGN.md §10)")
    raise AttributeError(f"module 'repro.fabric' has no attribute {name!r}")
