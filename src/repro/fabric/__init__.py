"""`repro.fabric` — one declarative session API over queues, scheduler,
replicas, and serving (DESIGN.md §10).

  - :mod:`repro.fabric.config`  — :class:`FabricConfig` / :class:`ClassSpec`
    (frozen, validated, JSON round-trip) + the standard
    :func:`tiered_classes` tenant set.
  - :mod:`repro.fabric.session` — :class:`Fabric`: ``open`` / ``submit`` /
    ``step`` / ``drain`` / ``stats`` / ``snapshot`` / ``restore`` /
    ``resize`` (live elasticity) / ``close``, with an in-loop checkpoint
    cadence for a bounded recovery point.
  - :mod:`repro.fabric.compat`  — deprecation shims mapping the old
    hand-wired constructors onto the new API.
"""

from repro.fabric.config import (ClassSpec, FabricConfig, FabricConfigError,
                                 tiered_classes)
from repro.fabric.session import Fabric
from repro.fabric import compat  # noqa: F401  (old->new constructor shims)

__all__ = ["ClassSpec", "FabricConfig", "FabricConfigError", "Fabric",
           "compat", "tiered_classes"]
