"""Deprecation shims: the pre-fabric hand-wired constructors, re-expressed
as one :class:`FabricConfig` (DESIGN.md §10 has the old->new map).

Before PR 4, standing up the system meant wiring ``QueueClass`` shards +
``Scheduler``/``ReplicaSet`` + ``Engine``/``EngineReplicaGroup`` by hand in
every driver. Those classes remain the internal layer (import and use them
freely for surgery); these shims cover the old *entry-point* signatures so
existing drivers migrate with a one-line change, and warn so they finish
the migration. Each returns a live :class:`Fabric` session.
"""

from __future__ import annotations

import warnings
from typing import Optional, Sequence, Tuple

from repro.fabric.config import ClassSpec, FabricConfig
from repro.fabric.session import Fabric


def _warn(old: str) -> None:
    warnings.warn(
        f"hand-wiring {old} is deprecated: declare a FabricConfig and call "
        f"Fabric.open (DESIGN.md §10 maps every old argument)",
        DeprecationWarning, stacklevel=3)


def class_specs(classes) -> Tuple[Tuple[ClassSpec, ...], int]:
    """Map live ``QueueClass`` objects to declarative specs; returns the
    specs plus the shard count they were built with."""
    if not classes:
        return (ClassSpec("default"),), 1
    specs = tuple(ClassSpec(qc.name, priority=qc.priority, weight=qc.weight,
                            admit_window=qc.admit_window) for qc in classes)
    return specs, max(len(qc.shards) for qc in classes)


def open_engine(cfg, params, *, classes=None, policy="strict",
                max_batch: int = 4, page_size: int = 16, num_pages: int = 64,
                window: int = 4, max_seq: int = 128) -> Fabric:
    """Old: ``Engine(cfg, params, classes=..., policy=...)`` hand-wired in a
    driver. New: a single-replica serving fabric."""
    _warn("Engine(...)")
    specs, shards = class_specs(classes)
    config = FabricConfig(classes=specs, shards_per_class=shards,
                          policy=policy, arch=cfg.name,
                          max_batch=max_batch, page_size=page_size,
                          num_pages=num_pages, kv_window=window,
                          max_seq=max_seq)
    return Fabric.open(config, params=params, model_cfg=cfg)


def open_replica_group(cfg, params, *, num_replicas: int = 2, classes=None,
                       policy="strict", min_steal: int = 1,
                       max_batch: int = 4, page_size: int = 16,
                       num_pages: int = 64, window: int = 4,
                       max_seq: int = 128) -> Fabric:
    """Old: ``EngineReplicaGroup(cfg, params, num_replicas=...)``. New: a
    serving fabric with ``replicas=N`` (and live ``resize``)."""
    _warn("EngineReplicaGroup(...)")
    specs, shards = class_specs(classes)
    config = FabricConfig(classes=specs, replicas=num_replicas,
                          shards_per_class=max(shards, num_replicas),
                          policy=policy, min_steal=min_steal, arch=cfg.name,
                          max_batch=max_batch, page_size=page_size,
                          num_pages=num_pages, kv_window=window,
                          max_seq=max_seq)
    return Fabric.open(config, params=params, model_cfg=cfg)


def open_replica_set(classes: Sequence, *, num_replicas: int = 1,
                     policy="strict", min_steal: int = 1,
                     queue_window: Optional[int] = None,
                     drain_k: int = 8) -> Fabric:
    """Old: ``ReplicaSet(Scheduler(classes), N)`` hand-wired in a benchmark
    or pipeline. New: a scheduler-only fabric (``arch=None``)."""
    _warn("Scheduler(...) + ReplicaSet(...)")
    specs, shards = class_specs(classes)
    kw = {} if queue_window is None else {"queue_window": queue_window}
    config = FabricConfig(classes=specs,
                          shards_per_class=max(shards, num_replicas),
                          replicas=num_replicas, policy=policy,
                          min_steal=min_steal, drain_k=drain_k, **kw)
    return Fabric.open(config)
