"""The fabric session handle (DESIGN.md §10).

One lifecycle object over the whole stack: ``Fabric.open(config)`` stands up
class queues, scheduler replicas and (when ``config.arch`` is set) the
engine replica group from a single declarative :class:`FabricConfig`;
``submit`` / ``step`` / ``drain`` run it; ``resize`` grows or shrinks the
replica count live (a batch of seat claims + a lane/page budget re-split,
no drain pause); a ``checkpoint_every_n_steps`` cadence writes exact-seat
frontier snapshots through the async checkpointer so a running fabric
always has a bounded recovery point; ``Fabric.restore(dir)`` resumes every
tenant at its exact FIFO seat.

Two modes, one protocol:

  * **serving** (``config.arch`` set) — a full
    :class:`~repro.serving.engine.EngineReplicaGroup`: ``submit`` takes
    token prompts and returns uids, ``step`` returns completed requests.
  * **scheduler-only** (``config.arch is None``) — the class fabric +
    :class:`~repro.sched.ReplicaSet` without engines (benchmarks, chaos
    tests, non-LLM consumers): ``submit`` takes arbitrary payloads and
    returns envelopes, ``step`` returns ``(view, envelope)`` deliveries.

The serving imports (jax, model configs, the engine) are lazy: a
scheduler-only fabric is plain host Python.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Sequence

from repro.control import ControlHandle
from repro.fabric.config import FabricConfig, FabricConfigError
from repro.fabric.stats import (SloView, StatsView, _json_safe,
                                class_view_from_snapshot)
from repro.sched import QueueClass, ReplicaSet, Scheduler, make_transport
from repro.sched.tenants import (TIERS, TenantMap, TenantQuotaLedger,
                                 TenantRouter, TenantStatsTable,
                                 group_class_name)

# Fabric.stats() (the raw-dict alias of stats_view()) warns once per
# process, not once per call site — the alias is a migration aid, not a
# supported surface.
_STATS_DICT_WARNED = False


def _build_classes(config: FabricConfig) -> List[QueueClass]:
    return [
        QueueClass(spec.name, priority=spec.priority, weight=spec.weight,
                   num_shards=config.shards_per_class,
                   admit_window=spec.admit_window,
                   window=config.queue_window,
                   reclaim_period=config.reclaim_period)
        for spec in config.classes]


def _build_transport(config: FabricConfig, codec=None):
    """Config -> seat-protocol transport. Serving fabrics carry Request
    payloads, so the sim transport's wire codec gets the request
    encode/decode hooks (the same pair the frontier checkpoint uses —
    DESIGN.md §11: the checkpoint format is the wire format). Scheduler-
    only fabrics default to the identity codec — cross-host envelopes take
    a plain JSON hop, so payloads must be JSON-stable (a tuple comes back
    a list); callers with richer payloads pass ``codec=(encode, decode)``
    to Fabric.open/from_snapshot/restore."""
    encode = decode = None
    if codec is not None:
        encode, decode = codec
    elif config.arch is not None and config.transport in ("sim", "wire"):
        from repro.serving.engine import request_from_state, request_state
        encode, decode = request_state, request_from_state
    return make_transport(
        config.transport, config.hosts, drop=config.transport_drop,
        reorder=config.transport_reorder, delay=config.transport_delay,
        seed=config.transport_seed, rtt_ms=config.transport_rtt_ms,
        credit=config.transport_credit, encode=encode, decode=decode)


class Fabric:
    """A running fabric session. Construct via :meth:`open` /
    :meth:`restore` / :meth:`from_snapshot`; usable as a context manager
    (``close()`` on exit writes the final frontier checkpoint)."""

    def __init__(self, config: FabricConfig, *, replica_set=None, group=None,
                 model_cfg=None, params=None, step: int = 0,
                 tenant_state: Optional[dict] = None):
        assert (replica_set is None) != (group is None), \
            "exactly one of replica_set (sched-only) / group (serving)"
        self.config = config
        self._group = group
        self._replica_set = group.replica_set if group is not None \
            else replica_set
        self.model_cfg = model_cfg
        self.params = params
        self.step_count = int(step)
        self._closed = False
        self._spec_by_name = {s.name: s for s in config.classes}
        # tenant scale (DESIGN.md §16): with config.tenants set, the
        # scheduler's hot paths switch to O(active classes) and submits
        # route through the tenant router (hashing, quotas, shedding).
        # Attached post-construction like the obs hub, so every
        # construction path (open / from_snapshot / replica rebuild)
        # works unchanged.
        self._tenants: Optional[TenantRouter] = None
        if config.tenants is not None:
            self._replica_set.scheduler.enable_active_tracking()
            self._tenants = self._build_router(config, tenant_state)
        self._ckpt = None
        if config.checkpoint_dir is not None:
            from repro.checkpoint.checkpointer import AsyncCheckpointer
            self._ckpt = AsyncCheckpointer(config.checkpoint_dir,
                                           window=config.checkpoint_window)
        # observability plane (DESIGN.md §13): one MetricsHub over the whole
        # session — flight recorders attach to every emitting component by
        # walking the object graph (re-walked after resize/fail_host, which
        # rebuild engines). config.obs is None -> no hub, no recorders, and
        # every emit site stays a single `is None` check.
        self._obs_hub = None
        if config.obs is not None and config.obs.enabled:
            from repro.obs import MetricsHub
            self._obs_hub = MetricsHub(config.obs)
            self._obs_hub.attach(self._replica_set, engines=self.engines)
        # control plane (DESIGN.md §14): the actuation surface is always
        # present (fabric.control.resize/set_weight/... are the typed way
        # to pull levers by hand); the closed-loop Controller inside it
        # exists only when config.control is set and enabled.
        self._control = ControlHandle(self, config.control)

    # ------------------------------------------------------------- lifecycle
    @classmethod
    def open(cls, config: FabricConfig, *, params=None,
             model_cfg=None, codec=None) -> "Fabric":
        """Stand up a fresh fabric from the declarative config. ``params`` /
        ``model_cfg`` are overrides for callers that already hold model
        state (tests, the compat shims); normally both derive from
        ``config.arch`` (+ ``params_dir``). ``codec=(encode, decode)``
        supplies the sim transport's payload wire hooks for scheduler-only
        fabrics with non-JSON-stable payloads."""
        config.validate()
        classes = _build_classes(config)
        transport = _build_transport(config, codec)
        if config.arch is None:
            sched = Scheduler(classes, policy=config.policy)
            rs = ReplicaSet(sched, config.replicas, policy=config.policy,
                            min_steal=config.min_steal, transport=transport)
            return cls(config, replica_set=rs)
        model_cfg, params = cls._model_state(config, model_cfg, params)
        from repro.serving.engine import EngineReplicaGroup
        group = EngineReplicaGroup(
            model_cfg, params, num_replicas=config.replicas,
            max_batch=config.max_batch, page_size=config.page_size,
            num_pages=config.num_pages, window=config.kv_window,
            max_seq=config.max_seq, classes=classes, policy=config.policy,
            min_steal=config.min_steal, transport=transport,
            device_admission=config.device_admission)
        return cls(config, group=group, model_cfg=model_cfg, params=params)

    @classmethod
    def from_snapshot(cls, snapshot: dict, *, params=None, model_cfg=None,
                      checkpoint_dir: Optional[str] = None,
                      overrides: Optional[dict] = None,
                      codec=None) -> "Fabric":
        """Rebuild a fabric from a :meth:`snapshot` dict (JSON round-trip
        safe): the config rides inside it, every tenant resumes at its
        exact FIFO seat, and the replica count is whatever the snapshot
        recorded (resizes survive checkpoints).

        ``overrides`` replaces config fields that are safe to change across
        a restore — policy, engine geometry/budgets, checkpoint cadence,
        and the transport/host layout (owners are recorded by replica and
        re-addressed on restore, so a snapshot taken under LocalTransport
        restores onto a multi-host SimHostTransport and vice versa) — and
        is re-validated; class declarations and seat structure always come
        from the snapshot (they ARE the resume state)."""
        config = FabricConfig.from_json(snapshot["config"])
        if overrides:
            for key in ("classes", "shards_per_class", "replicas",
                        "tenants"):
                if key in overrides:
                    raise FabricConfigError(
                        f"from_snapshot: cannot override {key!r} — it is "
                        f"part of the seat structure being restored (open a "
                        f"fresh fabric, or resize() after restoring)")
            config = dataclasses.replace(config, **overrides)
        if checkpoint_dir is not None \
                and checkpoint_dir != config.checkpoint_dir:
            config = dataclasses.replace(config, checkpoint_dir=checkpoint_dir)
        step = int(snapshot.get("step", 0))
        tenant_state = snapshot.get("tenants")
        transport = _build_transport(config, codec)
        if config.arch is None:
            rs = ReplicaSet.from_state(snapshot["sched"],
                                       policy=config.policy,
                                       min_steal=config.min_steal,
                                       transport=transport)
            return cls(config, replica_set=rs, step=step,
                       tenant_state=tenant_state)
        model_cfg, params = cls._model_state(config, model_cfg, params)
        from repro.serving.engine import EngineReplicaGroup
        group = EngineReplicaGroup.from_sched_state(
            model_cfg, params, snapshot["sched"], policy=config.policy,
            min_steal=config.min_steal, window=config.kv_window,
            max_batch=config.max_batch, page_size=config.page_size,
            num_pages=config.num_pages, max_seq=config.max_seq,
            transport=transport,
            device_admission=config.device_admission)
        return cls(config, group=group, model_cfg=model_cfg, params=params,
                   step=step, tenant_state=tenant_state)

    @classmethod
    def restore(cls, checkpoint_dir: str, *, step: Optional[int] = None,
                params=None, model_cfg=None,
                overrides: Optional[dict] = None, codec=None) -> "Fabric":
        """Resume from the latest (or a specific) cadence checkpoint in
        ``checkpoint_dir``: the snapshot carries its own config, so no
        re-declaration is needed (``overrides`` as in
        :meth:`from_snapshot`)."""
        from repro.checkpoint.checkpointer import restore_aux
        ck_step, aux = restore_aux(checkpoint_dir, step)
        if aux is None or "fabric" not in aux:
            raise FabricConfigError(
                f"checkpoint step {ck_step} in {checkpoint_dir!r} has no "
                f"fabric snapshot (aux['fabric']): was it written by "
                f"Fabric, or is this a params-only / pre-fabric directory?")
        return cls.from_snapshot(aux["fabric"], params=params,
                                 model_cfg=model_cfg,
                                 checkpoint_dir=checkpoint_dir,
                                 overrides=overrides, codec=codec)

    @staticmethod
    def _build_router(config: FabricConfig,
                      state: Optional[dict]) -> TenantRouter:
        t = config.tenants
        if state is not None:  # snapshot restore: routing/quotas/stats ride
            return TenantRouter.from_state(state, t.stats_capacity,
                                           t.stats_top_k)
        tmap = TenantMap(t.num_tenants, t.num_groups, t.salt)
        stats = TenantStatsTable(t.stats_capacity, t.stats_top_k)
        ledger = None
        if t.page_quota is not None:
            total = t.quota_total
            if total is None:
                # serving fabrics cap at the real page budget; scheduler-
                # only ones (no KV pool) at one full quota per group
                total = (config.num_pages if config.arch is not None
                         else t.num_groups * t.page_quota)
            ledger = TenantQuotaLedger(t.page_quota, total,
                                       t.quota_hosts or config.hosts)
        return TenantRouter(tmap, stats, ledger, t.admit_pressure)

    @staticmethod
    def _model_state(config: FabricConfig, model_cfg, params):
        import jax
        from repro.configs import get_config
        from repro.models import init_params
        if model_cfg is None:
            try:
                model_cfg = get_config(config.arch, smoke=config.smoke)
            except (ImportError, AttributeError, KeyError) as e:
                raise FabricConfigError(
                    f"unknown arch {config.arch!r} ({e}); see "
                    f"repro.configs.ARCHS") from None
        if params is None:
            params = init_params(model_cfg,
                                 jax.random.PRNGKey(config.param_seed))
            if config.params_dir is not None:
                from repro.checkpoint import checkpointer as C
                _, state = C.restore(config.params_dir, {"params": params})
                params = state["params"]
        return model_cfg, params

    def close(self, *, final_checkpoint: bool = True) -> None:
        """End the session. With a checkpoint dir configured, drains the
        async writer and (by default) writes one final frontier snapshot so
        the recovery point is the exact close state."""
        if self._closed:
            return
        self._closed = True
        try:
            if self._ckpt is not None:
                try:
                    self._ckpt.drain()
                    if final_checkpoint:
                        from repro.checkpoint.checkpointer import save
                        save(self.config.checkpoint_dir, self.step_count, {},
                             aux={"fabric": self.snapshot()})
                finally:
                    self._ckpt.close()
        finally:
            # transports that own external resources (the wire transport's
            # host worker processes + sockets) tear down last, after any
            # final snapshot has finished talking to them
            tclose = getattr(self._replica_set.transport, "close", None)
            if callable(tclose):
                tclose()

    def __enter__(self) -> "Fabric":
        return self

    def __exit__(self, *exc) -> None:
        self.close(final_checkpoint=exc[0] is None)

    # ----------------------------------------------------------------- intro
    @property
    def serving(self) -> bool:
        return self._group is not None

    @property
    def num_replicas(self) -> int:
        """Current replica count (tracks :meth:`resize`, unlike
        ``config.replicas`` which is the opening count)."""
        return self._replica_set.num_replicas

    @property
    def replicas(self):
        """The live :class:`~repro.sched.SchedulerReplica` list — benchmark
        harnesses drive per-replica drains through this."""
        return self._replica_set.replicas

    @property
    def replica_set(self) -> ReplicaSet:
        return self._replica_set

    @property
    def engines(self):
        return self._group.engines if self._group is not None else []

    @property
    def completed(self) -> Dict[int, Any]:
        return self._group.completed if self._group is not None else {}

    def pending(self) -> int:
        """Accepted-but-undelivered items across the fabric."""
        return self._replica_set.pending()

    def idle(self) -> bool:
        if self._group is not None:
            return self._group.idle()
        return self._replica_set.pending() == 0

    # ---------------------------------------------------------------- client
    def submit(self, item, *, qclass: Optional[str] = None,
               tenant=None, tier: Optional[str] = None,
               max_new_tokens: int = 16):
        """Serving mode: ``item`` is a token prompt; returns its uid (None
        on admission-window rejection). Scheduler-only mode: ``item`` is an
        arbitrary payload; returns its Envelope (None on rejection).

        Tenant fabrics (``config.tenants``): pass ``tenant`` (any hashable
        id) and optionally ``tier`` (interactive | batch | background,
        default interactive) instead of ``qclass`` — routing, per-tenant
        quota accounting and overload shedding happen here. ``None`` also
        means a 429-style shed (lowest tier under group pressure or quota
        exhaustion — counted in ``StatsView.classes[...].shed``)."""
        self._check_open()
        if tenant is not None:
            if self._tenants is None:
                raise FabricConfigError(
                    "submit(tenant=...) needs a tenant fabric: set "
                    "tenants=TenantSpec(...) on the config")
            return self._submit_tenant(item, tenant, tier or TIERS[0],
                                       max_new_tokens)
        if self._group is not None:
            return self._group.submit(item, max_new_tokens=max_new_tokens,
                                      qclass=qclass)
        name = qclass or self._replica_set.scheduler.default_class
        return self._replica_set.submit(name, item)

    def _page_estimate(self, item, max_new_tokens: int) -> int:
        """Admission-time KV page estimate for the quota ledger: the pages
        the request will occupy at full length (serving), or 1 unit per
        item on scheduler-only fabrics (the ledger then meters items)."""
        if self._group is None:
            return 1
        tokens = len(item) + max_new_tokens
        return -(-tokens // self.config.page_size)

    def _group_pressure(self, gid: int) -> bool:
        """Group overload signal for admission shedding: summed window
        occupancy across the group's tier classes vs the summed windows
        (plain atomic loads of state that already exists — zero added
        atomics, O(tiers) per submit)."""
        router = self._tenants
        by_name = self._replica_set.scheduler.by_name
        occ = cap = 0
        for tier in router.map.tiers:
            qc = by_name[group_class_name(gid, tier)]
            if qc.admit_window:
                occ += qc._inflight.load()
                cap += qc.admit_window
        return cap > 0 and occ >= router.admit_pressure * cap

    def _submit_tenant(self, item, tenant, tier: str, max_new_tokens: int):
        """The tenant admission path: route -> shed check (lowest tier
        only) -> quota charge -> class submit; every deny leaves the
        ledger exactly where it was. Admission keys — (class, seq) for
        scheduler-only, uid for serving — are credited back in step()."""
        router = self._tenants
        gid, cls = router.route(tenant, tier)
        pages = self._page_estimate(item, max_new_tokens)
        sheddable = router.sheddable(tier)
        if sheddable and self._group_pressure(gid):
            router.note_shed(tenant, cls)
            self._replica_set.scheduler.by_name[cls].stats.add_rejected()
            return None
        if not router.try_charge(tenant, pages):
            if sheddable:
                router.note_shed(tenant, cls)
            else:
                router.note_reject(tenant)
            self._replica_set.scheduler.by_name[cls].stats.add_rejected()
            return None
        if self._group is not None:
            uid = self._group.submit(item, max_new_tokens=max_new_tokens,
                                     qclass=cls)
            if uid is None:  # window rejection inside the class
                router.cancel_charge(tenant, pages)
                if sheddable:
                    router.note_shed(tenant, cls)
                else:
                    router.note_reject(tenant)
                return None
            router.note_admit(tenant, uid, pages)
            return uid
        env = self._replica_set.submit(cls, item)
        if env is None:
            router.cancel_charge(tenant, pages)
            if sheddable:
                router.note_shed(tenant, cls)
            else:
                router.note_reject(tenant)
            return None
        router.note_admit(tenant, (cls, env.seq), pages)
        return env

    def submit_many(self, items: Sequence, *, qclass: Optional[str] = None,
                    max_new_tokens: int = 16) -> List:
        """Batched admission (one cycle-range fetch-add + one splice per
        shard for the burst); rejected entries come back as None."""
        self._check_open()
        if self._group is not None:
            return self._group.submit_many(
                list(items), max_new_tokens=max_new_tokens, qclass=qclass)
        name = qclass or self._replica_set.scheduler.default_class
        return self._replica_set.submit_many(name, list(items))

    # ------------------------------------------------------------------ loop
    def step(self) -> List:
        """One fabric iteration: every replica admits/decodes (serving) or
        drains one batch (scheduler-only), starved replicas steal, and the
        checkpoint cadence fires when due. Returns completed requests
        (serving) or ``(view, envelope)`` deliveries (scheduler-only)."""
        self._check_open()
        self.step_count += 1
        if self._group is not None:
            out = self._group.step()
        else:
            out = []
            for r in self._replica_set.replicas:
                out.extend(r.drain(self.config.drain_k))
            self._replica_set.rebalance()
        router = self._tenants
        if router is not None and out:
            # credit quota charges + per-tenant delivery counts by the
            # admission key: uid (serving completions) or (class, seq)
            if self._group is not None:
                for req in out:
                    router.on_done(req.uid)
            else:
                for view, env in out:
                    router.on_done((view.name, env.seq))
        every = self.config.checkpoint_every_n_steps
        if (self._ckpt is not None and every is not None
                and self.step_count % every == 0):
            # Never blocks; dropped when the writer lags more than
            # checkpoint_window snapshots — the recovery point is bounded,
            # the step loop is not.
            self._ckpt.submit(self.step_count, {},
                              aux={"fabric": self.snapshot()})
        hub = self._obs_hub
        if (hub is not None and
                self.step_count % hub.config.sample_every_n_steps == 0):
            hub.sample(self._replica_set, self.engines)
            if hub.config.snapshot_path is not None:
                from repro.obs import append_jsonl_snapshot, strip_samples
                append_jsonl_snapshot(
                    hub.config.snapshot_path,
                    {"step": self.step_count,
                     "obs": strip_samples(hub.snapshot())})
        # Closed loop last, so a decision sees this step's depths and the
        # freshest gauge sample (DESIGN.md §14: signals→decision→actions).
        ctrl = self._control
        if (ctrl.controller is not None and
                self.step_count % ctrl.config.decide_every_n_steps == 0):
            ctrl.step()
        return out

    def drain(self, max_steps: int = 1000):
        """Run until idle. Returns the completed-request dict (serving) or
        the list of deliveries made during this call (scheduler-only)."""
        if self._group is not None:
            for _ in range(max_steps):
                self.step()
                if self._group.idle():
                    break
            return self._group.completed
        out: List = []
        for _ in range(max_steps):
            got = self.step()
            out.extend(got)
            if not got and self._replica_set.pending() == 0:
                break
        return out

    # ------------------------------------------------------------ elasticity
    def resize(self, num_replicas: int) -> "Fabric":
        """Live replica elasticity: grow/shrink the running fabric to
        ``num_replicas`` with no drain pause — a batch of seat claims plus
        (in serving mode) a lane/page budget re-split. Bounded by
        ``config.max_replicas`` (seats are provisioned at open)."""
        self._check_open()
        n = int(num_replicas)
        if n < 1 or n > self.config.max_replicas:
            raise FabricConfigError(
                f"resize({n}): replica count must be in [1, max_replicas="
                f"{self.config.max_replicas}] — seats are provisioned at "
                f"open; raise max_replicas in the config to resize further")
        if self._group is not None:
            self._group.resize(n)
        else:
            self._replica_set.resize(n)
        if self._obs_hub is not None:  # engines were rebuilt: re-attach
            self._obs_hub.attach(self._replica_set, engines=self.engines)
        return self

    def fail_host(self, host: int) -> int:
        """Chaos/ops entry point: kill one simulated transport host mid-run
        and recover its seats into the survivors (serving mode first
        preempts the dead host's lanes to their exact seats). Per-class
        FIFO delivery is preserved exactly — the dead host's final frontier
        state replays through the wire codec. Returns the number of seats
        reassigned."""
        self._check_open()
        if self._group is not None:
            moved = self._group.fail_host(host)
        else:
            moved = self._replica_set.fail_host(host)
        if self._obs_hub is not None:  # survivor engines rebuilt: re-attach
            self._obs_hub.attach(self._replica_set, engines=self.engines)
        return moved

    def add_host(self) -> int:
        """Grow the simulated host fleet by one (sim transport only); the
        next :meth:`resize` / reseat spreads seats over the enlarged
        fleet. Returns the new host count. The control plane's
        ``GrowHost`` action is ``add_host()`` + ``resize(n)``."""
        self._check_open()
        t = self.transport
        if not hasattr(t, "add_host"):
            raise FabricConfigError(
                "add_host(): the local transport is single-host by "
                "definition — open with transport='sim' to grow hosts")
        n = t.add_host()
        if self._obs_hub is not None:
            self._obs_hub.attach(self._replica_set, engines=self.engines)
        return n

    @property
    def transport(self):
        return self._replica_set.transport

    @property
    def num_hosts(self) -> int:
        return self._replica_set.transport.num_hosts

    @property
    def control(self) -> ControlHandle:
        """The control plane's actuation surface (DESIGN.md §14): typed
        signal reads (``fabric.control.signals()``) and typed actions
        (``.resize/.grow_host/.set_weight/.set_priority/.apply``), plus
        the closed-loop controller when ``config.control`` is set."""
        return self._control

    @property
    def obs(self):
        """The session's :class:`~repro.obs.MetricsHub` (None when
        ``config.obs`` is unset/disabled) — the exporters' entry point:
        ``perfetto_trace(fabric.obs.events())``,
        ``prometheus_text(fabric.stats_view())``."""
        return self._obs_hub

    @property
    def tenants(self) -> Optional[TenantRouter]:
        """The tenant router (None unless ``config.tenants`` is set):
        routing map, quota ledger, shed counters, lazy per-tenant stats."""
        return self._tenants

    # ------------------------------------------------------------ checkpoint
    def snapshot(self) -> dict:
        """JSON-able exact-seat frontier snapshot of the whole session:
        the config, the fabric step, and every class's cycle counters, seat
        cursors/owners and undelivered envelopes. Take it at a step
        boundary; restore with :meth:`from_snapshot`."""
        if self._group is not None:
            sched = self._group.sched_state()
        else:
            sched = self._replica_set.state()
        out = {"config": self.config.to_json(), "step": self.step_count,
               "sched": sched}
        if self._tenants is not None:
            out["tenants"] = self._tenants.state()
        return out

    def checkpoint(self, *, wait: bool = True) -> bool:
        """Write a frontier checkpoint now, outside the cadence. Returns
        False when the async writer's window was full and the snapshot was
        dropped (never blocks unless ``wait``)."""
        self._check_open()
        if self._ckpt is None:
            raise FabricConfigError(
                "checkpoint(): no checkpoint_dir configured")
        ok = self._ckpt.submit(self.step_count, {},
                               aux={"fabric": self.snapshot()})
        if wait:
            self._ckpt.drain()
        return ok

    def flush_checkpoints(self, timeout: float = 60.0) -> None:
        """Block until every cadence snapshot handed to the async writer is
        durably on disk (e.g. before a deliberate kill in tests/demos)."""
        if self._ckpt is not None:
            self._ckpt.drain(timeout)

    # ------------------------------------------------------------- telemetry
    def stats_view(self) -> StatsView:
        """The versioned fabric-wide telemetry snapshot (DESIGN.md §14):
        typed per-class aggregates (via ``aggregate_class_snapshots``
        across replicas, continuous across resizes) and the ``slo`` view —
        measured per-class ``admit_p99_ms`` against each class's configured
        ``slo_ms`` target — plus pass-through ``replicas`` / ``transport``
        / ``checkpoint`` / ``obs`` / ``control`` sections. This is the one
        schema the controller, serve.py heartbeat and exporters all read;
        ``view.to_json()`` is the JSON-stable raw form."""
        router = self._tenants
        # Tenant fabrics emit only the *active* grid classes: the view
        # stays O(active tenants), never O(declared) — idle groups cost
        # nothing to report, exactly like they cost nothing to drain.
        snap = self._replica_set.snapshot(active_only=router is not None)
        shed_by = router.shed_by_class if router is not None else {}
        classes = {}
        slo = {}
        for name, cs in snap["classes"].items():
            spec = self._spec_by_name[name]
            classes[name] = class_view_from_snapshot(
                name, cs, shed_by.get(name, 0))
            p99 = cs["admit_p99_ms"]
            ok = None if (spec.slo_ms is None or p99 is None) \
                else p99 <= spec.slo_ms
            slo[name] = SloView(
                target_ms=spec.slo_ms,
                admit_p99_ms=p99,
                ok=ok,
                headroom_ms=(None if spec.slo_ms is None or p99 is None
                             else spec.slo_ms - p99),
            )
        tenants = None
        if router is not None:
            tenants = router.snapshot()
            act = self._replica_set.scheduler.active
            tenants["active_classes"] = 0 if act is None else len(act)
        checkpoint = None
        if self._ckpt is not None:
            checkpoint = {"written": list(self._ckpt.written),
                          "dropped": self._ckpt.dropped}
        transport = _json_safe(snap["transport"])
        if self._obs_hub is not None:
            rtt = self._obs_hub.snapshot().get("rtt_ms")
            if rtt:
                transport["rtt_ms"] = _json_safe(rtt)
        return StatsView(
            step=self.step_count,
            num_replicas=self.num_replicas,
            num_hosts=self.num_hosts,
            resizes=self._replica_set.resizes,
            classes=classes,
            slo=slo,
            replicas=_json_safe(snap["replicas"]),
            transport=transport,
            checkpoint=checkpoint,
            obs=(_json_safe(self._obs_hub.snapshot())
                 if self._obs_hub is not None else None),
            control=self._control.snapshot(),
            tenants=_json_safe(tenants) if tenants is not None else None,
        )

    def stats(self) -> dict:
        """Deprecated raw-dict alias of :meth:`stats_view` — exactly
        ``stats_view().to_json()``. Warns once per process; new code reads
        the typed view. (Two schema-1 differences from the pre-PR-8 dict:
        per-class blobs carry ``name`` instead of ``class`` and no longer
        ship raw ``latency_samples``, and nested section keys are
        strings.)"""
        global _STATS_DICT_WARNED
        if not _STATS_DICT_WARNED:
            _STATS_DICT_WARNED = True
            warnings.warn(
                "Fabric.stats() is deprecated: read the versioned "
                "Fabric.stats_view() (StatsView, schema_version "
                f"{StatsView.schema_version}); stats() now returns "
                "stats_view().to_json()", DeprecationWarning, stacklevel=2)
        return self.stats_view().to_json()

    # -------------------------------------------------------------- internal
    def _check_open(self) -> None:
        if self._closed:
            raise FabricConfigError("fabric session is closed")
