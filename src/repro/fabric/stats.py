"""Versioned, typed fabric stats surface (DESIGN.md §14).

``Fabric.stats_view()`` returns one frozen :class:`StatsView` — the single
stats schema that the controller (``repro.control``), ``serve.py``'s
heartbeat lines and the exporters all read. The raw dict that grew across
PRs 2–7 survives only as the deprecated ``Fabric.stats()`` alias (exactly
one ``DeprecationWarning`` per process), and is now *defined* as
``stats_view().to_json()`` — one schema, two spellings.

Schema rules:

  * ``schema_version`` bumps on any key rename/removal; additive optional
    sections do not bump it.
  * ``to_json()`` / ``from_json()`` are exact inverses
    (``StatsView.from_json(v.to_json()) == v``), and ``to_json()`` output
    is JSON-stable: plain types, string keys, no raw latency reservoirs
    (the §13 size convention — reservoirs are merge plumbing, not
    snapshot payload).
  * The typed core is the per-class counters and the SLO view; sections
    whose layout is owned elsewhere (``replicas``, ``transport``,
    ``checkpoint``, ``obs``, ``control``) pass through as dicts.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

SCHEMA_VERSION = 1


def _json_safe(obj: Any) -> Any:
    """Deep-normalize a pass-through section to JSON-stable form: string
    keys, lists for tuples, no latency reservoirs."""
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()
                if k != "latency_samples"}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    return obj


@dataclasses.dataclass(frozen=True)
class ClassStatsView:
    """Fabric-wide aggregate for one queue class (continuous across
    resizes; merged exactly across replicas by pooling reservoirs)."""

    name: str
    pending: int
    submitted: int
    rejected: int
    delivered: int
    requeued: int
    gap_waits: int
    admit_p50_ms: Optional[float]
    admit_p99_ms: Optional[float]
    shard_depths: Tuple[int, ...] = ()
    # 429-style admission sheds (tenant fabrics, lowest tier only) —
    # additive optional field, no schema bump; 0 everywhere else.
    shed: int = 0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["shard_depths"] = list(self.shard_depths)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "ClassStatsView":
        d = dict(d)
        d["shard_depths"] = tuple(d.get("shard_depths") or ())
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class SloView:
    """Measured p99 admission latency against one class's ``slo_ms``
    target. ``ok``/``headroom_ms`` are None until both sides exist."""

    target_ms: Optional[float]
    admit_p99_ms: Optional[float]
    ok: Optional[bool]
    headroom_ms: Optional[float]

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "SloView":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class StatsView:
    """One frozen fabric-wide telemetry snapshot (``schema_version`` 1)."""

    step: int
    num_replicas: int
    num_hosts: int
    resizes: int
    classes: Dict[str, ClassStatsView]
    slo: Dict[str, SloView]
    replicas: Dict[str, dict]
    transport: dict
    checkpoint: Optional[dict] = None
    obs: Optional[dict] = None
    control: Optional[dict] = None
    # tenant fabrics (DESIGN.md §16): declared/tracked/active counts,
    # shed totals, quota occupancy, top-K tenants by backlog. With this
    # section present, ``classes`` holds only the *active* grid classes —
    # the emitted view is O(active), never O(declared tenants).
    tenants: Optional[dict] = None
    schema_version: int = SCHEMA_VERSION

    def to_json(self) -> dict:
        out = {
            "schema_version": self.schema_version,
            "step": self.step,
            "num_replicas": self.num_replicas,
            "num_hosts": self.num_hosts,
            "resizes": self.resizes,
            "classes": {n: c.to_json() for n, c in self.classes.items()},
            "slo": {n: s.to_json() for n, s in self.slo.items()},
            "replicas": self.replicas,
            "transport": self.transport,
        }
        for key in ("checkpoint", "obs", "control", "tenants"):
            val = getattr(self, key)
            if val is not None:
                out[key] = val
        return out

    @classmethod
    def from_json(cls, d: dict) -> "StatsView":
        version = d.get("schema_version", SCHEMA_VERSION)
        if version != SCHEMA_VERSION:
            raise ValueError(
                f"StatsView schema_version {version} is not supported "
                f"(this build reads version {SCHEMA_VERSION})")
        return cls(
            step=d["step"],
            num_replicas=d["num_replicas"],
            num_hosts=d["num_hosts"],
            resizes=d["resizes"],
            classes={n: ClassStatsView.from_json(c)
                     for n, c in d["classes"].items()},
            slo={n: SloView.from_json(s) for n, s in d["slo"].items()},
            replicas=d["replicas"],
            transport=d["transport"],
            checkpoint=d.get("checkpoint"),
            obs=d.get("obs"),
            control=d.get("control"),
            tenants=d.get("tenants"),
            schema_version=version,
        )


def class_view_from_snapshot(name: str, snap: dict,
                             shed: int = 0) -> ClassStatsView:
    """Build the typed per-class view from a raw ``ClassStats`` aggregate
    (``aggregate_class_snapshots`` output), dropping the reservoir."""
    return ClassStatsView(
        name=name,
        pending=snap["pending"],
        submitted=snap["submitted"],
        rejected=snap["rejected"],
        delivered=snap["delivered"],
        requeued=snap["requeued"],
        gap_waits=snap["gap_waits"],
        admit_p50_ms=snap["admit_p50_ms"],
        admit_p99_ms=snap["admit_p99_ms"],
        shard_depths=tuple(snap["shard_depths"]),
        shed=shed,
    )
