"""Sharding rules: param-path -> PartitionSpec, activation & cache specs.

Mesh axes:
  single-pod:  (data=16, model=16)                  -> 256 chips
  multi-pod:   (pod=2, data=16, model=16)           -> 512 chips

Strategy (1000+ node posture, DESIGN.md §3):
  * 2-D FSDP x TP on weights: rows -> 'data', cols -> 'model'. GSPMD then
    all-gathers weights for the forward (FSDP) and reduce-scatters grads;
    optimizer state inherits the 2-D sharding (ZeRO-3-equivalent).
  * experts -> 'model' (EP); router replicated over 'model'.
  * batch   -> ('pod', 'data') when multi-pod, else 'data'. The 'pod' axis
    carries ONLY gradient all-reduce traffic (hierarchical reduction).
  * decode KV cache: time dim -> 'model' (sequence-sharded cache; softmax
    reductions over the sharded axis become cross-shard collectives).
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# (regex on '/'-joined param path) -> CANDIDATE specs, first whose sharded
# dims all divide evenly wins (e.g. 40 experts can't split 16-way EP -> fall
# back to TP over the expert FFN dims; 49155-row vocab -> shard d_model only).
# Paths look like: blocks/0/attn/wq, blocks/1/moe/wg, embed, lm_head, ...
_PARAM_RULES = [
    (r"embed$",               [P("model", "data"), P(None, "data")]),
    (r"lm_head$",             [P("data", "model"), P("data", None)]),
    (r"final_norm/",          [P()]),
    (r"ln\d*/|norm_attn/|norm_ssm/",  [P(None)]),
    (r"attn/w[qkv]$",         [P(None, "data", "model"), P(None, "data", None)]),
    (r"attn/wo$",             [P(None, "model", "data"), P(None, None, "data")]),
    (r"mlp/w[gu]$",           [P(None, "data", "model"), P(None, "data", None)]),
    (r"mlp/wd$",              [P(None, "model", "data"), P(None, None, "data")]),
    (r"moe/router$",          [P(None, "data", None)]),
    (r"moe/w[gu]$",           [P(None, "model", "data", None), P(None, None, "data", "model")]),
    (r"moe/wd$",              [P(None, "model", None, "data"), P(None, None, "model", "data")]),
    (r"mlstm/(wq|wk|wv|ogate)$", [P(None, "data", "model"), P(None, "data", None)]),
    (r"mlstm/wo$",            [P(None, "model", "data"), P(None, None, "data")]),
    (r"mlstm/w[if]$",         [P(None, "data", None)]),
    (r"slstm/w[zifo]$",       [P(None, "data", "model"), P(None, "data", None)]),
    (r"slstm/r[zifo]$",       [P(None)]),
    (r"slstm/wout$",          [P(None, "model", "data"), P(None, None, "data")]),
    (r"mamba/win$",           [P(None, "data", "model"), P(None, "data", None)]),
    (r"mamba/wout$",          [P(None, "model", "data"), P(None, None, "data")]),
    (r"mamba/(a_log|d_skip)$", [P(None)]),
]

_DEFAULT_AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _spec_fits(spec: P, shape, axis_sizes) -> bool:
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        div = 1
        for nme in names:
            div *= axis_sizes.get(nme, 1)
        if i >= len(shape) or shape[i] % div != 0 or shape[i] < div:
            return False
    return True


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path_str: str, shape=None, axis_sizes=None) -> P:
    axis_sizes = axis_sizes or _DEFAULT_AXIS_SIZES
    for pat, candidates in _PARAM_RULES:
        if re.search(pat, path_str):
            if shape is None:
                return candidates[0]
            for spec in candidates:
                if _spec_fits(spec, shape, axis_sizes):
                    return spec
            # last resort: strip whichever entries don't divide
            base = candidates[0]
            entries = list(base) + [None] * (len(shape) - len(base))
            out = []
            for i, entry in enumerate(entries[:len(shape)]):
                one = P(*([None] * i + [entry]))
                out.append(entry if entry and _spec_fits(one, shape, axis_sizes)
                           else None)
            return P(*out)
    return P()  # replicate small leftovers


def _strip_axis(spec: P, axis: str) -> P:
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a != axis)
            out.append(kept if kept else None)
        else:
            out.append(None if entry == axis else entry)
    return P(*out)


def param_specs(params, mesh: Optional[Mesh] = None, mode: str = "2d") -> Any:
    """Pytree of PartitionSpecs matching the param pytree (shape-aware when
    leaves carry shapes). mode: '2d' FSDPxTP | 'tp' (replicate over data —
    stationary decode weights) | 'dp' (replicate over model — small models)."""
    axis_sizes = (dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh
                  else _DEFAULT_AXIS_SIZES)

    def one(path, x):
        spec = param_spec(_path_str(path), getattr(x, "shape", None), axis_sizes)
        if mode == "tp":
            spec = _strip_axis(spec, "data")
        elif mode == "dp":
            spec = _strip_axis(spec, "model")
        return spec

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, mesh: Mesh, mode: str = "2d") -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh, mode))


def batch_spec(mesh: Mesh) -> P:
    """tokens [B, S] (labels etc. follow)."""
    return P(batch_axes(mesh), None)


def batch_specs_for(mesh: Mesh, batch_like) -> Any:
    bs = batch_spec(mesh)

    def leaf_spec(x):
        if getattr(x, "ndim", 0) >= 2:
            return bs if x.ndim == 2 else P(batch_axes(mesh), *([None] * (x.ndim - 1)))
        return P()

    return jax.tree_util.tree_map(leaf_spec, batch_like)


def cache_specs_for(mesh: Mesh, cache, batch_size: int) -> Any:
    """Decode-cache leaves. Stacked layout [L, B, T|H, ...]: batch -> data
    when divisible; dim-2 (cache time for KV, heads for SSM state) -> 'model'
    when divisible (sequence-sharded KV cache; softmax reductions over the
    sharded axis lower to cross-shard collectives)."""
    ba = batch_axes(mesh)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    b_axis = ba if batch_size % n_b == 0 and batch_size >= n_b else None
    n_model = mesh.shape["model"]

    def leaf_spec(x):
        nd = getattr(x, "ndim", 0)
        if nd < 2:
            return P()
        spec = [None, b_axis] + [None] * (nd - 2)
        if nd >= 3 and x.shape[2] % n_model == 0 and x.shape[2] >= n_model:
            spec[2] = "model"
        return P(*spec)

    return jax.tree_util.tree_map(leaf_spec, cache)


def logical_mesh_devices(n: int):
    return jax.devices()[:n]
