"""Pipeline parallelism with CMP-windowed microbatch buffers.

The coordination problem in pipeline parallelism is buffer lifecycle: stage
s's activation output must stay alive until stage s+1 consumes it (and, for
training, until the backward pass revisits it), after which the buffer must
recycle — classically done with per-microbatch ready-flags and stage
barriers. The CMP mapping (DESIGN.md §2):

  * an activation buffer is *produced* (AVAILABLE, cycle = microbatch tick)
    when a stage writes it;
  * the consuming stage *claims* it (CLAIMED) — the claim IS the dataflow
    edge, no flag handshake;
  * claimed buffers recycle once outside the window W = pipeline depth
    (the number of in-flight microbatches) — a stalled stage can delay at
    most W buffers, never the pool.

This module provides a 1F1B schedule planner, an executor that runs it with
a real :class:`repro.core.slotpool` pool guarding a fixed ring of activation
buffers, and numerical-equivalence guarantees (pipelined grads == plain
grads). On a real multi-pod deployment each stage maps to a `pod`/`stage`
mesh axis and the buffer ring lives in each stage's HBM; here the schedule
and pool-safety logic are exercised on one host.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import slotpool as sp
from repro.core.domain import AVAILABLE, STATE_NAMES


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Tick:
    kind: str        # "fwd" | "bwd"
    stage: int
    microbatch: int


def one_f_one_b(num_stages: int, num_micro: int) -> List[Tick]:
    """Classic 1F1B: warmup fwds, steady-state alternation, cooldown bwds.
    In-flight microbatches per stage never exceed num_stages (= window W)."""
    ticks: List[Tick] = []
    for s in range(num_stages):
        # each stage's local order; we emit a global order by time step
        pass
    # simple global emission: time-stepped wavefront
    fwd_done = [0] * num_stages
    bwd_done = [0] * num_stages
    total = num_micro * num_stages
    while sum(fwd_done) + sum(bwd_done) < 2 * total / num_stages * num_stages // 1:
        progressed = False
        for s in range(num_stages):
            warmup = min(num_stages - s, num_micro)
            can_fwd = (fwd_done[s] < num_micro
                       and (s == 0 or fwd_done[s] < fwd_done[s - 1])
                       and fwd_done[s] - bwd_done[s] < min(num_stages, num_micro))
            can_bwd = (bwd_done[s] < num_micro
                       and bwd_done[s] < fwd_done[s]
                       and (s == num_stages - 1 or bwd_done[s] < bwd_done[s + 1])
                       and fwd_done[s] >= min(warmup, num_micro))
            if can_bwd and (fwd_done[s] - bwd_done[s] >= min(warmup, num_micro)
                            or fwd_done[s] == num_micro):
                ticks.append(Tick("bwd", s, bwd_done[s]))
                bwd_done[s] += 1
                progressed = True
            elif can_fwd:
                ticks.append(Tick("fwd", s, fwd_done[s]))
                fwd_done[s] += 1
                progressed = True
        if not progressed:
            # drain any remaining legal bwd
            for s in range(num_stages - 1, -1, -1):
                if (bwd_done[s] < fwd_done[s]
                        and (s == num_stages - 1 or bwd_done[s] < bwd_done[s + 1])):
                    ticks.append(Tick("bwd", s, bwd_done[s]))
                    bwd_done[s] += 1
                    progressed = True
                    break
            if not progressed:
                raise RuntimeError("1F1B schedule deadlock (bug)")
        if all(f == num_micro for f in fwd_done) and all(b == num_micro for b in bwd_done):
            break
    return ticks


def max_in_flight(ticks: List[Tick], num_stages: int) -> int:
    """Peak outstanding (fwd-issued, bwd-incomplete) microbatches at stage 0
    == the protection window the buffer pool needs."""
    peak = cur = 0
    for t in ticks:
        if t.stage == 0 and t.kind == "fwd":
            cur += 1
            peak = max(peak, cur)
        if t.stage == 0 and t.kind == "bwd":
            cur -= 1
    return peak


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------


class PipelineRunner:
    """Runs fn = stage_s(params_s, x) over a 1F1B schedule with activation
    buffers guarded by a CMP slot pool.

    stage_fns: list of callables x -> x' (length = num_stages).
    The runner checks every buffer access against the pool state: reading a
    recycled slot raises — i.e., the window invariant is *enforced*, not
    assumed.
    """

    def __init__(self, stage_fns: List, num_micro: int, *,
                 extra_buffers: int = 2):
        self.stage_fns = stage_fns
        self.num_stages = len(stage_fns)
        self.num_micro = num_micro
        self.ticks = one_f_one_b(self.num_stages, num_micro)
        self.window = max_in_flight(self.ticks, self.num_stages)
        # one ring per stage boundary: W slots + slack
        n_slots = self.window + extra_buffers
        self.pools = [sp.make(n_slots) for _ in range(self.num_stages + 1)]
        self.slot_of: List[Dict[int, int]] = [dict() for _ in range(self.num_stages + 1)]
        self.buffers: List[Dict[int, Any]] = [dict() for _ in range(self.num_stages + 1)]
        self.stats = {"fwd": 0, "bwd": 0, "reclaimed": 0, "peak_slots": 0}

    # ------------------------------------------------------------- buffers
    def _produce(self, boundary: int, micro: int, value) -> None:
        pool, ids, valid = sp.produce(self.pools[boundary], 1)
        if not bool(valid[0]):
            pool, ids, valid = sp.produce_with_reclaim(
                self.pools[boundary], 1, self.window)
            assert bool(valid[0]), (
                f"buffer pool exhausted at boundary {boundary}: the schedule "
                f"exceeded the protection window {self.window}")
        self.pools[boundary] = pool
        slot = int(ids[0])
        self.slot_of[boundary][micro] = slot
        self.buffers[boundary][slot] = value
        used = sp.counts(self.pools[boundary])
        self.stats["peak_slots"] = max(self.stats["peak_slots"],
                                       used["available"] + used["claimed"])

    def _consume(self, boundary: int, micro: int):
        slot = self.slot_of[boundary][micro]
        state = int(self.pools[boundary].state[slot])
        assert state == AVAILABLE, (
            f"UAF: microbatch {micro} buffer at boundary {boundary} was "
            f"recycled (state={STATE_NAMES.get(state, state)}) — window violation")
        value = self.buffers[boundary][slot]
        self.pools[boundary] = sp.claim_ids(
            self.pools[boundary], jnp.asarray([slot], jnp.int32),
            jnp.asarray([True]))
        # claimed buffers recycle once the window slides past them
        self.pools[boundary], n = sp.reclaim(self.pools[boundary], self.window)
        self.stats["reclaimed"] += int(n)
        return value

    # ------------------------------------------------------------- run
    def forward(self, microbatches: List[jax.Array]) -> List[jax.Array]:
        """Forward-only pipeline (serving/eval). Returns per-micro outputs."""
        assert len(microbatches) == self.num_micro
        outs: Dict[int, jax.Array] = {}
        for m, x in enumerate(microbatches):
            self._produce(0, m, x)
        for t in self.ticks:
            if t.kind != "fwd":
                continue
            x = self._consume(t.stage, t.microbatch)
            y = self.stage_fns[t.stage](x)
            self.stats["fwd"] += 1
            if t.stage + 1 < self.num_stages:
                self._produce(t.stage + 1, t.microbatch, y)
            else:
                outs[t.microbatch] = y
        return [outs[m] for m in range(self.num_micro)]

    def train_grads(self, params_stages: List[Any], microbatches: List[jax.Array],
                    loss_fn) -> Tuple[List[Any], jax.Array]:
        """Full 1F1B with backward: returns (per-stage grads summed over
        microbatches, mean loss). Numerically identical to non-pipelined
        accumulation (validated in tests)."""
        num_s = self.num_stages
        fwd_cache: Dict[Tuple[int, int], Any] = {}
        grads = [None] * num_s
        dlosses: Dict[int, jax.Array] = {}
        cot: Dict[Tuple[int, int], Any] = {}  # cotangent flowing backward
        losses = []
        for m, x in enumerate(microbatches):
            self._produce(0, m, x)

        for t in self.ticks:
            s, m = t.stage, t.microbatch
            if t.kind == "fwd":
                x = self._consume(s, m)
                y, vjp = jax.vjp(lambda p, xx: self.stage_fns[s](xx, p),
                                 params_stages[s], x)
                fwd_cache[(s, m)] = vjp
                self.stats["fwd"] += 1
                if s + 1 < num_s:
                    self._produce(s + 1, m, y)
                else:
                    loss, dloss = jax.value_and_grad(loss_fn)(y)
                    losses.append(loss)
                    dlosses[m] = dloss
            else:  # bwd
                if s == num_s - 1:
                    g_out = dlosses.pop(m)
                else:
                    g_out = cot.pop((s + 1, m))
                vjp = fwd_cache.pop((s, m))
                g_params, g_x = vjp(g_out)
                grads[s] = (g_params if grads[s] is None else
                            jax.tree_util.tree_map(jnp.add, grads[s], g_params))
                if s > 0:
                    cot[(s, m)] = g_x
                self.stats["bwd"] += 1
        return grads, jnp.mean(jnp.stack(losses))
