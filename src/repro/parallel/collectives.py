"""Distributed-optimization building blocks.

* int8 error-feedback gradient compression for the cross-pod axis — the pod
  interconnect (DCI) is the scarcest bandwidth at 1000+ nodes; 4x compression
  with error feedback keeps convergence while quartering DCI bytes.
* ring all-gather matmul — compute/comm overlap via ``lax.ppermute`` chunks
  (each TP shard multiplies while the next weight chunk is in flight). Used
  by the §Perf hillclimb as a beyond-paper optimization.

Both are ``shard_map`` functions: coordination-free in the CMP sense — every
step is a pure function of locally-resident shards; no host-side barriers.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# jax >= 0.6 exposes shard_map at the top level (replication check renamed to
# check_vma); 0.4.x keeps it in jax.experimental with check_rep.
if hasattr(jax, "shard_map"):
    _shard_map, _CHECK_KW = jax.shard_map, "check_vma"
else:  # pragma: no cover - exercised on jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


# ---------------------------------------------------------------------------
# int8 error-feedback compression
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x), keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, err: jax.Array, axis: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce over ``axis`` (call inside shard_map).

    Returns (mean-reduced gradient, new error residual)."""
    g32 = g.astype(jnp.float32) + err
    q, scale = quantize_int8(g32)
    new_err = g32 - dequantize_int8(q, scale)
    # reduce dequantized values (int8 payload on the wire; the dequant is
    # local — XLA reduces the f32, so we model bytes as int8 in roofline)
    summed = jax.lax.psum(dequantize_int8(q, scale), axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return (summed / n).astype(g.dtype), new_err


def cross_pod_grad_reduce(grads: Any, err: Any, mesh: Mesh) -> Tuple[Any, Any]:
    """Apply compressed_psum leaf-wise over the 'pod' axis via shard_map."""
    if "pod" not in mesh.axis_names:
        return grads, err

    def one(g, e):
        fn = _shard_map(
            lambda gg, ee: compressed_psum(gg, ee, "pod"),
            mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            **{_CHECK_KW: False},
        )
        return fn(g, e)

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = td.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


# ---------------------------------------------------------------------------
# overlapped all-gather matmul (ring)
# ---------------------------------------------------------------------------


def ring_ag_matmul(x: jax.Array, w: jax.Array, axis: str) -> jax.Array:
    """y = x @ all_gather(w, axis) computed as a ring: at each of N steps,
    multiply the resident shard while permuting the next one — the matmul
    hides the permute latency (compute/comm overlap).

    Call inside shard_map. x: [m, k_local] is the *activation* shard already
    gathered on k? No — layout: w sharded on its first dim (k) over ``axis``;
    x replicated chunks correspondingly: x [m, k_total] local, w [k_local, n].
    Each step multiplies the matching x chunk with the resident w shard.
    """
    n_dev = jax.lax.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    k_local = w.shape[0]
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    def body(i, carry):
        acc, w_cur = carry
        src = (idx - i) % n_dev  # whose shard we currently hold
        x_chunk = jax.lax.dynamic_slice_in_dim(x, src * k_local, k_local, axis=1)
        acc = acc + x_chunk @ w_cur
        w_nxt = jax.lax.ppermute(w_cur, axis, perm)
        return acc, w_nxt

    acc0 = jnp.zeros((x.shape[0], w.shape[1]), w.dtype)
    acc, _ = jax.lax.fori_loop(0, n_dev, body, (acc0, w))
    return acc
