"""musicgen-large [audio] — 48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192
vocab=2048. Decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

The EnCodec frontend is a STUB per the assignment: ``input_specs()`` provides
token ids in the EnCodec codebook vocabulary (2048); the codebook delay
pattern is flattened to a single stream (noted in DESIGN.md).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio", num_layers=48, d_model=2048,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=2048,
    head_dim=64, rope_theta=10000.0, block_pattern=("dense",),
    norm="layernorm", act="gelu", frontend="audio",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128, head_dim=16,
        block_pattern=("dense",), norm="layernorm", act="gelu",
        frontend="audio", dtype="float32", remat=False,
    )
