"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128e top-1. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

MoE layers interleave with dense layers (pattern dense,moe), matching the
published "every other layer routed" structure that lands total params near
400B with ~17B active (top-1 of 128 experts, expert_d_ff=8192).
The shared-expert path and early-fusion multimodality are not modeled (the
assignment specifies the LM backbone; early fusion enters via input embeddings).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", num_layers=48,
    d_model=5120, num_heads=40, num_kv_heads=8, d_ff=8192, vocab_size=202048,
    head_dim=128, rope_theta=500000.0, block_pattern=("dense", "moe"),
    num_experts=128, num_experts_per_tok=1, expert_d_ff=8192,
    optimizer_state_dtype="bfloat16",  # 400B params: bf16 moments (DESIGN.md)
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
        block_pattern=("dense", "moe"), num_experts=4, num_experts_per_tok=1,
        expert_d_ff=128, capacity_factor=4.0, dtype="float32", remat=False,
    )
