"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000. anyres tiling. [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Backbone only (mistral-7b); the vision tower is a STUB per the assignment:
``input_specs()`` provides precomputed anyres patch embeddings [B, n_img, D]
which the model prepends to the token embeddings.
"""

from repro.configs.base import ModelConfig

# 576 patches/tile x ~5 anyres tiles ≈ 2880 image-embedding positions.
NUM_IMAGE_EMBEDS = 2880

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=32000,
    head_dim=128, rope_theta=1000000.0, block_pattern=("dense",),
    frontend="vision",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-smoke", family="vlm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
        block_pattern=("dense",), frontend="vision", dtype="float32", remat=False,
    )
