"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16. Parallel attn+mamba heads. [arXiv:2411.13676; hf]

Attention heads use a sliding window (Hymba uses SWA in all but 3 layers; we
model all-SWA) so the decode state is O(window + ssm_state) => runs long_500k.
Hymba's learnable meta-tokens are not modeled (noted in DESIGN.md).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", num_layers=32, d_model=1600,
    num_heads=25, num_kv_heads=5, d_ff=5504, vocab_size=32001,
    head_dim=64, rope_theta=10000.0, block_pattern=("hymba",),
    ssm_state=16, ssm_heads=25, ssm_head_dim=64, sliding_window=1024,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
        block_pattern=("hymba",), ssm_state=4, ssm_heads=4, ssm_head_dim=16,
        sliding_window=16, dtype="float32", remat=False,
    )
