"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304.
sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

Constant-size recurrent state => runs long_500k (DESIGN.md §4). The paged-KV
CMP path is inapplicable (no KV cache); recurrent state uses a degenerate
2-slot pool (double buffering, window W=1) — noted inapplicability.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", num_layers=12, d_model=768,
    num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304,
    head_dim=192, block_pattern=("mlstm", "slstm"),
    ssm_heads=4, ssm_head_dim=192,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=512, head_dim=16,
        block_pattern=("mlstm", "slstm"), ssm_heads=4, ssm_head_dim=16,
        dtype="float32", remat=False,
    )
