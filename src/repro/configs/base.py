"""Config system: ModelConfig dataclass, input-shape registry, arch registry.

Every assigned architecture is a module in this package exposing ``CONFIG``
(the exact published shape) and ``smoke_config()`` (a reduced same-family
variant for CPU tests). Select with ``--arch <id>`` in the launchers.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # block structure: pattern repeated num_layers/len(pattern) times
    block_pattern: Tuple[str, ...] = ("dense",)
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    # attention details
    rope_theta: float = 10000.0
    sliding_window: int = 0          # 0 = full attention
    attn_softcap: float = 0.0
    # misc
    norm: str = "rmsnorm"
    act: str = "silu"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    frontend: Optional[str] = None   # vision | audio (stub embeddings)
    remat: bool = True
    attention_impl: str = "ref"      # ref | pallas
    ssd_chunk: int = 256
    # memory-efficient (online-softmax) cache attention: process the KV cache
    # in blocks of this size when S>1 and T>block (prefill); 0 disables.
    attn_chunk_kv: int = 1024
    # scan unroll knobs (dry-run cost extrapolation; see launch/dryrun.py)
    scan_unroll: int = 1         # layer scan
    time_scan_unroll: int = 1    # ssm/recurrent time scans
    attn_scan_unroll: int = 1    # chunked-attention KV scan
    # mesh axes carrying the batch dim (set by the launcher when lowering on
    # a mesh). The embedding gather's output sharding is ambiguous (token ids
    # want batch->data, embed columns want D->data); without an explicit
    # constraint GSPMD replicates the batch and attention computes 16x
    # redundant work (measured — see EXPERIMENTS.md §Perf iteration 1).
    batch_axes: Optional[Tuple[str, ...]] = None
    # --- beyond-paper optimization knobs (§Perf hillclimb) ---
    # dispatch MoE within token groups (gathers/sorts become group-local;
    # set to the number of data shards): 1 = global dispatch
    moe_groups: int = 1
    # constrain chunked-attention KV blocks to this mesh axis (prevents the
    # GSPMD involuntary full rematerialization when scanning a cache whose
    # time dim is sharded)
    kv_block_axis: Optional[str] = None
    # parameter sharding mode: "2d" (FSDP x TP), "tp" (replicate over data —
    # stationary weights for decode), "dp" (replicate over model — pure DP
    # for small models)
    param_mode: str = "2d"
    # shard recurrent state over this mesh axis (mLSTM value dim — makes the
    # time scan collective-free under TP; §Perf cell B)
    ssm_shard_axis: Optional[str] = None
    # optimizer memory policy (bf16 moments for very large models)
    optimizer_state_dtype: str = "float32"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def pattern_repeats(self) -> int:
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: num_layers {self.num_layers} not divisible by "
            f"pattern {self.block_pattern}")
        return self.num_layers // len(self.block_pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True if decode state does not grow with full context (may run
        long_500k)."""
        return self.family in ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCHS = [
    "glm4_9b", "yi_6b", "phi3_mini", "command_r_35b", "llama4_maverick",
    "granite_moe", "xlstm_125m", "hymba_1_5b", "llava_next", "musicgen_large",
]

# canonical ids as given in the assignment -> module names
_ALIASES = {
    "glm4-9b": "glm4_9b",
    "yi-6b": "yi_6b",
    "phi3-mini-3.8b": "phi3_mini",
    "command-r-35b": "command_r_35b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "granite-moe-3b-a800m": "granite_moe",
    "xlstm-125m": "xlstm_125m",
    "hymba-1.5b": "hymba_1_5b",
    "llava-next-mistral-7b": "llava_next",
    "musicgen-large": "musicgen_large",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod_name = _ALIASES.get(arch, arch.replace("-", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config() if smoke else mod.CONFIG


def cell_is_runnable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch x shape) is a runnable cell (DESIGN.md §4 skips)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md §4)"
    return True, ""
