"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
RoPE, GQA. [hf:THUDM/glm-4-9b; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense", num_layers=40, d_model=4096,
    num_heads=32, num_kv_heads=2, d_ff=13696, vocab_size=151552,
    head_dim=128, rope_theta=10000.0, block_pattern=("dense",),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        head_dim=16, block_pattern=("dense",), dtype="float32", remat=False,
    )
