"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40e top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", num_layers=32, d_model=1536,
    num_heads=24, num_kv_heads=8, d_ff=512, vocab_size=49155,
    head_dim=64, rope_theta=10000.0, block_pattern=("moe",),
    num_experts=40, num_experts_per_tok=8, expert_d_ff=512,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=512, head_dim=16,
        block_pattern=("moe",), num_experts=4, num_experts_per_tok=2,
        expert_d_ff=64, capacity_factor=4.0, dtype="float32", remat=False,
    )
