"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (GQA kv=32 = MHA) d_ff=8192
vocab=32064. RoPE SwiGLU. [arXiv:2404.14219; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense", num_layers=32, d_model=3072,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32064,
    head_dim=96, rope_theta=10000.0, block_pattern=("dense",),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
        head_dim=16, block_pattern=("dense",), dtype="float32", remat=False,
    )
