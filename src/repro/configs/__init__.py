from repro.configs.base import ARCHS, SHAPES, InputShape, ModelConfig, cell_is_runnable, get_config

__all__ = ["ARCHS", "SHAPES", "InputShape", "ModelConfig", "cell_is_runnable", "get_config"]
