"""CMP protection gauges, sampled from existing domain counters
(DESIGN.md §13).

Everything here is a read-only sweep over state the fabric already
maintains for correctness — the domain cycle clocks (``cycle`` −
``deque_cycle`` vs. the protection window W), the reclaim diagnostics,
the node-pool allocation counter, the device-ring depth properties and
the transport counters. A gauge sweep adds zero atomics and zero hot-path
work; like every diagnostic read in this repo it is approximate under
races and exact when quiesced.
"""

from __future__ import annotations

from typing import List


def sample_cmp_shard(q) -> dict:
    """One CMP shard's protection-domain view: window occupancy (how full
    the bounded protection window actually runs — the quantity
    bounded-memory designs argue about), reclaim progress/stall counters,
    and node-pool recycling."""
    cycle = q.cycle.load()
    dc = q.deque_cycle.load()
    occ = max(0, cycle - dc)
    return {
        "cycle": cycle,
        "deque_cycle": dc,
        "window": q.window,
        "occupancy": occ,
        "occupancy_frac": occ / q.window if q.window else 0.0,
        "pool_allocated": q.pool.allocated,
        **q.stats,  # enq_retries / deq_scans / reclaimed / reclaim_passes
                    # / reclaim_contended / rescued
    }


def sample_class_shards(qc) -> dict:
    """Per-class roll-up over its CMP shards: worst-case window occupancy
    (the shard closest to its protection bound), summed reclaim/rescue
    counters."""
    shards = [sample_cmp_shard(q) for q in qc.shards.queues]
    agg = {
        "class": qc.name,
        "num_shards": len(shards),
        "occupancy_frac_max": max((s["occupancy_frac"] for s in shards),
                                  default=0.0),
        "occupancy_total": sum(s["occupancy"] for s in shards),
        "pool_allocated": sum(s["pool_allocated"] for s in shards),
    }
    for key in ("enq_retries", "deq_scans", "reclaimed", "reclaim_passes",
                "reclaim_contended", "rescued"):
        agg[key] = sum(s.get(key, 0) for s in shards)
    return agg


def sample_admission_ring(ring) -> dict:
    """Device-admission ring depth + kernel-call amortization counters."""
    return {
        "capacity": ring.capacity,
        "pending": ring.pending,
        "buffered": ring.buffered,
        "room": ring.room,
        **ring.stats,  # steps / kernel_calls / pushed / claimed / rejected
    }


def sample_transport(transport, hub=None) -> dict:
    """Transport counters + (when a hub is attached) per-host RTT
    percentiles from the hub's histograms. Retries/drops are the
    transport's own counters — the retry half of the RTT/retry story."""
    out = dict(transport.stats())
    if hub is not None:
        out["rtt_ms"] = {
            host: {
                "p50": None if (p := w.percentile(50)) is None else p * 1e3,
                "p99": None if (p := w.percentile(99)) is None else p * 1e3,
                "count": w.count,
            }
            for host, w in sorted(hub.rtt.items())}
    return out


def sample_fabric_gauges(replica_set, engines=(), hub=None) -> dict:
    """One full gauge sweep over a fabric: per-class CMP protection view,
    per-engine admission-ring depth, transport RTT/retry. This is the dict
    the :class:`~repro.obs.hub.MetricsHub` appends to its rolling window."""
    sched = replica_set.scheduler
    act = getattr(sched, "active", None)
    # Tenant fabrics track an active-class set: sweep only classes that
    # currently hold work, so the gauge cost is O(active), not O(declared)
    # — a 10k-tenant grid with 100 hot groups samples ~300 classes, not
    # 30k. Without active tracking (act is None) sweep everything.
    classes = (sched.classes if act is None
               else [sched.by_name[n] for n in act.names()])
    out: dict = {
        "classes": {qc.name: sample_class_shards(qc) for qc in classes},
        "transport": sample_transport(replica_set.transport, hub),
        "pending": replica_set.pending(),
    }
    rings = {}
    for eng in engines:
        ring = getattr(eng, "_dev_admit", None)
        if ring is not None:
            rings[eng.sched.rid] = sample_admission_ring(ring)
    if rings:
        out["admission_rings"] = rings
    return out


def flatten_gauges(sample: dict, prefix: str = "obs") -> List[tuple]:
    """Flatten a gauge sweep into ``(dotted.key, value)`` pairs of plain
    numbers — the Prometheus exporter's input."""
    out: List[tuple] = []

    def walk(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{path}.{k}")
        elif isinstance(node, (int, float)) and not isinstance(node, bool):
            out.append((path, node))

    walk(sample, prefix)
    return out
