"""Observability plane: flight recorder, CMP protection gauges, exporters
(DESIGN.md §13).

Zero-added-atomics tracing and metrics over the whole fabric: per-replica
event rings with deterministic head-sampling (``trace_rate``), gauges read
from the domain counters the system already maintains, and exporters for
Chrome/Perfetto traces, Prometheus text exposition, and JSONL snapshots.
Wired end-to-end via ``FabricConfig(obs=ObsConfig(...))``; the
:class:`MetricsHub` rolling window is the future autoscaler's sensor
input (ROADMAP: closed-loop control plane).
"""

from repro.obs.export import (append_jsonl_snapshot, format_class_lines,
                              perfetto_trace, prometheus_text,
                              stage_breakdown, strip_samples)
from repro.obs.gauges import (flatten_gauges, sample_admission_ring,
                              sample_class_shards, sample_cmp_shard,
                              sample_fabric_gauges, sample_transport)
from repro.obs.hub import MetricsHub
from repro.obs.recorder import (CLAIM_BLOCK, COMPLETE, CONTROL,
                                CONTROL_EVENTS, DECODE, DRAIN, FLUSH,
                                LANE_PREFILL, LIFECYCLE_STAGES,
                                PRODUCER_RID, REQUEUE, RESCUE, SEAT,
                                SHARD_ENQUEUE, STEAL, SUBMIT, WINDOW_ADMIT,
                                FlightRecorder, ObsConfig, sample_stride)

__all__ = [
    "ObsConfig", "FlightRecorder", "MetricsHub", "sample_stride",
    "LIFECYCLE_STAGES", "CONTROL_EVENTS", "PRODUCER_RID",
    "SUBMIT", "WINDOW_ADMIT", "SHARD_ENQUEUE", "DRAIN", "SEAT",
    "LANE_PREFILL", "DECODE", "COMPLETE",
    "STEAL", "REQUEUE", "RESCUE", "CLAIM_BLOCK", "FLUSH", "CONTROL",
    "perfetto_trace", "prometheus_text", "stage_breakdown",
    "append_jsonl_snapshot", "strip_samples", "format_class_lines",
    "sample_cmp_shard", "sample_class_shards", "sample_admission_ring",
    "sample_transport", "sample_fabric_gauges", "flatten_gauges",
]
