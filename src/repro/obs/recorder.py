"""Flight recorder: per-replica lifecycle event rings (DESIGN.md §13).

The observability plane follows the same zero-added-atomics discipline as
the rest of the telemetry stack (``sched/stats.py``): event appends are
plain GIL-atomic list operations by whichever single thread owns the
emitting object (the drainer for drain-side stages, the producer for
submit-side stages), and reads are sampled diagnostic snapshots —
approximate under races, exact when quiesced. No lock, no atomic, no
allocation beyond one tuple per recorded event ever enters the hot path.

Head-sampling keeps the hot path O(1): the trace decision for an envelope
is a pure function of its class cycle — ``seq % every == 0`` with
``every = round(1 / trace_rate)`` — so every emit site along the lifecycle
agrees on which envelopes are traced *without the envelope carrying a trace
bit* (``Envelope`` is a ``__slots__`` dataclass; the sampling arithmetic is
cheaper than widening it). Control events (steals, rescues, device-ring
kernel calls, flushes) are rare by construction and always recorded.

Event tuples are ``(t, stage, cls, seq, rid, host, arg)`` — ``t`` from the
same monotonic clock as the admission-latency stamps, so exporter-built
spans and the latency reservoirs agree on durations.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Event taxonomy (DESIGN.md §13). The eight lifecycle stages, in envelope
# order; plus the control events. Stage names are the wire strings — emit
# sites outside this package (e.g. core/cmp.py, which must not import obs)
# use the literals, and these constants pin them.
# ---------------------------------------------------------------------------
SUBMIT = "submit"                # class-cycle stamp assigned (producer)
WINDOW_ADMIT = "window_admit"    # admission-window seat claimed (producer)
SHARD_ENQUEUE = "shard_enqueue"  # spliced into the home CMP shard (producer)
DRAIN = "drain"                  # claimed out of a shard by a drain loop
SEAT = "seat"                    # delivered at its exact FIFO seat
LANE_PREFILL = "lane_prefill"    # laned + prompt prefilled (serving)
DECODE = "decode"                # first decode token after prefill (serving)
COMPLETE = "complete"            # request finished (serving)

STEAL = "steal"                  # seat ownership claimed from a peer
REQUEUE = "requeue"              # preempted back to its class seat
RESCUE = "rescue"                # reclaim stole stalled-claimer data (Alg 4)
CLAIM_BLOCK = "claim_block"      # device-ring fused kernel invocation
FLUSH = "flush"                  # device-ring checkpoint/resize boundary
CONTROL = "control"              # control-plane decision (resize/weights)

LIFECYCLE_STAGES: Tuple[str, ...] = (
    SUBMIT, WINDOW_ADMIT, SHARD_ENQUEUE, DRAIN, SEAT,
    LANE_PREFILL, DECODE, COMPLETE)
CONTROL_EVENTS: Tuple[str, ...] = (STEAL, REQUEUE, RESCUE, CLAIM_BLOCK,
                                   FLUSH, CONTROL)

#: rid used for fabric-global (producer-side / shard-side) rings — events
#: emitted by code that is not pinned to one replica's drain loop.
PRODUCER_RID = -1


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability plane configuration (``FabricConfig(obs=...)``).

    Attributes:
      enabled: master switch; a disabled config wires nothing (emit sites
        pay one ``is None`` check).
      trace_rate: fraction of envelopes head-sampled into the flight
        recorder (1.0 = every envelope, 0.0 = lifecycle tracing off;
        control events are always recorded). The sampling decision is
        deterministic per class cycle, so every stage of a sampled
        envelope's life is captured.
      ring_capacity: events retained per recorder ring (oldest overwritten).
      metrics_window_s: rolling gauge-sample retention for the
        :class:`~repro.obs.hub.MetricsHub` window (the autoscaler's input).
      sample_every_n_steps: gauge-sweep cadence in ``Fabric.step`` calls.
      snapshot_path: optional JSONL file; when set, every gauge sweep also
        appends one snapshot line (``reports/…``-style periodic export).
    """

    enabled: bool = True
    trace_rate: float = 0.01
    ring_capacity: int = 4096
    metrics_window_s: float = 60.0
    sample_every_n_steps: int = 16
    snapshot_path: Optional[str] = None

    def validate(self) -> None:
        if not (0.0 <= self.trace_rate <= 1.0):
            raise ValueError(
                f"ObsConfig: trace_rate must be in [0, 1] "
                f"(got {self.trace_rate})")
        if self.ring_capacity < 1:
            raise ValueError(
                f"ObsConfig: ring_capacity must be >= 1 "
                f"(got {self.ring_capacity})")
        if self.metrics_window_s <= 0:
            raise ValueError(
                f"ObsConfig: metrics_window_s must be > 0 "
                f"(got {self.metrics_window_s})")
        if self.sample_every_n_steps < 1:
            raise ValueError(
                f"ObsConfig: sample_every_n_steps must be >= 1 "
                f"(got {self.sample_every_n_steps})")


def sample_stride(trace_rate: float) -> int:
    """trace_rate -> the deterministic head-sampling stride ``every``
    (0 disables tracing entirely)."""
    if trace_rate <= 0.0:
        return 0
    return max(1, int(round(1.0 / trace_rate)))


class FlightRecorder:
    """One fixed-size event ring (per replica, or the producer-side ring).

    Appends are plain list ops (GIL-atomic, single logical writer per
    emitting object); the ring never grows past ``capacity``. ``events()``
    returns an append-ordered snapshot for the exporters.
    """

    __slots__ = ("host", "rid", "capacity", "every", "_buf", "_idx",
                 "dropped", "counts")

    def __init__(self, config: ObsConfig, *, host: int = 0,
                 rid: int = PRODUCER_RID):
        self.host = int(host)
        self.rid = int(rid)
        self.capacity = int(config.ring_capacity)
        self.every = sample_stride(config.trace_rate)
        self._buf: List[tuple] = []
        self._idx = 0
        self.dropped = 0  # events overwritten by ring wrap
        self.counts: Dict[str, int] = {}  # per-stage emitted totals

    def sampled(self, seq: int) -> bool:
        """O(1) head-sampling decision, a pure function of the class cycle
        — every emit site along an envelope's lifecycle agrees."""
        e = self.every
        return e > 0 and seq % e == 0

    def emit(self, stage: str, cls: str, seq: int, *,
             t: Optional[float] = None, arg: Any = None) -> None:
        """Record one event. Callers gate on :meth:`sampled` for lifecycle
        stages; control events skip the gate (rare by construction)."""
        ev = (time.monotonic() if t is None else t,
              stage, cls, seq, self.rid, self.host, arg)
        buf = self._buf
        if len(buf) < self.capacity:
            buf.append(ev)
        else:
            self._buf[self._idx] = ev
            self._idx = (self._idx + 1) % self.capacity
            self.dropped += 1
        self.counts[stage] = self.counts.get(stage, 0) + 1

    def events(self) -> List[tuple]:
        """Append-ordered snapshot of the retained ring contents."""
        buf = self._buf
        i = self._idx
        return buf[i:] + buf[:i] if i else list(buf)

    def snapshot(self) -> dict:
        return {"rid": self.rid, "host": self.host,
                "retained": len(self._buf), "dropped": self.dropped,
                "counts": dict(self.counts)}
