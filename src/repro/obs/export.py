"""Exporters for the observability plane (DESIGN.md §13).

Three output formats, all built from the same flight-recorder event tuples
and gauge sweeps:

  * :func:`perfetto_trace` — Chrome/Perfetto ``trace.json`` (the Trace
    Event Format): each traced envelope becomes a chain of complete
    ("ph":"X") slices, one per lifecycle stage, whose duration is the time
    since the previous stage — so the trace viewer shows exactly where an
    envelope's time went (window wait vs. shard hop vs. steal vs. lane).
    Control events render as instants ("ph":"i"). pid = host, tid = replica.
  * :func:`prometheus_text` — Prometheus text exposition (``# HELP`` /
    ``# TYPE`` + samples) over the fabric stats dict and a gauge sweep.
  * :func:`append_jsonl_snapshot` — periodic JSONL snapshots (one JSON
    object per line, raw latency reservoirs stripped) into ``reports/``.

Plus :func:`stage_breakdown`, the measured per-stage latency table the
obs bench reports (where do the p99 milliseconds actually go?).
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

from repro.obs.recorder import CONTROL_EVENTS, LIFECYCLE_STAGES
from repro.sched.stats import _interp_percentile

_STAGE_ORDER = {s: i for i, s in enumerate(LIFECYCLE_STAGES)}


def _spans(events: List[tuple]) -> Dict[tuple, List[tuple]]:
    """Group lifecycle events by (cls, seq) and time-order each chain."""
    chains: Dict[tuple, List[tuple]] = {}
    for ev in events:
        if ev[1] in _STAGE_ORDER:
            chains.setdefault((ev[2], ev[3]), []).append(ev)
    for chain in chains.values():
        # same-timestamp stages (producer emits three in one clock read)
        # tie-break on lifecycle order so spans never go negative
        chain.sort(key=lambda ev: (ev[0], _STAGE_ORDER[ev[1]]))
    return chains


def perfetto_trace(events: List[tuple], *, path: Optional[str] = None
                   ) -> dict:
    """Flight-recorder events -> a Chrome/Perfetto Trace Event Format dict
    (written to ``path`` when given). Timestamps are microseconds relative
    to the earliest recorded event."""
    if events:
        t0 = min(ev[0] for ev in events)
    else:
        t0 = 0.0
    us = lambda t: (t - t0) * 1e6  # noqa: E731
    out: List[dict] = []
    for (cls, seq), chain in sorted(_spans(events).items()):
        prev_t = chain[0][0]
        for t, stage, _, _, rid, host, arg in chain:
            ev = {"name": stage, "ph": "X", "cat": cls,
                  "ts": round(us(prev_t), 3),
                  "dur": round((t - prev_t) * 1e6, 3),
                  "pid": host, "tid": rid,
                  "args": {"cls": cls, "seq": seq}}
            if arg is not None:
                ev["args"]["detail"] = arg
            out.append(ev)
            prev_t = t
    for t, stage, cls, seq, rid, host, arg in events:
        if stage in CONTROL_EVENTS:
            out.append({"name": stage, "ph": "i", "s": "t", "cat": cls,
                        "ts": round(us(t), 3), "pid": host, "tid": rid,
                        "args": {"cls": cls, "seq": seq, "detail": arg}})
    trace = {"traceEvents": out, "displayTimeUnit": "ms"}
    if path is not None:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(trace, f)
    return trace


def stage_breakdown(events: List[tuple]) -> Dict[str, dict]:
    """Per-stage latency table from the traced envelopes: for each adjacent
    lifecycle stage pair actually observed, the p50/p99/mean milliseconds
    spent *reaching* the later stage. The first measured answer to "where
    do the p99 admission milliseconds come from?"."""
    deltas: Dict[str, List[float]] = {}
    for chain in _spans(events).values():
        for (t0, s0, *_), (t1, s1, *_) in zip(chain, chain[1:]):
            deltas.setdefault(f"{s0}->{s1}", []).append(t1 - t0)
    out: Dict[str, dict] = {}
    for key, ds in sorted(deltas.items()):
        ds.sort()
        out[key] = {
            "n": len(ds),
            "p50_ms": _interp_percentile(ds, 50) * 1e3,
            "p99_ms": _interp_percentile(ds, 99) * 1e3,
            "mean_ms": sum(ds) / len(ds) * 1e3,
        }
    return out


def format_class_lines(stats, prefix: str = "[stats]") -> List[str]:
    """One compact human-readable line per class from the fabric stats —
    a :class:`~repro.fabric.stats.StatsView` or its ``to_json()`` dict —
    the serve.py ``--stats-interval`` heartbeat format."""
    if hasattr(stats, "to_json"):
        stats = stats.to_json()
    out = []
    for name, cs in sorted(stats.get("classes", {}).items()):
        slo = stats.get("slo", {}).get(name, {})
        p50, p99 = cs.get("admit_p50_ms"), cs.get("admit_p99_ms")
        fmt = lambda v: "-" if v is None else f"{v:.2f}"  # noqa: E731
        line = (f"{prefix} class {name}: submitted={cs.get('submitted', 0)} "
                f"delivered={cs.get('delivered', 0)} "
                f"rejected={cs.get('rejected', 0)} "
                f"requeued={cs.get('requeued', 0)} "
                f"pending={cs.get('pending', 0)} "
                f"p50_ms={fmt(p50)} p99_ms={fmt(p99)}")
        if slo.get("target_ms") is not None:
            line += f" slo_ok={slo.get('ok')}"
        out.append(line)
    return out


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

_COUNTER_KEYS = {
    "submitted", "rejected", "delivered", "requeued", "gap_waits",
    "enq_retries", "deq_scans", "reclaimed", "reclaim_passes",
    "reclaim_contended", "rescued", "steals", "stolen_cycles",
    "empty_drains", "remote_msgs", "remote_bytes", "drops", "delayed",
    "reordered", "retransmits", "remote_claims", "fetches", "publishes",
    "kernel_calls", "pushed", "claimed", "steps", "dropped", "count",
    "pool_allocated", "shed",
}


def _prom_name(key: str) -> str:
    return "repro_" + key.replace(".", "_").replace("-", "_")


def prometheus_text(stats, gauges: Optional[dict] = None) -> str:
    """Fabric stats (+ optional gauge sweep) -> Prometheus text exposition.

    ``stats`` is a :class:`~repro.fabric.stats.StatsView` or its
    ``to_json()`` dict. Per-class series carry a ``{cls="..."}`` label;
    everything else flattens to dotted metric names. Counters (monotone
    totals) are typed ``counter``, the rest ``gauge``.
    """
    from repro.obs.gauges import flatten_gauges

    if hasattr(stats, "to_json"):
        stats = stats.to_json()

    series: List[tuple] = []  # (name, labels, value, prom_type)

    def add(path: str, value, labels: str = "") -> None:
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            return
        typ = "counter" if path.split(".")[-1] in _COUNTER_KEYS else "gauge"
        series.append((_prom_name(path), labels, value, typ))

    for name, cs in stats.get("classes", {}).items():
        label = f'{{cls="{name}"}}'
        for key, val in cs.items():
            if key in ("class", "name", "shard_depths", "latency_samples"):
                continue
            typ = "counter" if key in _COUNTER_KEYS else "gauge"
            if isinstance(val, (int, float)) and not isinstance(val, bool):
                series.append((_prom_name(f"class_{key}"), label, val, typ))
    for name, slo in stats.get("slo", {}).items():
        label = f'{{cls="{name}"}}'
        for key in ("target_ms", "admit_p99_ms", "headroom_ms"):
            val = slo.get(key)
            if isinstance(val, (int, float)) and not isinstance(val, bool):
                series.append((_prom_name(f"slo_{key}"), label, val, "gauge"))
    for key, val in stats.get("transport", {}).items():
        if key == "rtt_ms" and isinstance(val, dict):
            # per-dest-host RTT percentiles from the obs hub (the wire
            # transport and rtt-injected sim both feed record_rtt)
            for host, pct in val.items():
                if not isinstance(pct, dict):
                    continue
                for q in ("p50", "p99"):
                    v = pct.get(q)
                    if isinstance(v, (int, float)) \
                            and not isinstance(v, bool):
                        series.append((
                            _prom_name("transport_rtt_ms"),
                            f'{{host="{host}",quantile="{q}"}}', v, "gauge"))
                n = pct.get("count")
                if isinstance(n, (int, float)) and not isinstance(n, bool):
                    series.append((_prom_name("transport_rtt_count"),
                                   f'{{host="{host}"}}', n, "counter"))
            continue
        if isinstance(val, (int, float)) and not isinstance(val, bool):
            typ = "counter" if key in _COUNTER_KEYS else "gauge"
            series.append((_prom_name(f"transport_{key}"), "", val, typ))
    for key in ("step", "num_replicas", "resizes"):
        if key in stats:
            series.append((_prom_name(key), "", stats[key], "gauge"))
    tenants = stats.get("tenants") or {}
    for key in ("declared", "groups", "tracked", "active_backlog",
                "active_classes", "shed_total"):
        val = tenants.get(key)
        if isinstance(val, (int, float)) and not isinstance(val, bool):
            typ = "counter" if key == "shed_total" else "gauge"
            series.append((_prom_name(f"tenants_{key}"), "", val, typ))
    for tot_key, tot_val in (tenants.get("totals") or {}).items():
        if isinstance(tot_val, (int, float)) and not isinstance(tot_val, bool):
            series.append((_prom_name(f"tenants_total_{tot_key}"), "",
                           tot_val, "counter"))
    obs = stats.get("obs", {})
    for rid, rec in obs.get("recorders", {}).items():
        label = f'{{rid="{rid}"}}'
        series.append((_prom_name("obs_events_dropped"), label,
                       rec.get("dropped", 0), "counter"))
        for stage, n in rec.get("counts", {}).items():
            series.append((_prom_name("obs_events_total"),
                           f'{{rid="{rid}",stage="{stage}"}}', n, "counter"))
    if gauges:
        for path, value in flatten_gauges(gauges):
            add(path.replace("obs.", "", 1), value)

    # The exposition format wants every line of one metric in a single
    # contiguous group; dedupe (name, labels) — e.g. transport counters
    # appear in both the stats dict and the gauge sweep — keeping the first.
    grouped: Dict[str, List[tuple]] = {}
    types: Dict[str, str] = {}
    seen_sample = set()
    for name, labels, value, typ in series:
        if (name, labels) in seen_sample:
            continue
        seen_sample.add((name, labels))
        grouped.setdefault(name, []).append((labels, value))
        types.setdefault(name, typ)
    lines: List[str] = []
    for name, samples in grouped.items():
        lines.append(f"# HELP {name} repro fabric metric")
        lines.append(f"# TYPE {name} {types[name]}")
        for labels, value in samples:
            v = f"{value:.9g}" if isinstance(value, float) else str(value)
            lines.append(f"{name}{labels} {v}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# JSONL snapshots
# ---------------------------------------------------------------------------

def strip_samples(obj):
    """Deep-copy ``obj`` without raw latency reservoirs (they are exact-
    merge plumbing, not snapshot payload — DESIGN.md §13 size convention)."""
    if isinstance(obj, dict):
        return {k: strip_samples(v) for k, v in obj.items()
                if k != "latency_samples"}
    if isinstance(obj, (list, tuple)):
        return [strip_samples(v) for v in obj]
    return obj


def append_jsonl_snapshot(path: str, snapshot: dict, *,
                          t: Optional[float] = None) -> None:
    """Append one snapshot line to a JSONL file (parents created)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    rec = {"t": time.time() if t is None else t, **strip_samples(snapshot)}
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
