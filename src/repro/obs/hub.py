"""Fabric-wide metrics hub (DESIGN.md §13).

The :class:`MetricsHub` is the one object the rest of the system talks to:

  * it owns the per-replica :class:`~repro.obs.recorder.FlightRecorder`
    rings (plus the producer-side ring) and hands them out at attach time;
  * it keeps the per-host transport **RTT histograms** (fed by the
    transport's remote-op timing when a hub is attached);
  * it maintains a **rolling window** of timestamped gauge sweeps — the
    future autoscaler's input: a controller reads ``hub.window()`` and
    gets the last ``metrics_window_s`` seconds of protection-window
    occupancy, queue depth, ring depth and RTT without touching the fabric.

Attachment is post-construction and idempotent: emitting objects carry a
class-level ``_obs = None`` default (so un-attached fabrics pay one
``is None`` check), and :meth:`attach` re-walks the object graph after any
operation that rebuilds replicas or engines (open / restore / resize /
fail_host).
"""

from __future__ import annotations

import time
from collections import deque
from typing import Deque, Dict, List, Tuple

from repro.obs.gauges import sample_fabric_gauges
from repro.obs.recorder import PRODUCER_RID, FlightRecorder, ObsConfig
from repro.sched.stats import LatencyWindow


class MetricsHub:
    def __init__(self, config: ObsConfig):
        config.validate()
        self.config = config
        self._recorders: Dict[int, FlightRecorder] = {}
        self.rtt: Dict[int, LatencyWindow] = {}  # dest host -> histogram
        self._window: Deque[Tuple[float, dict]] = deque()
        self.samples_taken = 0

    # ---------------------------------------------------------- recorders
    def recorder(self, rid: int = PRODUCER_RID, host: int = 0
                 ) -> FlightRecorder:
        rec = self._recorders.get(rid)
        if rec is None:
            rec = self._recorders[rid] = FlightRecorder(
                self.config, host=host, rid=rid)
        return rec

    def events(self) -> List[tuple]:
        """All retained events across every ring, time-ordered — the
        exporters' input."""
        out: List[tuple] = []
        for rec in self._recorders.values():
            out.extend(rec.events())
        out.sort(key=lambda ev: ev[0])
        return out

    # ---------------------------------------------------------------- RTT
    def record_rtt(self, host: int, seconds: float) -> None:
        """One remote-op round trip to ``host`` (called by the transport's
        remote paths when a hub is attached — never on home-host ops)."""
        w = self.rtt.get(host)
        if w is None:
            w = self.rtt[host] = LatencyWindow(1024)
        w.record(seconds)

    # --------------------------------------------------------- attachment
    def attach(self, replica_set, engines=()) -> None:
        """(Re-)wire every emit site of a fabric to this hub's recorders.
        Idempotent; call after any operation that rebuilds replicas or
        engines (open / restore / resize / fail_host)."""
        producer = self.recorder(PRODUCER_RID)
        for qc in replica_set.scheduler.classes:
            qc._obs = producer
            for q in qc.shards.queues:
                q._obs = producer
                q._obs_cls = qc.name
        for r in replica_set.replicas:
            rec = self.recorder(r.rid, r.addr.host)
            r._obs = rec
            for v in r.views:
                v._obs = rec
        replica_set.transport._obs = self
        for eng in engines:
            rec = self.recorder(eng.sched.rid, eng.sched.addr.host)
            eng._obs = rec
            ring = getattr(eng, "_dev_admit", None)
            if ring is not None:
                ring._obs = rec

    # ------------------------------------------------------ rolling window
    def sample(self, replica_set, engines=()) -> dict:
        """One gauge sweep, appended to the rolling window (older samples
        past ``metrics_window_s`` drop off the front)."""
        now = time.monotonic()
        sweep = sample_fabric_gauges(replica_set, engines, hub=self)
        self._window.append((now, sweep))
        self.samples_taken += 1
        horizon = now - self.config.metrics_window_s
        while self._window and self._window[0][0] < horizon:
            self._window.popleft()
        return sweep

    def window(self) -> List[Tuple[float, dict]]:
        """The retained (timestamp, gauge-sweep) samples, oldest first."""
        return list(self._window)

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> dict:
        """The ``Fabric.stats_view().obs`` view: recorder ring health +
        per-stage event totals, RTT percentiles, rolling-window extent,
        and the latest gauge sweep (when one has been taken)."""
        counts: Dict[str, int] = {}
        for rec in self._recorders.values():
            for stage, n in rec.counts.items():
                counts[stage] = counts.get(stage, 0) + n
        out = {
            "trace_rate": self.config.trace_rate,
            "events_total": counts,
            "recorders": {rid: rec.snapshot()
                          for rid, rec in sorted(self._recorders.items())},
            "rtt_ms": {
                host: {"p50": None if (p := w.percentile(50)) is None
                       else p * 1e3,
                       "p99": None if (p := w.percentile(99)) is None
                       else p * 1e3,
                       "count": w.count}
                for host, w in sorted(self.rtt.items())},
            "window": {"samples": len(self._window),
                       "span_s": (self._window[-1][0] - self._window[0][0]
                                  if len(self._window) > 1 else 0.0),
                       "taken": self.samples_taken},
        }
        if self._window:
            out["gauges"] = self._window[-1][1]
        return out
