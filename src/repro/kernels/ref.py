"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_flash_attention(q, k, v, *, causal=True, sliding_window=0):
    """q [B,H,S,hd]; k,v [B,KV,T,hd] -> [B,H,S,hd]."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    rep = H // KV
    kx = jnp.tile(k, (1, rep, 1, 1))  # r-major GQA: head h -> kv h % KV
    vx = jnp.tile(v, (1, rep, 1, 1))
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kx.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = mask & (q_pos >= k_pos)
    if sliding_window > 0:
        mask = mask & (q_pos - k_pos < sliding_window)
    s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w, vx.astype(jnp.float32)).astype(q.dtype)


def ref_paged_attention(q, k_pages, v_pages, block_tables, seq_lens):
    """Decode attention over paged KV.

    q [B, H, hd]; k/v_pages [P, KV, page, hd]; block_tables [B, pages_per_seq]
    (entries index into P; -pad with 0 beyond seq); seq_lens [B].
    """
    B, H, hd = q.shape
    P, KV, page, _ = k_pages.shape
    pages_per_seq = block_tables.shape[1]
    rep = H // KV
    # gather each sequence's pages -> [B, KV, pages_per_seq*page, hd]
    kg = k_pages[block_tables]  # [B, pps, KV, page, hd]
    vg = v_pages[block_tables]
    kg = jnp.moveaxis(kg, 2, 1).reshape(B, KV, pages_per_seq * page, hd)
    vg = jnp.moveaxis(vg, 2, 1).reshape(B, KV, pages_per_seq * page, hd)
    kg = jnp.tile(kg, (1, rep, 1, 1))  # r-major GQA
    vg = jnp.tile(vg, (1, rep, 1, 1))
    s = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32), kg.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    valid = jnp.arange(pages_per_seq * page)[None, :] < seq_lens[:, None]
    s = jnp.where(valid[:, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,bhtd->bhd", w, vg.astype(jnp.float32)).astype(q.dtype)


def ref_ring_step(state, cycle, meta, req, *, k, window,
                  free=0, available=1, claimed=2):
    """Oracle for the fused admission-ring step (kernels/cmp_ring.py): window
    reclaim + batched ring enqueue (contiguous prefix accept) + k-way
    earliest-claim + monotone frontier publish, in pure jnp. Bit-identical to
    the Pallas kernel; also serves as the compiled fast path on hosts without
    a TPU. Returns (state', cycle', meta', claimed_cycles[k])."""
    imax = jnp.iinfo(jnp.int32).max
    n = state.shape[0]
    enq, dc = meta[0], meta[1]
    push_n = jnp.minimum(req[0], n)
    want = req[1]
    idx = jnp.arange(n, dtype=jnp.int32)

    freeable = (state == claimed) & (cycle < dc - window)
    state = jnp.where(freeable, free, state)

    off = jnp.mod(idx - enq, n)
    blocked = (off < push_n) & (state != free)
    accepted = jnp.min(jnp.where(blocked, off, push_n))
    take = off < accepted
    state = jnp.where(take, available, state)
    cycle = jnp.where(take, enq + 1 + off, cycle)

    # Live ring cycles are unique, so the cascade's ascending-cycle claim
    # order is exactly the sorted order of the AVAILABLE keys — a full sort
    # plus threshold-select, which XLA CPU runs ~6x faster than top_k at
    # ring sizes (top_k degenerates toward O(n*k) there).
    key = jnp.where(state == available, cycle, imax)
    sorted_keys = jnp.sort(key)
    lane = jnp.arange(k)
    take = jnp.minimum(want, jnp.minimum(jnp.sum(key != imax), k))
    threshold = sorted_keys[jnp.maximum(take - 1, 0)]
    sel = (key != imax) & (key <= threshold) & (take > 0)
    claimed_cycles = jnp.where(lane < take, sorted_keys[:k], -1).astype(jnp.int32)
    state = jnp.where(sel, claimed, state)
    max_claimed = jnp.max(jnp.where(lane < take, claimed_cycles, dc))
    new_meta = jnp.stack([enq + accepted,
                          jnp.maximum(dc, max_claimed)]).astype(jnp.int32)
    return state, cycle, new_meta, claimed_cycles


def ref_claim(state, cycle, k, available=1, claimed=2):
    """Claim the k earliest-cycle AVAILABLE slots. Returns (new_state, ids,
    valid) — ids==n for invalid lanes (matches slotpool semantics)."""
    n = state.shape[0]
    key = jnp.where(state == available, cycle, jnp.iinfo(jnp.int32).max)
    neg, ids = jax.lax.top_k(-key, k)
    valid = neg != -jnp.iinfo(jnp.int32).max
    ids = jnp.where(valid, ids, n).astype(jnp.int32)
    new_state = state.at[ids].set(claimed, mode="drop")
    return new_state, ids, valid
