"""Paged decode attention over CMP-managed KV blocks (Pallas TPU kernel).

The serving engine stores KV in fixed-size pages whose lifecycle is governed
by the CMP slot pool (core/slotpool.py): pages are produced (allocated) with
monotone cycles, retired when a request finishes, and reclaimed only outside
the protection window — so a page referenced by an in-flight decode step can
never be recycled underneath it (the paper's UAF guarantee, transplanted).

TPU adaptation: instead of CUDA-style gather loads, the page indirection uses
*scalar prefetch* — block tables are SMEM-prefetched scalars consumed by the
BlockSpec index_map, so the pipeline DMAs exactly the pages each sequence
needs from HBM into VMEM. The last grid axis (pages) iterates sequentially,
carrying the online-softmax state in VMEM scratch.

Layouts: q [B, H, hd] (one decode token); k/v pages [P, KV, page, hd];
block_tables [B, pages_per_seq] int32; seq_lens [B] int32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(bt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                  l_ref, *, page: int, sm_scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = sl_ref[b]

    @pl.when(p * page < seq_len)
    def _compute():
        q = q_ref[0, 0].reshape(1, -1).astype(jnp.float32)       # [1, hd]
        k = k_ref[0, 0].astype(jnp.float32)                      # [page, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale  # [1, page]
        pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        mask = pos < seq_len
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        pr = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pr, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(pr, v)
        m_ref[...] = m_new

    @pl.when(p == np_ - 1)
    def _out():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).reshape(
            o_ref.shape[2:]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(
    q: jax.Array,             # [B, H, hd]
    k_pages: jax.Array,       # [P, KV, page, hd]
    v_pages: jax.Array,       # [P, KV, page, hd]
    block_tables: jax.Array,  # [B, pages_per_seq] int32 (pad with any valid id)
    seq_lens: jax.Array,      # [B] int32
    *,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    P, KV, page, _ = k_pages.shape
    pps = block_tables.shape[1]
    rep = H // KV
    sm_scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_paged_kernel, page=page, sm_scale=sm_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, pps),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, p, bt, sl: (b, h, 0)),
            pl.BlockSpec((1, 1, page, hd),
                         lambda b, h, p, bt, sl: (bt[b, p], h % KV, 0, 0)),
            pl.BlockSpec((1, 1, page, hd),
                         lambda b, h, p, bt, sl: (bt[b, p], h % KV, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, p, bt, sl: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, q, k_pages, v_pages)
