"""Flash attention (causal, GQA, optional sliding window) as a Pallas TPU
kernel.

TPU adaptation (not a CUDA port): the grid's last dimension iterates
*sequentially* on a TensorCore, so the online-softmax running state (m, l,
acc) lives in VMEM scratch carried across the K-block axis — no atomics, no
shared-memory tile sync. Block shapes default to 128 (MXU-aligned); the
K/V working set per step is one [block_k, head_dim] tile in VMEM.

Layouts: q [B, H, S, hd]; k/v [B, KV, T, hd]. GQA maps query head h to KV
head h // (H // KV) in the BlockSpec index_map (no KV replication in HBM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, causal: bool, sliding_window: int,
                  block_q: int, block_k: int, true_s: int, true_t: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Skip fully-masked K blocks (beyond the causal diagonal / window).
    run = jnp.bool_(True)
    if causal:
        run = run & (ik * block_k <= iq * block_q + block_q - 1)
    if sliding_window > 0:
        run = run & ((iq * block_q) - (ik * block_k + block_k - 1) < sliding_window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [block_q, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [block_k, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale
        mask = (k_pos < true_t) & (q_pos < true_s)
        if causal:
            mask = mask & (q_pos >= k_pos)
        if sliding_window > 0:
            mask = mask & (q_pos - k_pos < sliding_window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # [block_q, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        correction = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * correction + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _out():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, H, S, hd]
    k: jax.Array,  # [B, KV, T, hd]
    v: jax.Array,  # [B, KV, T, hd]
    *,
    causal: bool = True,
    sliding_window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    rep = H // KV  # GQA r-major: query head h reads KV head h % KV
    sm_scale = 1.0 / (hd ** 0.5)

    pad_q = (-S) % block_q
    pad_k = (-T) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sp, Tp = S + pad_q, T + pad_k
    grid = (B, H, Sp // block_q, Tp // block_k)

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal,
        sliding_window=sliding_window, block_q=block_q, block_k=block_k,
        true_s=S, true_t=T,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h % KV, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h % KV, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
