"""Fused CMP claim (Pallas kernel): earliest-cycle AVAILABLE slot selection +
state transition in one VMEM pass.

This is the device analogue of the paper's dequeue Phases 1-2 (scan-cursor
probe + claim CAS): a deterministic k-way earliest-claim over the slot state
and cycle arrays. Fusing select+transition avoids materializing the masked
key array and the separate scatter XLA would emit (3 HBM round-trips -> 1).

VMEM constraint: the whole pool (state+cycle, 8 bytes/slot) must fit one VMEM
block — pools up to ~1M slots, far beyond any practical page pool.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.slotpool import AVAILABLE, CLAIMED

_INT_MAX = jnp.iinfo(jnp.int32).max


def _claim_kernel(state_ref, cycle_ref, new_state_ref, ids_ref, *, k: int, n: int):
    state = state_ref[...].reshape(1, n)
    cycle = cycle_ref[...].reshape(1, n)
    key = jnp.where(state == AVAILABLE, cycle, _INT_MAX)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    new_state = state
    ids = jnp.zeros((k,), jnp.int32)
    for i in range(k):  # k is small & static: unrolled argmin cascade
        m = jnp.min(key)
        # lowest index among minima (deterministic tie-break)
        idx = jnp.min(jnp.where(key == m, iota, _INT_MAX))
        found = m != _INT_MAX
        take = found & (iota == idx)
        new_state = jnp.where(take, CLAIMED, new_state)
        key = jnp.where(take, _INT_MAX, key)
        ids = ids.at[i].set(jnp.where(found, idx, n).astype(jnp.int32))
    new_state_ref[...] = new_state.reshape(n)
    ids_ref[...] = ids


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def cmp_claim(state: jax.Array, cycle: jax.Array, *, k: int,
              interpret: bool = False):
    """Returns (new_state [N], ids [k]); ids==N marks invalid (pool empty)."""
    n = state.shape[0]
    kernel = functools.partial(_claim_kernel, k=k, n=n)
    new_state, ids = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        interpret=interpret,
    )(state, cycle)
    return new_state, ids
