"""Fused CMP claim (Pallas kernel): earliest-cycle AVAILABLE slot selection +
state transition, tiled over a grid so the pool may exceed one VMEM block.

This is the device analogue of the paper's dequeue Phases 1-2 (scan-cursor
probe + claim CAS): a deterministic k-way earliest-claim over the slot state
and cycle arrays. Two paths:

* single-block (pool fits one VMEM tile): one fused pass computes the k-way
  argmin cascade and the AVAILABLE -> CLAIMED transition in VMEM, avoiding
  the masked key materialization and the separate scatter XLA would emit
  (3 HBM round-trips -> 1);
* tiled (pool larger than one tile): a ``pl.pallas_call`` grid runs the same
  k-way cascade per block, emitting each block's k best (cycle, id)
  candidates; any global winner is necessarily among its block's local top-k,
  so a cross-block lexicographic merge of ``num_blocks x k`` candidates
  (tiny, O(k) per block) recovers the exact global earliest-claim order,
  ties broken by lowest id — bit-identical to the single-block kernel and
  the ``kernels/ref.py`` oracle.

State constants come from the unified protection domain
(:mod:`repro.core.domain`), the same definitions the host queue uses.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.domain import AVAILABLE, CLAIMED

_INT_MAX = jnp.iinfo(jnp.int32).max

# Default tile: state+cycle at 8 bytes/slot -> 16 KiB per block, a lane-
# aligned slice that leaves VMEM headroom for the double-buffered grid.
_DEFAULT_BLOCK = 2048


def _claim_kernel(state_ref, cycle_ref, new_state_ref, ids_ref, *, k: int, n: int):
    """Single-block fused path: k-way cascade + state transition in VMEM."""
    state = state_ref[...].reshape(1, n)
    cycle = cycle_ref[...].reshape(1, n)
    key = jnp.where(state == AVAILABLE, cycle, _INT_MAX)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    new_state = state
    ids = jnp.zeros((k,), jnp.int32)
    for i in range(k):  # k is small & static: unrolled argmin cascade
        m = jnp.min(key)
        # lowest index among minima (deterministic tie-break)
        idx = jnp.min(jnp.where(key == m, iota, _INT_MAX))
        found = m != _INT_MAX
        take = found & (iota == idx)
        new_state = jnp.where(take, CLAIMED, new_state)
        key = jnp.where(take, _INT_MAX, key)
        ids = ids.at[i].set(jnp.where(found, idx, n).astype(jnp.int32))
    new_state_ref[...] = new_state.reshape(n)
    ids_ref[...] = ids


def _claim_block_kernel(state_ref, cycle_ref, cand_cycle_ref, cand_id_ref,
                        *, k: int, block_n: int, n: int):
    """Tiled path, per-grid-block body: local k-way min over this tile,
    emitting the k best (cycle, global id) candidates for the merge."""
    b = pl.program_id(0)
    state = state_ref[...].reshape(1, block_n)
    cycle = cycle_ref[...].reshape(1, block_n)
    gids = jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1) + b * block_n
    # Padding lanes (gids >= n) were materialized as CLAIMED by the wrapper,
    # but mask them here too so the kernel is safe for any input.
    key = jnp.where((state == AVAILABLE) & (gids < n), cycle, _INT_MAX)
    cand_c, cand_i = [], []
    for _ in range(k):
        m = jnp.min(key)
        idx = jnp.min(jnp.where(key == m, gids, _INT_MAX))
        found = m != _INT_MAX
        take = found & (gids == idx)
        key = jnp.where(take, _INT_MAX, key)
        cand_c.append(jnp.where(found, m, _INT_MAX))
        cand_i.append(jnp.where(found, idx, n).astype(jnp.int32))
    cand_cycle_ref[...] = jnp.stack(cand_c).reshape(1, k)
    cand_id_ref[...] = jnp.stack(cand_i).reshape(1, k)


def _cmp_claim_tiled(state, cycle, *, k: int, block_n: int, interpret: bool):
    n = state.shape[0]
    nb = -(-n // block_n)  # cdiv
    pad = nb * block_n - n
    state_p = jnp.pad(state, (0, pad), constant_values=CLAIMED) if pad else state
    cycle_p = jnp.pad(cycle, (0, pad)) if pad else cycle
    kernel = functools.partial(_claim_block_kernel, k=k, block_n=block_n, n=n)
    cand_c, cand_i = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
        ],
        interpret=interpret,
    )(state_p.reshape(nb, block_n), cycle_p.reshape(nb, block_n))
    # Cross-block merge: global order is lexicographic (cycle, id) ascending —
    # identical to the fused kernel's cascade and lax.top_k's tie-breaking.
    flat_c = cand_c.reshape(-1)
    flat_i = cand_i.reshape(-1)
    order = jnp.lexsort((flat_i, flat_c))
    sel = order[:k]
    ids = jnp.where(flat_c[sel] != _INT_MAX, flat_i[sel], n).astype(jnp.int32)
    new_state = state.at[ids].set(CLAIMED, mode="drop")  # ids==n dropped
    return new_state, ids


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def cmp_claim(state: jax.Array, cycle: jax.Array, *, k: int,
              block_n: Optional[int] = None, interpret: bool = False):
    """Returns (new_state [N], ids [k]); ids==N marks invalid (pool empty).

    Pools up to ``block_n`` slots take the single fused VMEM pass; larger
    pools take the tiled grid (block-local k-way min + cross-block merge).
    """
    n = state.shape[0]
    bn = block_n or _DEFAULT_BLOCK
    if n > bn:
        return _cmp_claim_tiled(state, cycle, k=k, block_n=bn,
                                interpret=interpret)
    kernel = functools.partial(_claim_kernel, k=k, n=n)
    new_state, ids = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        interpret=interpret,
    )(state, cycle)
    return new_state, ids
