"""Device-resident CMP admission ring (Pallas kernel, DESIGN.md §12).

A bounded ring of ``N`` slots living on the accelerator, carrying the CMP
protection domain (:mod:`repro.core.domain` constants) in two int32 arrays
(``state``, ``cycle``) plus a 2-word ``meta`` vector ``[enq_cycle,
deque_cycle]``. One fused kernel invocation — ``cmp_ring_step`` — runs a whole
admission step without a host sync:

* stage R (paper Alg 4): window reclaim — ``CLAIMED`` slots whose cycle fell
  behind ``deque_cycle - W`` return to ``FREE``;
* stage E (paper Alg 1, Phases 1-2): batched enqueue — the ``push_n`` new
  items take the contiguous cycle range ``[enq+1, enq+push_n]``; slot for
  cycle ``c`` is ``(c-1) mod N``, and the *contiguous prefix* whose slots are
  FREE is accepted (stopping at the first occupied slot preserves FIFO cycle
  assignment: no holes in the accepted range). Rejected suffixes fall back to
  the host path;
* stage C (paper Alg 3, Phases 1-3): the k-way earliest-cycle claim cascade —
  the same unrolled argmin cascade as :mod:`repro.kernels.cmp_claim` — claims
  up to ``want`` AVAILABLE slots in cycle order;
* stage P (paper Alg 3, Phase 5): monotone frontier publish,
  ``deque_cycle' = max(deque_cycle, max claimed cycle)``.

The payload handle IS the cycle number (unique, monotone), so the kernel
returns claimed *cycles*; the host keeps an authoritative cycle -> envelope
mirror (see :mod:`repro.serving.admission`).

``ref.ref_ring_step`` is the bit-exact pure-jnp oracle; it doubles as the
fast compiled path on hosts without a TPU (host-fallback rules: DESIGN.md
§12).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.domain import AVAILABLE, CLAIMED, FREE

_INT_MAX = jnp.iinfo(jnp.int32).max


def _ring_kernel(state_ref, cycle_ref, meta_ref, req_ref,
                 new_state_ref, new_cycle_ref, new_meta_ref, claimed_ref,
                 *, k: int, n: int, window: int):
    state = state_ref[...].reshape(1, n)
    cycle = cycle_ref[...].reshape(1, n)
    enq = meta_ref[0]
    dc = meta_ref[1]
    push_n = req_ref[0]
    want = req_ref[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)

    # Stage R: window reclaim (Alg 4) — monotone, coordination-free.
    freeable = (state == CLAIMED) & (cycle < dc - window)
    state = jnp.where(freeable, FREE, state)

    # Stage E: batched enqueue (Alg 1). Slot j hosts candidate cycle
    # enq+1+off_j with off_j = (j - enq) mod n; accept the contiguous
    # offset prefix whose slots are FREE.
    off = jnp.mod(iota - enq, n)
    blocked = (off < push_n) & (state != FREE)
    accepted = jnp.min(jnp.where(blocked, off, push_n))
    take = off < accepted
    state = jnp.where(take, AVAILABLE, state)
    cycle = jnp.where(take, enq + 1 + off, cycle)

    # Stage C: k-way earliest-claim cascade (Alg 3 Phases 1-3), masked to
    # the first `want` lanes. k is small & static: unrolled.
    key = jnp.where(state == AVAILABLE, cycle, _INT_MAX)
    claimed = jnp.full((k,), -1, jnp.int32)
    max_claimed = dc
    for i in range(k):
        m = jnp.min(key)
        idx = jnp.min(jnp.where(key == m, iota, _INT_MAX))
        found = (m != _INT_MAX) & (i < want)
        tk = found & (iota == idx)
        state = jnp.where(tk, CLAIMED, state)
        key = jnp.where(tk, _INT_MAX, key)
        claimed = claimed.at[i].set(jnp.where(found, m, -1))
        max_claimed = jnp.where(found, jnp.maximum(max_claimed, m), max_claimed)

    # Stage P: monotone frontier publish (Alg 3 Phase 5).
    new_meta_ref[0] = enq + accepted
    new_meta_ref[1] = max_claimed
    new_state_ref[...] = state.reshape(n)
    new_cycle_ref[...] = cycle.reshape(n)
    claimed_ref[...] = claimed


@functools.partial(jax.jit, static_argnames=("k", "window", "interpret"))
def cmp_ring_step(state: jax.Array, cycle: jax.Array, meta: jax.Array,
                  req: jax.Array, *, k: int, window: int,
                  interpret: bool = False):
    """One fused admission step over the device ring.

    Args:
      state, cycle: int32 [N] slot arrays (domain constants / cycle stamps).
      meta: int32 [2] = [enq_cycle, deque_cycle].
      req: int32 [2] = [push_n, want] (dynamic; push_n is clamped to N).
    Returns (new_state, new_cycle, new_meta, claimed_cycles[k]); claimed
    entries are cycle numbers, -1 marks an unfilled claim lane. The number
    of accepted pushes is ``new_meta[0] - meta[0]``.
    """
    n = state.shape[0]
    req = jnp.stack([jnp.minimum(req[0], n), req[1]]).astype(jnp.int32)
    kernel = functools.partial(_ring_kernel, k=k, n=n, window=window)
    return pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((2,), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        interpret=interpret,
    )(state, cycle, meta, req)
