"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python for correctness validation; on TPU the same calls compile
to Mosaic. Model code calls these; layouts are adapted here.
"""

from __future__ import annotations

import jax

from repro.kernels import cmp_claim as _claim
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa


def _interpret() -> bool:
    return jax.devices()[0].platform != "tpu"


def flash_attention(q, k, v, *, causal=True, sliding_window=0,
                    block_q=128, block_k=128):
    """Model layout: q [B, S, H, hd]; k/v [B, T, KV, hd] -> [B, S, H, hd]."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    S = q.shape[1]
    bq = min(block_q, max(16, 1 << (S - 1).bit_length()))
    bk = min(block_k, bq)
    out = _fa.flash_attention(qt, kt, vt, causal=causal,
                              sliding_window=sliding_window,
                              block_q=bq, block_k=bk, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens):
    """q [B, H, hd]; pages [P, KV, page, hd] -> [B, H, hd]."""
    return _pa.paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                               interpret=_interpret())


_ref_ring_jit = None


def ring_step(state, cycle, meta, req, *, k, window, use_pallas=None):
    """Fused admission-ring step (reclaim + enqueue-many + k-way claim +
    frontier publish) in ONE device invocation. On TPU this is the Pallas
    kernel; elsewhere the jit'd pure-jnp oracle runs as the fast path
    (interpret-mode Pallas is reserved for the equivalence tests)."""
    if use_pallas is None:
        use_pallas = not _interpret()
    if use_pallas:
        from repro.kernels import cmp_ring as _ring

        return _ring.cmp_ring_step(state, cycle, meta, req, k=k, window=window)
    global _ref_ring_jit
    if _ref_ring_jit is None:
        from repro.kernels import ref as _ref

        _ref_ring_jit = jax.jit(_ref.ref_ring_step,
                                static_argnames=("k", "window"))
    return _ref_ring_jit(state, cycle, meta, req, k=k, window=window)


def claim(state, cycle, *, k, block_n=None):
    """Fused earliest-claim: (new_state, ids). ids==N => invalid.
    Pools larger than one VMEM block dispatch to the tiled grid kernel
    (block-local k-way min + cross-block merge)."""
    return _claim.cmp_claim(state, cycle, k=k, block_n=block_n,
                            interpret=_interpret())
