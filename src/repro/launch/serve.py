"""Serving driver: continuous-batching engine on the CMP paged-KV pool,
with optional multi-tenant priority classes (the sched fabric).

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --requests 8 --max-new 8

  # 3-class mixed traffic (interactive/batch/background) under a policy:
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --multitenant --policy wfq --requests 9

  # 2 steal-rebalanced engine replicas with frontier checkpointing:
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --multitenant --replicas 2 --checkpoint-dir /tmp/serve_ckpt
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=128)
    ap.add_argument("--window", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--multitenant", action="store_true",
                    help="3 priority classes (interactive/batch/background) "
                         "instead of one FIFO queue")
    ap.add_argument("--policy", default="strict",
                    choices=("strict", "wfq", "fifo"),
                    help="cross-class drain policy (with --multitenant)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="N steal-rebalanced engine replicas, each owning a "
                         "shard subset of every class and a 1/N lane+page "
                         "budget (DESIGN.md §9)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="frontier-checkpoint directory: resumes every "
                         "tenant at its exact FIFO seat if a snapshot "
                         "exists, and writes one at exit (replica mode)")
    args = ap.parse_args()
    if args.checkpoint_dir and args.checkpoint_dir == args.ckpt_dir:
        ap.error("--checkpoint-dir (frontier snapshots) must differ from "
                 "--ckpt-dir (model params): a frontier-only step would "
                 "shadow the params checkpoint's `latest`")

    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.sched import QueueClass
    from repro.serving.engine import Engine

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir:
        from repro.checkpoint import checkpointer as C
        _, state = C.restore(args.ckpt_dir, {"params": params})
        params = state["params"]

    shards = max(1, args.replicas)
    classes = None
    if args.multitenant:
        classes = [QueueClass("interactive", priority=2, weight=8.0,
                              num_shards=shards),
                   QueueClass("batch", priority=1, weight=3.0,
                              num_shards=shards),
                   QueueClass("background", priority=0, weight=1.0,
                              num_shards=shards)]
    if args.replicas > 1:
        from repro.checkpoint.checkpointer import latest_step, restore_aux
        from repro.serving.engine import EngineReplicaGroup
        eng_kw = dict(max_batch=args.max_batch, page_size=args.page_size,
                      num_pages=args.num_pages, max_seq=256)
        needed = set(c.name for c in classes) if classes else {"default"}
        resumed = None
        if args.checkpoint_dir and latest_step(args.checkpoint_dir) is not None:
            step, aux = restore_aux(args.checkpoint_dir)
            if aux is not None and "sched" in aux:
                have = set(aux["sched"]["classes"])
                if needed <= have:
                    eng = EngineReplicaGroup.from_sched_state(
                        cfg, params, aux["sched"], policy=args.policy,
                        window=args.window, **eng_kw)
                    resumed = step
                else:
                    print(f"[serve] WARNING: frontier checkpoint has classes "
                          f"{sorted(have)} but this run needs "
                          f"{sorted(needed)}; starting fresh (snapshot left "
                          f"untouched)")
        if resumed is None:
            eng = EngineReplicaGroup(cfg, params, num_replicas=args.replicas,
                                     window=args.window, classes=classes,
                                     policy=args.policy, **eng_kw)
        else:
            # the snapshot fixes the replica count (seat ownership is part
            # of the frontier state) — a differing --replicas is not a
            # silent reshard
            if len(eng.engines) != args.replicas:
                print(f"[serve] WARNING: --replicas {args.replicas} ignored; "
                      f"checkpoint was taken with {len(eng.engines)} "
                      f"replicas (reseat is a future roadmap item)")
            print(f"[serve] resumed {len(eng.engines)} replicas from "
                  f"frontier checkpoint step {resumed}: "
                  f"{eng.replica_set.pending()} seats pending")
    else:
        eng = Engine(cfg, params, max_batch=args.max_batch,
                     page_size=args.page_size, num_pages=args.num_pages,
                     window=args.window, max_seq=256,
                     classes=classes, policy=args.policy)
    tenant_cycle = ("interactive", "batch", "background")
    rng = jax.random.PRNGKey(42)
    uids, tenant_of = [], {}
    t0 = time.time()
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = 3 + i % 5
        prompt = [int(t) for t in
                  jax.random.randint(k, (plen,), 1, cfg.vocab_size)]
        qclass = tenant_cycle[i % 3] if args.multitenant else None
        uid = eng.submit(prompt, max_new_tokens=args.max_new, qclass=qclass)
        if uid is not None:
            uids.append(uid)
            tenant_of[uid] = qclass or "default"
    done = eng.run_until_idle(max_steps=2000)
    dt = time.time() - t0
    total_tokens = sum(len(done[u].output) for u in uids)
    for u in uids:
        r = done[u]
        print(f"[serve] req {u} ({tenant_of[u]}): {len(r.output)} tokens "
              f"(preemptions={r.preemptions}) -> {r.output[:8]}")
    if args.replicas > 1:
        free = sum(e.pool.free_pages() for e in eng.engines)
        total = sum(e.pool.num_pages for e in eng.engines)
    else:
        free, total = eng.pool.free_pages(), eng.pool.num_pages
    print(f"[serve] {len(uids)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s); engine steps={eng.step_count}; "
          f"free pages={free}/{total}")
    if args.replicas > 1:
        for rid, rstats in eng.replica_stats().items():
            print(f"[serve] replica {rid}: steals={rstats['steals']} "
                  f"stolen_cycles={rstats['stolen_cycles']} "
                  f"empty_drains={rstats['empty_drains']}")
    if args.multitenant:
        for name, cs in eng.class_stats().items():
            print(f"[serve] class {name}: submitted={cs['submitted']} "
                  f"requeued={cs['requeued']} "
                  f"p50_ms={cs['admit_p50_ms']} p99_ms={cs['admit_p99_ms']}")
    if args.replicas > 1 and args.checkpoint_dir:
        from repro.checkpoint.checkpointer import save
        path = save(args.checkpoint_dir, eng.step_count, {},
                    aux={"sched": eng.sched_state()})
        print(f"[serve] frontier checkpoint written: {path}")


if __name__ == "__main__":
    main()
