"""Serving driver: continuous-batching engine on the CMP paged-KV pool,
with optional multi-tenant priority classes (the sched fabric).

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --requests 8 --max-new 8

  # 3-class mixed traffic (interactive/batch/background) under a policy:
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --multitenant --policy wfq --requests 9
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=128)
    ap.add_argument("--window", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--multitenant", action="store_true",
                    help="3 priority classes (interactive/batch/background) "
                         "instead of one FIFO queue")
    ap.add_argument("--policy", default="strict",
                    choices=("strict", "wfq", "fifo"),
                    help="cross-class drain policy (with --multitenant)")
    args = ap.parse_args()

    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.sched import QueueClass
    from repro.serving.engine import Engine

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir:
        from repro.checkpoint import checkpointer as C
        _, state = C.restore(args.ckpt_dir, {"params": params})
        params = state["params"]

    classes = None
    if args.multitenant:
        classes = [QueueClass("interactive", priority=2, weight=8.0),
                   QueueClass("batch", priority=1, weight=3.0),
                   QueueClass("background", priority=0, weight=1.0)]
    eng = Engine(cfg, params, max_batch=args.max_batch,
                 page_size=args.page_size, num_pages=args.num_pages,
                 window=args.window, max_seq=256,
                 classes=classes, policy=args.policy)
    tenant_cycle = ("interactive", "batch", "background")
    rng = jax.random.PRNGKey(42)
    uids, tenant_of = [], {}
    t0 = time.time()
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = 3 + i % 5
        prompt = [int(t) for t in
                  jax.random.randint(k, (plen,), 1, cfg.vocab_size)]
        qclass = tenant_cycle[i % 3] if args.multitenant else None
        uid = eng.submit(prompt, max_new_tokens=args.max_new, qclass=qclass)
        if uid is not None:
            uids.append(uid)
            tenant_of[uid] = qclass or "default"
    done = eng.run_until_idle(max_steps=2000)
    dt = time.time() - t0
    total_tokens = sum(len(done[u].output) for u in uids)
    for u in uids:
        r = done[u]
        print(f"[serve] req {u} ({tenant_of[u]}): {len(r.output)} tokens "
              f"(preemptions={r.preemptions}) -> {r.output[:8]}")
    print(f"[serve] {len(uids)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s); engine steps={eng.step_count}; "
          f"free pages={eng.pool.free_pages()}/{eng.pool.num_pages}")
    if args.multitenant:
        for name, snap in eng.class_stats().items():
            print(f"[serve] class {name}: submitted={snap['submitted']} "
                  f"requeued={snap['requeued']} "
                  f"p50_ms={snap['admit_p50_ms']} p99_ms={snap['admit_p99_ms']}")


if __name__ == "__main__":
    main()
