"""Serving driver: the whole system — class queues, scheduler replicas,
engine group, checkpoint cadence — stood up through one declarative
`FabricConfig` and driven through one `Fabric` session (DESIGN.md §10).

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --requests 8 --max-new 8

  # 3-class mixed traffic (interactive/batch/background) under a policy:
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --multitenant --policy wfq --requests 9

  # 2 steal-rebalanced engine replicas, frontier checkpoint every 8 steps:
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --multitenant --replicas 2 --checkpoint-dir /tmp/serve_ckpt \\
      --checkpoint-every 8
"""

from __future__ import annotations

import argparse
import time

TENANTS = ("interactive", "batch", "background")


def config_from_args(args) -> "FabricConfig":  # noqa: F821
    """Flags -> one validated FabricConfig. Conflicting combinations that
    the old hand-wired driver accepted silently (a cross-class --policy
    without --multitenant, a checkpoint cadence with nowhere to write,
    --checkpoint-dir shadowing --ckpt-dir) raise FabricConfigError with the
    fix spelled out."""
    from repro.fabric import ClassSpec, FabricConfig, tiered_classes
    classes = tiered_classes() if args.multitenant else (ClassSpec("default"),)
    return FabricConfig(
        classes=classes, replicas=args.replicas, policy=args.policy,
        arch=args.arch, smoke=args.smoke, params_dir=args.ckpt_dir,
        max_batch=args.max_batch, page_size=args.page_size,
        num_pages=args.num_pages, max_seq=256, kv_window=args.window,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every_n_steps=args.checkpoint_every)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=128)
    ap.add_argument("--window", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None,
                    help="model-params checkpoint to restore weights from")
    ap.add_argument("--multitenant", action="store_true",
                    help="3 priority classes (interactive/batch/background) "
                         "instead of one FIFO queue")
    ap.add_argument("--policy", default="strict",
                    choices=("strict", "wfq", "fifo"),
                    help="cross-class drain policy (with --multitenant)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="N steal-rebalanced engine replicas (live-resized "
                         "to this count when resuming a checkpoint)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="frontier-checkpoint directory: resumes every "
                         "tenant at its exact FIFO seat if a snapshot "
                         "exists; one is written at close")
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    help="also write a frontier snapshot every N engine "
                         "steps (bounded in-loop recovery point)")
    args = ap.parse_args()
    from repro.fabric import Fabric, FabricConfigError
    try:
        config = config_from_args(args)
    except FabricConfigError as e:
        ap.error(str(e))

    from repro.checkpoint.checkpointer import latest_step
    fab = None
    if args.checkpoint_dir and latest_step(args.checkpoint_dir) is not None:
        # The seat structure (classes/shards/replica count) comes from the
        # snapshot; knobs that rebuild fresh on restore keep following the
        # flags, as the pre-fabric driver did.
        overrides = dict(policy=config.policy, kv_window=config.kv_window,
                         max_batch=config.max_batch,
                         page_size=config.page_size,
                         num_pages=config.num_pages,
                         max_seq=config.max_seq,
                         params_dir=config.params_dir,
                         checkpoint_every_n_steps=(
                             config.checkpoint_every_n_steps))
        try:
            fab = Fabric.restore(args.checkpoint_dir, overrides=overrides)
        except (FabricConfigError, FileNotFoundError, KeyError) as e:
            # e.g. a params-only or pre-fabric snapshot format, or flags
            # incompatible with the snapshot's class structure
            print(f"[serve] WARNING: cannot resume from "
                  f"{args.checkpoint_dir}: {e}; starting fresh (snapshot "
                  f"left untouched)")
        if fab is not None:
            need = {c.name for c in config.classes}
            have = {c.name for c in fab.config.classes}
            if need != have:
                print(f"[serve] WARNING: frontier checkpoint has classes "
                      f"{sorted(have)} but this run needs {sorted(need)}; "
                      f"starting fresh (snapshot left untouched)")
                fab.close(final_checkpoint=False)
                fab = None
        if fab is not None:
            print(f"[serve] resumed {fab.num_replicas} replicas from "
                  f"frontier checkpoint step {fab.step_count}: "
                  f"{fab.pending()} seats pending")
            if fab.num_replicas != args.replicas:  # live reseat, no restart
                try:
                    fab.resize(args.replicas)
                    print(f"[serve] live-resized to {args.replicas} "
                          f"replicas")
                except FabricConfigError as e:
                    print(f"[serve] WARNING: --replicas {args.replicas} "
                          f"ignored ({e}); keeping {fab.num_replicas}")
    if fab is None:
        fab = Fabric.open(config)

    t0 = time.time()
    uids, tenant_of = [], {}
    for i in range(args.requests):
        plen = 3 + i % 5
        prompt = [(7 * i + j) % (fab.model_cfg.vocab_size - 1) + 1
                  for j in range(plen)]
        qclass = TENANTS[i % 3] if args.multitenant else None
        uid = fab.submit(prompt, max_new_tokens=args.max_new, qclass=qclass)
        if uid is not None:
            uids.append(uid)
            tenant_of[uid] = qclass or "default"
    done = fab.drain(max_steps=2000)
    dt = time.time() - t0
    total_tokens = sum(len(done[u].output) for u in uids)
    for u in uids:
        r = done[u]
        print(f"[serve] req {u} ({tenant_of[u]}): {len(r.output)} tokens "
              f"(preemptions={r.preemptions}) -> {r.output[:8]}")
    free = sum(e.pool.free_pages() for e in fab.engines)
    total = sum(e.pool.num_pages for e in fab.engines)
    print(f"[serve] {len(uids)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s); fabric steps={fab.step_count}; "
          f"free pages={free}/{total}")
    stats = fab.stats()
    if args.replicas > 1:
        for rid, rs in stats["replicas"].items():
            print(f"[serve] replica {rid}: steals={rs['steals']} "
                  f"stolen_cycles={rs['stolen_cycles']} "
                  f"empty_drains={rs['empty_drains']}")
    if args.multitenant:
        for name, cs in stats["classes"].items():
            slo = stats["slo"][name]
            print(f"[serve] class {name}: submitted={cs['submitted']} "
                  f"requeued={cs['requeued']} p50_ms={cs['admit_p50_ms']} "
                  f"p99_ms={cs['admit_p99_ms']} "
                  f"slo_target_ms={slo['target_ms']} slo_ok={slo['ok']}")
    fab.close()  # writes the final frontier snapshot when --checkpoint-dir
    if args.checkpoint_dir:
        print(f"[serve] frontier checkpoint written: step {fab.step_count} "
              f"in {args.checkpoint_dir}")


if __name__ == "__main__":
    main()
