"""Serving driver: the whole system — class queues, scheduler replicas,
engine group, transport, checkpoint cadence, obs plane, autoscaler — stood
up through one declarative `FabricConfig` and driven through one `Fabric`
session (DESIGN.md §10-11, §14).

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --requests 8 --max-new 8

  # 3-class mixed traffic (interactive/batch/background) under a policy:
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --multitenant --policy wfq --requests 9

  # 2 steal-rebalanced engine replicas, frontier checkpoint every 8 steps:
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --multitenant --replicas 2 --checkpoint-dir /tmp/serve_ckpt \\
      --checkpoint-every 8

  # 4 replicas over 2 simulated hosts (host-addressed seats, serialized
  # wire envelopes), self-asserting delivery equality vs one host:
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --replicas 4 --hosts 2 --verify-single-host

  # ten-thousand-tenant fabric (DESIGN.md §16): 2000 declared tenants
  # hashed onto 32 class groups, heavy-tailed traffic, per-tenant FIFO
  # order asserted identical across host layouts:
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --tenants 2000 --replicas 2 --hosts 2 --verify-single-host

  # closed-loop autoscaling (DESIGN.md §14): start at 1 replica, let the
  # controller grow toward --max-replicas under load ('--autoscale
  # dry-run' records decisions without actuating):
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --replicas 1 --max-replicas 4 --autoscale --requests 16

Flag conventions: optional-value flags follow ``--flag [value]`` —
``--policy [strict|wfq|fifo]`` (bare = wfq), ``--device-admission
[true|false|auto]`` (bare = true), ``--trace [PATH]`` (bare =
reports/trace.json), ``--autoscale [dry-run]`` (bare = actuating).
``--dry-run`` prints the resolved FabricConfig JSON and exits.
"""

from __future__ import annotations

import argparse
import json
import time

TENANTS = ("interactive", "batch", "background")


def config_from_args(args) -> "FabricConfig":  # noqa: F821
    """Flags -> one validated FabricConfig. Conflicting combinations that
    the old hand-wired driver accepted silently (a cross-class --policy
    without --multitenant, a checkpoint cadence with nowhere to write,
    --checkpoint-dir shadowing --ckpt-dir, --hosts without enough replicas)
    raise FabricConfigError with the fix spelled out."""
    from repro.fabric import (ClassSpec, FabricConfig, FabricConfigError,
                              TenantSpec, tiered_classes)
    tenants = None
    if getattr(args, "tenants", None):
        if args.multitenant:
            raise FabricConfigError(
                "--tenants and --multitenant are exclusive: --tenants "
                "derives its own group x tier class grid")
        tenants = TenantSpec(num_tenants=args.tenants,
                             num_groups=getattr(args, "tenant_groups", 32),
                             page_quota=getattr(args, "tenant_quota", None))
    classes = tiered_classes() if args.multitenant else (ClassSpec("default"),)
    hosts = getattr(args, "hosts", 1)
    transport = getattr(args, "transport", "auto")
    if transport == "auto":
        transport = "sim" if hosts > 1 else "local"
    obs = None
    if (getattr(args, "trace", None) or getattr(args, "metrics_out", None)
            or getattr(args, "stats_interval", None)):
        from repro.obs import ObsConfig
        obs = ObsConfig(trace_rate=getattr(args, "trace_rate", 0.01))
    control = None
    autoscale = getattr(args, "autoscale", False)
    max_replicas = getattr(args, "max_replicas", None)
    if autoscale:
        from repro.control import ControlConfig
        control = ControlConfig(dry_run=(autoscale == "dry-run"))
        if obs is None:  # the controller's sensor input (config.validate
            from repro.obs import ObsConfig  # enforces obs-with-control)
            obs = ObsConfig(trace_rate=0.0)
        if max_replicas is None:  # headroom for the loop to grow into
            max_replicas = max(args.replicas * 2, hosts)
    return FabricConfig(
        obs=obs, control=control,
        classes=classes, tenants=tenants,
        replicas=args.replicas, max_replicas=max_replicas,
        policy=args.policy,
        hosts=hosts, transport=transport,
        transport_drop=getattr(args, "transport_drop", 0.0),
        transport_delay=getattr(args, "transport_delay", 0.0),
        transport_rtt_ms=getattr(args, "transport_rtt_ms", 0.0),
        transport_credit=getattr(args, "credit", 4),
        arch=args.arch, smoke=args.smoke, params_dir=args.ckpt_dir,
        max_batch=args.max_batch, page_size=args.page_size,
        num_pages=args.num_pages, max_seq=256, kv_window=args.window,
        device_admission=getattr(args, "device_admission", False),
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every_n_steps=args.checkpoint_every)


def tenant_of_request(i: int, num_tenants: int) -> int:
    """Deterministic heavy-tailed tenant popularity: hash the request index
    to a log-uniform draw over [0, T) — a handful of tenants get most of
    the traffic, the long tail gets a trickle, and the mapping is identical
    across host layouts (no RNG state to diverge)."""
    h = (i * 2654435761) & 0xFFFFFFFF  # Knuth multiplicative hash
    u = h / 2 ** 32
    return int(num_tenants ** u) - 1 if num_tenants > 1 else 0


def run_workload(fab, args):
    """Submit the flag-shaped request wave and drain it, recording the
    *completion order* (the delivery-order signal --verify-single-host
    compares across host layouts). All requests are submitted before any
    step runs, so admission decisions (including tenant sheds) are
    layout-independent."""
    uids, tenant_of = [], {}
    num_tenants = getattr(args, "tenants", None)
    for i in range(args.requests):
        plen = 3 + i % 5
        prompt = [(7 * i + j) % (fab.model_cfg.vocab_size - 1) + 1
                  for j in range(plen)]
        if num_tenants:
            tid = tenant_of_request(i, num_tenants)
            uid = fab.submit(prompt, max_new_tokens=args.max_new,
                             tenant=f"t{tid}", tier=TENANTS[i % 3])
            label = f"t{tid}"
        else:
            qclass = TENANTS[i % 3] if args.multitenant else None
            uid = fab.submit(prompt, max_new_tokens=args.max_new,
                             qclass=qclass)
            label = qclass or "default"
        if uid is not None:
            uids.append(uid)
            tenant_of[uid] = label
    order = []
    interval = getattr(args, "stats_interval", None)
    for step in range(1, 2001):
        order.extend(r.uid for r in fab.step())
        if interval and step % interval == 0:
            from repro.obs import format_class_lines
            for line in format_class_lines(fab.stats_view(),
                                           prefix=f"[serve] step {step}"):
                print(line)
        if fab.idle():
            break
    done = dict(fab.completed)
    return uids, tenant_of, done, order


def verify_single_host(args, config) -> None:
    """Run the identical workload under the multi-host layout and under one
    host, and assert the runs are indistinguishable to every tenant: same
    admitted requests, token-identical outputs, and the same per-class
    completion order (the host split is a transparent implementation
    detail of the seat protocol — exactly the tentpole claim). With
    --autoscale, the controller runs in both layouts: per-class delivery
    order must be controller-invariant too (resize preserves seat order)."""
    import dataclasses
    from repro.fabric import Fabric
    # Throwaway self-test runs: never write (or resume) the user's real
    # frontier checkpoints with the synthetic verify workload.
    config = dataclasses.replace(config, checkpoint_dir=None,
                                 checkpoint_every_n_steps=None)
    if config.tenants is not None:
        # Pin the quota ledger's host-cap split to the multi-host layout so
        # quota admission decisions are identical in both runs (otherwise
        # hosts=1 pools the whole budget and can admit what hosts=N sheds).
        config = dataclasses.replace(
            config, tenants=dataclasses.replace(
                config.tenants, quota_hosts=config.hosts))
    runs = {}
    for label, cfg in (("multi", config),
                       ("single", dataclasses.replace(
                           config, hosts=1, transport="local",
                           transport_drop=0.0, transport_delay=0.0,
                           transport_reorder=False, transport_rtt_ms=0.0))):
        fab = Fabric.open(cfg)
        uids, tenant_of, done, order = run_workload(fab, args)
        runs[label] = (uids, tenant_of, done, order)
        view = fab.stats_view()
        line = (f"[serve] verify[{label}]: hosts={cfg.hosts} "
                f"replicas={fab.num_replicas} completed={len(done)} "
                f"transport={view.transport['kind']}")
        if view.control and view.control.get("enabled"):
            line += (f" control_decisions={view.control['decisions']}"
                     f" resizes={view.resizes}")
        print(line)
        fab.close(final_checkpoint=False)
    (u_m, t_m, d_m, o_m), (u_s, t_s, d_s, o_s) = runs["multi"], runs["single"]
    assert u_m == u_s, "admitted request sets diverged across host layouts"
    assert set(d_m) == set(d_s), (
        f"completion sets diverged: multi-only="
    f"{sorted(set(d_m) - set(d_s))} single-only={sorted(set(d_s) - set(d_m))}")
    for u in d_m:
        assert d_m[u].output == d_s[u].output, (
            f"req {u}: outputs diverged across host layouts")
    for name in set(t_m.values()):
        o_mc = [u for u in o_m if t_m[u] == name]
        o_sc = [u for u in o_s if t_s[u] == name]
        assert o_mc == o_sc, (
            f"class {name}: completion order diverged "
            f"(multi={o_mc}, single={o_sc})")
    print(f"[serve] verify-single-host PASS: {len(d_m)} requests, "
          f"per-class delivery order identical at hosts={config.hosts} "
          f"vs hosts=1")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="CMP serving fabric driver (one FabricConfig in, one "
                    "Fabric session out)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the resolved FabricConfig JSON and exit "
                         "without opening a fabric")

    model = ap.add_argument_group("model")
    model.add_argument("--arch", default="glm4-9b")
    model.add_argument("--smoke", action="store_true")
    model.add_argument("--ckpt-dir", default=None,
                       help="model-params checkpoint to restore weights "
                            "from")

    work = ap.add_argument_group("workload")
    work.add_argument("--requests", type=int, default=8)
    work.add_argument("--max-new", type=int, default=8)
    work.add_argument("--multitenant", action="store_true",
                      help="3 priority classes (interactive/batch/"
                           "background) instead of one FIFO queue")
    work.add_argument("--tenants", type=int, default=None, metavar="N",
                      help="tenant fabric: declare N tenants hashed onto "
                           "--tenant-groups class groups (3 tiers each, "
                           "hierarchical drain, O(active) cost); requests "
                           "get heavy-tailed tenant popularity and "
                           "--verify-single-host checks per-tenant FIFO "
                           "order")
    work.add_argument("--tenant-groups", type=int, default=32, metavar="G",
                      help="class groups the tenant hash space maps onto "
                           "(with --tenants; default 32)")
    work.add_argument("--tenant-quota", type=int, default=None, metavar="P",
                      help="per-tenant KV page quota (with --tenants); "
                           "over-quota admissions are denied, lowest tier "
                           "counts them as 429-style sheds")
    work.add_argument("--verify-single-host", action="store_true",
                      help="run the workload under --hosts N and under one "
                           "host and assert identical per-class delivery "
                           "order and token-identical outputs (self-test; "
                           "skips checkpoint resume)")

    fabric = ap.add_argument_group("fabric")
    fabric.add_argument("--replicas", type=int, default=1,
                        help="N steal-rebalanced engine replicas (live-"
                             "resized to this count when resuming a "
                             "checkpoint)")
    fabric.add_argument("--max-replicas", type=int, default=None,
                        help="live-resize ceiling (seats are provisioned "
                             "at open); defaults to --replicas, or 2x with "
                             "--autoscale")
    fabric.add_argument("--hosts", type=int, default=1,
                        help="spread the replicas over N simulated hosts "
                             "(host-addressed seats over the sim "
                             "transport; 1 = in-process local transport)")
    fabric.add_argument("--transport", default="auto",
                        choices=("auto", "local", "sim", "wire"),
                        help="seat transport: 'sim' = in-process simulated "
                             "hosts, 'wire' = real per-host worker "
                             "processes over localhost TCP (DESIGN.md "
                             "§15); 'auto' picks sim when --hosts > 1 "
                             "else local")
    fabric.add_argument("--transport-drop", type=float, default=0.0,
                        metavar="P",
                        help="chaos: drop each remote data-plane message "
                             "with probability P before it changes state "
                             "(sim and wire transports)")
    fabric.add_argument("--transport-delay", type=float, default=0.0,
                        metavar="P",
                        help="chaos: park each remote fetch batch with "
                             "probability P until the next quiesce")
    fabric.add_argument("--transport-rtt-ms", type=float, default=0.0,
                        help="inject a deterministic per-op round-trip "
                             "time in milliseconds (sim: sleeps per op; "
                             "wire: server delays responses, so "
                             "pipelined fetches overlap the RTT)")
    fabric.add_argument("--credit", type=int, default=4,
                        help="wire transport prefetch credit: fetches "
                             "kept in flight per (class, shard); 1 = "
                             "synchronous request/response")
    fabric.add_argument("--policy", nargs="?", const="wfq", default="strict",
                        choices=("strict", "wfq", "fifo", "hier"),
                        help="cross-class drain policy (with "
                             "--multitenant/--tenants); bare --policy = "
                             "wfq; --tenants defaults to hier (WFQ across "
                             "groups, strict within)")
    fabric.add_argument("--device-admission", dest="device_admission",
                        nargs="?", const=True, default=False,
                        type=lambda s: {"true": True, "false": False,
                                        "auto": "auto"}[s.lower()],
                        help="route engine admission through the device-"
                             "resident CMP ring (DESIGN.md §12): bare flag "
                             "forces the ring, 'auto' uses it only on TPU, "
                             "'false' keeps the host path")

    engine = ap.add_argument_group("engine geometry")
    engine.add_argument("--max-batch", type=int, default=4)
    engine.add_argument("--page-size", type=int, default=16)
    engine.add_argument("--num-pages", type=int, default=128)
    engine.add_argument("--window", type=int, default=4)

    auto = ap.add_argument_group("autoscale (DESIGN.md §14)")
    auto.add_argument("--autoscale", nargs="?", const=True, default=False,
                      metavar="dry-run",
                      help="arm the closed-loop controller inside "
                           "Fabric.step (grow/shrink replicas toward "
                           "--max-replicas on backlog + SLO headroom); "
                           "'--autoscale dry-run' records decisions "
                           "without actuating")

    ckpt = ap.add_argument_group("checkpoint")
    ckpt.add_argument("--checkpoint-dir", default=None,
                      help="frontier-checkpoint directory: resumes every "
                           "tenant at its exact FIFO seat if a snapshot "
                           "exists; one is written at close")
    ckpt.add_argument("--checkpoint-every", type=int, default=None,
                      help="also write a frontier snapshot every N engine "
                           "steps (bounded in-loop recovery point)")

    obs = ap.add_argument_group("observability")
    obs.add_argument("--trace", nargs="?", const="reports/trace.json",
                     default=None, metavar="PATH",
                     help="enable the flight recorder and write a Chrome/"
                          "Perfetto trace.json after the run (bare flag = "
                          "reports/trace.json; load at ui.perfetto.dev)")
    obs.add_argument("--trace-rate", type=float, default=0.01,
                     help="head-sampling rate for lifecycle tracing "
                          "(1.0 = every envelope; default 0.01)")
    obs.add_argument("--metrics-out", default=None, metavar="PATH",
                     help="write Prometheus text exposition of the final "
                          "fabric stats to PATH")
    obs.add_argument("--stats-interval", type=int, default=None, metavar="N",
                     help="print a per-class stats line every N fabric "
                          "steps")
    return ap


def main() -> None:
    ap = build_parser()
    args = ap.parse_args()
    if args.autoscale not in (False, True, "dry-run"):
        ap.error(f"--autoscale takes no value or 'dry-run' "
                 f"(got {args.autoscale!r})")
    if args.verify_single_host and args.hosts < 2:
        ap.error("--verify-single-host compares a multi-host layout "
                 "against one host; it needs --hosts >= 2 (with --hosts 1 "
                 "both runs would be identical and the PASS vacuous)")
    from repro.fabric import Fabric, FabricConfigError
    try:
        config = config_from_args(args)
    except FabricConfigError as e:
        ap.error(str(e))

    if args.dry_run:
        print(json.dumps(config.to_json(), indent=2, sort_keys=True))
        return

    if args.verify_single_host:
        verify_single_host(args, config)
        return

    from repro.checkpoint.checkpointer import latest_step
    fab = None
    if args.checkpoint_dir and latest_step(args.checkpoint_dir) is not None:
        # The seat structure (classes/shards/replica count) comes from the
        # snapshot; knobs that rebuild fresh on restore keep following the
        # flags, as the pre-fabric driver did — including the transport and
        # host layout (seat owners re-address by replica on restore).
        overrides = dict(policy=config.policy, kv_window=config.kv_window,
                         max_batch=config.max_batch,
                         page_size=config.page_size,
                         num_pages=config.num_pages,
                         max_seq=config.max_seq,
                         device_admission=config.device_admission,
                         hosts=config.hosts, transport=config.transport,
                         transport_drop=config.transport_drop,
                         transport_delay=config.transport_delay,
                         transport_rtt_ms=config.transport_rtt_ms,
                         transport_credit=config.transport_credit,
                         params_dir=config.params_dir,
                         obs=config.obs, control=config.control,
                         checkpoint_every_n_steps=(
                             config.checkpoint_every_n_steps))
        try:
            fab = Fabric.restore(args.checkpoint_dir, overrides=overrides)
        except (FabricConfigError, FileNotFoundError, KeyError) as e:
            # e.g. a params-only or pre-fabric snapshot format, or flags
            # incompatible with the snapshot's class structure
            print(f"[serve] WARNING: cannot resume from "
                  f"{args.checkpoint_dir}: {e}; starting fresh (snapshot "
                  f"left untouched)")
        if fab is not None:
            need = {c.name for c in config.classes}
            have = {c.name for c in fab.config.classes}
            if need != have:
                print(f"[serve] WARNING: frontier checkpoint has classes "
                      f"{sorted(have)} but this run needs {sorted(need)}; "
                      f"starting fresh (snapshot left untouched)")
                fab.close(final_checkpoint=False)
                fab = None
        if fab is not None:
            print(f"[serve] resumed {fab.num_replicas} replicas over "
                  f"{fab.transport.num_hosts} host(s) from frontier "
                  f"checkpoint step {fab.step_count}: "
                  f"{fab.pending()} seats pending")
            if fab.num_replicas != args.replicas:  # live reseat, no restart
                try:
                    fab.resize(args.replicas)
                    print(f"[serve] live-resized to {args.replicas} "
                          f"replicas")
                except FabricConfigError as e:
                    print(f"[serve] WARNING: --replicas {args.replicas} "
                          f"ignored ({e}); keeping {fab.num_replicas}")
    if fab is None:
        fab = Fabric.open(config)

    t0 = time.time()
    uids, tenant_of, done, _ = run_workload(fab, args)
    dt = time.time() - t0
    total_tokens = sum(len(done[u].output) for u in uids)
    for u in uids:
        r = done[u]
        print(f"[serve] req {u} ({tenant_of[u]}): {len(r.output)} tokens "
              f"(preemptions={r.preemptions}) -> {r.output[:8]}")
    free = sum(e.pool.free_pages() for e in fab.engines)
    total = sum(e.pool.num_pages for e in fab.engines)
    print(f"[serve] {len(uids)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s); fabric steps={fab.step_count}; "
          f"free pages={free}/{total}")
    view = fab.stats_view()
    if args.hosts > 1:
        ts = view.transport
        print(f"[serve] transport: hosts={ts['hosts']} "
              f"remote_msgs={ts['remote_msgs']} "
              f"remote_bytes={ts['remote_bytes']} "
              f"remote_claims={ts['remote_claims']}")
    if fab.num_replicas > 1 or args.replicas > 1:
        for rid, rs in view.replicas.items():
            print(f"[serve] replica {rid} (host {rs['host']}): "
                  f"steals={rs['steals']} "
                  f"stolen_cycles={rs['stolen_cycles']} "
                  f"empty_drains={rs['empty_drains']}")
    if args.multitenant:
        for name, cs in view.classes.items():
            slo = view.slo[name]
            print(f"[serve] class {name}: submitted={cs.submitted} "
                  f"requeued={cs.requeued} p50_ms={cs.admit_p50_ms} "
                  f"p99_ms={cs.admit_p99_ms} "
                  f"slo_target_ms={slo.target_ms} slo_ok={slo.ok}")
    if args.tenants:
        tv = view.tenants or {}
        tot = tv.get("totals", {})
        print(f"[serve] tenants: declared={tv.get('declared')} "
              f"groups={tv.get('groups')} tracked={tv.get('tracked')} "
              f"active_classes={tv.get('active_classes')} "
              f"submitted={tot.get('submitted')} "
              f"delivered={tot.get('delivered')} shed={tot.get('shed')} "
              f"rejected={tot.get('rejected')}")
        for row in tv.get("top", []):
            print(f"[serve]   top tenant {row['tenant']}: "
                  f"backlog={row['backlog']} submitted={row['submitted']} "
                  f"delivered={row['delivered']}")
    if args.autoscale:
        ctl = view.control or {}
        print(f"[serve] control: decisions={ctl.get('decisions', 0)} "
              f"applied={ctl.get('applied')} resizes={view.resizes} "
              f"final_replicas={view.num_replicas} "
              f"hosts={view.num_hosts} dry_run={ctl.get('dry_run')}")
        for d in ctl.get("last", []):
            print(f"[serve]   step {d['step']}: {d['kind']}"
                  f"{' (dry-run)' if not d['applied'] else ''} — "
                  f"{d['reason']}")
    if fab.obs is not None:
        from repro.obs import perfetto_trace, prometheus_text, stage_breakdown
        events = fab.obs.events()
        if args.trace:
            perfetto_trace(events, path=args.trace)
            print(f"[serve] flight-recorder trace: {len(events)} events "
                  f"(trace_rate={fab.obs.config.trace_rate}) -> {args.trace}")
            for pair, row in stage_breakdown(events).items():
                print(f"[serve]   {pair}: n={row['n']} "
                      f"p50={row['p50_ms']:.3f}ms p99={row['p99_ms']:.3f}ms")
        if args.metrics_out:
            import os
            d = os.path.dirname(args.metrics_out)
            if d:
                os.makedirs(d, exist_ok=True)
            with open(args.metrics_out, "w") as f:
                f.write(prometheus_text(view))
            print(f"[serve] metrics exposition -> {args.metrics_out}")
    fab.close()  # writes the final frontier snapshot when --checkpoint-dir
    if args.checkpoint_dir:
        print(f"[serve] frontier checkpoint written: step {fab.step_count} "
              f"in {args.checkpoint_dir}")


if __name__ == "__main__":
    main()
