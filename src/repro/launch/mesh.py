"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods).

    Axes: 'pod' carries only cross-pod gradient reduction; 'data' is
    batch/FSDP; 'model' is TP/EP/sequence-sharding."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU tests (requires >= n_data*n_model host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
