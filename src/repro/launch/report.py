"""Assemble the EXPERIMENTS.md roofline tables from reports/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report [--dir reports/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load(dirname):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def table(rows, mesh):
    out = [
        "| arch | shape | status | compute | memory | collective | dominant "
        "| flops/chip | bytes/chip | wire/chip | useful FLOPs | params |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows = [r for r in rows if r.get("mesh") == mesh]
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | skip (full-attn, "
                       f"DESIGN.md §4) | — | — | — | — | — | — | — | — |")
            continue
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | — | — | — | — "
                       f"| — | — | — | — |")
            continue
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {fmt_s(t['compute_s'])} "
            f"| {fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} "
            f"| **{t['dominant']}** | {t['flops_per_chip']:.2e} "
            f"| {fmt_b(t['bytes_per_chip'])} | {fmt_b(t['wire_bytes_per_chip'])} "
            f"| {t['useful_flops_ratio']:.2f} | {t['n_params']/1e9:.2f}B |")
    return "\n".join(out)


def summarize(rows, mesh):
    ok = [r for r in rows if r.get("mesh") == mesh and r.get("ok") and not r.get("skipped")]
    skip = [r for r in rows if r.get("mesh") == mesh and r.get("skipped")]
    fail = [r for r in rows if r.get("mesh") == mesh and not r.get("ok")]
    return f"{len(ok)} compiled, {len(skip)} documented skips, {len(fail)} failures"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="reports/dryrun")
    args = ap.parse_args()
    rows = load(args.dir)
    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"\n### Mesh {mesh} — {summarize(rows, mesh)}\n")
        print(table(rows, mesh))


if __name__ == "__main__":
    main()
