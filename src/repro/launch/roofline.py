"""Three-term roofline extraction from the compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = wire_bytes_per_chip / link_bw

Hardware model (TPU v5e, per assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis()`` on an SPMD executable describes the *per-device* module,
so flops/bytes are per-chip already (verified in tests against a known
matmul). Collective bytes are not in cost_analysis; we parse the
post-partitioning HLO and convert each collective's result shape to
per-participant ring wire bytes:

    all-reduce         2 * bytes * (n-1)/n     (reduce-scatter + all-gather)
    all-gather         bytes * (n-1)/n
    reduce-scatter     bytes * (n-1)           (operand = result * n)
    all-to-all         bytes * (n-1)/n
    collective-permute bytes
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # bytes/s / chip
LINK_BW = 50e9        # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[0-9,]*\][^\s]*\s*,?\s*)+)\)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_wire_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-participating-chip ring wire bytes by collective kind."""
    out: Dict[str, float] = {"all-reduce": 0.0, "all-gather": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0, "ops": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # count async start only
        result_bytes = _shape_bytes(m.group(1))
        kind = m.group(2)
        n = 1
        g = _GROUPS_BRACE_RE.search(line)
        if g:
            n = len([x for x in g.group(1).split(",") if x.strip()])
        else:
            g = _GROUPS_IOTA_RE.search(line)
            if g:
                n = int(g.group(2))
        if n <= 1 and kind != "collective-permute":
            continue
        if kind == "all-reduce":
            wire = 2 * result_bytes * (n - 1) / n
        elif kind == "all-gather":
            wire = result_bytes * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = result_bytes * (n - 1)
        elif kind == "all-to-all":
            wire = result_bytes * (n - 1) / n
        else:  # collective-permute
            wire = result_bytes
        out[kind] += wire
        out["ops"] += 1
    out["total"] = sum(v for k, v in out.items() if k not in ("ops", "total"))
    return out


def roofline_terms(cost: dict, hlo_text: str, *, links: int = 2) -> Dict[str, float]:
    """cost: compiled.cost_analysis() dict (per-device module)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    wire = collective_wire_bytes(hlo_text)
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = wire["total"] / (LINK_BW * links)
    dominant = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
        key=lambda kv: kv[1])[0]
    return {
        "flops_per_chip": flops,
        "bytes_per_chip": byts,
        "wire_bytes_per_chip": wire["total"],
        "wire_breakdown": {k: wire[k] for k in
                           ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute")},
        "collective_ops": wire["ops"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "step_s_lower_bound": max(compute_s, memory_s, collective_s),
    }


def model_flops(n_active_params: int, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D for a train step (fwd+bwd), 2*N*D for inference."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens
