"""Training driver: CMP data pipeline -> fault-tolerant Trainer.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \\
      --steps 50 --batch 8 --seq 128 [--ckpt-dir ckpt/] [--resume]

Full-scale (multi-pod) training uses the same step function lowered by
launch/dryrun.py with the production mesh; this driver runs the real loop at
whatever scale the host provides (1 CPU here, a pod slice on TPU).
"""

from __future__ import annotations

import argparse
import dataclasses
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--producers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (custom model size)")
    ap.add_argument("--layers", type=int, default=None)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data.pipeline import DataPipeline
    from repro.models import param_count
    from repro.training.optimizer import OptConfig
    from repro.training.train_loop import Trainer

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.d_model or args.layers:
        pat = len(cfg.block_pattern)
        cfg = dataclasses.replace(
            cfg,
            d_model=args.d_model or cfg.d_model,
            num_layers=(args.layers or cfg.num_layers) // pat * pat,
            d_ff=(args.d_model or cfg.d_model) * 4 if cfg.d_ff else 0,
            head_dim=(args.d_model or cfg.d_model) // cfg.num_heads,
        )
    opt = OptConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                    total_steps=args.steps,
                    moment_dtype=cfg.optimizer_state_dtype)
    pipe = DataPipeline(batch=args.batch, seq=args.seq, vocab=cfg.vocab_size,
                        num_producers=args.producers, window=64)
    tr = Trainer(cfg, opt, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    print(f"[train] {cfg.name}: {param_count(tr.params):,} params, "
          f"{args.steps} steps of {args.batch}x{args.seq}")
    if args.resume and tr.try_restore(pipe):
        print(f"[train] resumed from step {tr.step}")

    t0 = time.time()
    it = iter(pipe)
    done = 0
    while done < args.steps:
        chunk = min(10, args.steps - done)
        tr.fit(it, chunk, data_pipe=pipe)
        done += chunk
        dt = time.time() - t0
        print(f"[train] step {tr.step}  loss {tr.history[-1]:.4f}  "
              f"({dt/done:.2f}s/step, stragglers={tr.stragglers})")
    pipe.close()
    if tr.async_ckpt:
        tr.async_ckpt.close()
    print(f"[train] done: loss {tr.history[0]:.4f} -> {tr.history[-1]:.4f}")


if __name__ == "__main__":
    main()
