import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
# on the production mesh, prove it fits (memory_analysis + analytic budget),
# and extract roofline terms (cost_analysis + collective parse).
#
# MUST run as its own process (the two lines above must execute before any
# jax initialization - do not import this module into a live jax process).
#
# Cost-model calibration: XLA counts a while-loop body ONCE regardless of
# trip count (verified in tests/test_roofline.py). Every loop in this model
# stack (layer scan, chunked-attention KV scan, recurrent time scans) carries
# an unroll knob, so we lower the cell at knob=1 and knob=2 and solve for the
# per-iteration cost; totals are exact linear reconstructions:
#
#   c(base)       = out + ls + a + s      (one body instance each)
#   c(layer x2)   = out + 2(ls + a + s)
#   c(attn  x2)   = out + ls + 2a + s
#   c(ssm   x2)   = out + ls + a + 2s
#   total         = out + R*ls + R*Ta*a + R*Ts*s
#
# where R = layer-scan trips, Ta = chunked-attn trips, Ts = time-scan trips.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out reports/dryrun]

import argparse
import dataclasses
import json
import math
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cell_is_runnable, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as R
from repro.models import model as M
from repro.models.blocks import cache_len
from repro.models.layers import kv_chunks
from repro.models.frontends import num_frontend_embeds
from repro.parallel import sharding as S
from repro.training import optimizer as O


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct only - no allocation)
# ---------------------------------------------------------------------------


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(lambda k: M.init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Abstract stand-ins for every model input of this cell."""
    B, Ssz = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((B, Ssz + 1), jnp.int32)}
        if cfg.frontend == "vision":
            batch["extra_embeds"] = jax.ShapeDtypeStruct(
                (B, num_frontend_embeds(cfg), cfg.d_model), jnp.dtype(cfg.dtype))
        return {"batch": batch}
    if shape.kind == "prefill":
        cache = jax.eval_shape(lambda: M.init_cache(cfg, B, Ssz))
        spec = {"tokens": jax.ShapeDtypeStruct((B, Ssz), jnp.int32), "cache": cache}
        if cfg.frontend == "vision":
            spec["extra_embeds"] = jax.ShapeDtypeStruct(
                (B, num_frontend_embeds(cfg), cfg.d_model), jnp.dtype(cfg.dtype))
        return spec
    # decode: one new token against a cache of shape.seq_len
    cache = jax.eval_shape(lambda: M.init_cache(cfg, B, Ssz))
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32), "cache": cache}


def make_step(cfg: ModelConfig, shape: InputShape, opt_cfg: O.OptConfig):
    if shape.kind == "train":
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                M.loss_fn, has_aux=True)(params, batch, cfg)
            params, opt_state, om = O.apply_updates(params, grads, opt_state, opt_cfg)
            return params, opt_state, (loss, om["grad_norm"])
        return train_step
    if shape.kind == "prefill":
        def prefill_step(params, tokens, cache, extra_embeds=None):
            return M.prefill(params, tokens, cfg, cache, extra_embeds=extra_embeds)
        return prefill_step

    def serve_step(params, tokens, cache):
        return M.decode_step(params, tokens, cfg, cache)
    return serve_step


# ---------------------------------------------------------------------------
# lowering one variant
# ---------------------------------------------------------------------------


def lower_cell(cfg: ModelConfig, shape: InputShape, mesh,
               opt_cfg: Optional[O.OptConfig] = None):
    """Returns the lowered step for this cfg variant on this mesh."""
    opt_cfg = opt_cfg or O.OptConfig(moment_dtype=cfg.optimizer_state_dtype)
    ba = S.batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    if cfg.batch_axes is not None:
        ba = tuple(cfg.batch_axes)  # explicit variant override
        nb = 1
        for a in ba:
            nb *= mesh.shape[a]
    elif shape.global_batch % nb == 0 and shape.global_batch >= nb:
        cfg = dataclasses.replace(cfg, batch_axes=tuple(ba))
    specs = input_specs(cfg, shape)
    step = make_step(cfg, shape, opt_cfg)
    n_b = nb  # input batch sharding follows cfg.batch_axes (variant-aware)
    from jax.sharding import NamedSharding, PartitionSpec as P

    def ns(spec):
        return NamedSharding(mesh, spec)

    def batch_sharding(x):
        b_ok = x.shape[0] % n_b == 0 and x.shape[0] >= n_b
        return ns(P(ba if b_ok else None, *([None] * (x.ndim - 1))))

    p_struct = params_struct(cfg)
    p_shard = S.param_shardings(p_struct, mesh, cfg.param_mode)

    with mesh:
        if shape.kind == "train":
            o_struct = jax.eval_shape(lambda p: O.init(p, opt_cfg), p_struct)
            o_shard = O.OptState(step=ns(P()),
                                 mu=S.param_shardings(p_struct, mesh, cfg.param_mode),
                                 nu=S.param_shardings(p_struct, mesh, cfg.param_mode))
            b_shard = jax.tree_util.tree_map(batch_sharding, specs["batch"])
            jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            return jitted.lower(p_struct, o_struct, specs["batch"])
        c_struct = specs["cache"]
        c_shard = jax.tree_util.tree_map(
            ns, S.cache_specs_for(mesh, c_struct, shape.global_batch))
        t_shard = batch_sharding(specs["tokens"])
        if shape.kind == "prefill":
            args = [p_struct, specs["tokens"], c_struct]
            in_sh = [p_shard, t_shard, c_shard]
            if "extra_embeds" in specs:
                args.append(specs["extra_embeds"])
                in_sh.append(batch_sharding(specs["extra_embeds"]))
            jitted = jax.jit(step, in_shardings=tuple(in_sh), donate_argnums=(2,))
            return jitted.lower(*args)
        jitted = jax.jit(step, in_shardings=(p_shard, t_shard, c_shard),
                         donate_argnums=(2,))
        return jitted.lower(p_struct, specs["tokens"], c_struct)


# ---------------------------------------------------------------------------
# loop trip counts per cell (must mirror model dispatch exactly)
# ---------------------------------------------------------------------------


def trip_counts(cfg: ModelConfig, shape: InputShape) -> Dict[str, int]:
    trips = {"layer": cfg.pattern_repeats, "attn": 0, "ssm": 0}
    Ssz = shape.seq_len
    if shape.kind == "prefill":
        s_q = Ssz + (num_frontend_embeds(cfg) if cfg.frontend == "vision" else 0)
        t_cache = cache_len(cfg, Ssz)
        if any(k in ("dense", "moe", "hymba") for k in cfg.block_pattern):
            trips["attn"] = kv_chunks(s_q, t_cache, cfg.attn_chunk_kv)
    s_time = Ssz if shape.kind in ("train", "prefill") else 1
    if shape.kind == "train":
        s_time = Ssz  # loss_fn trains on tokens[:, :-1] -> S positions
        if cfg.frontend == "vision":
            s_time += num_frontend_embeds(cfg)
    if s_time > 1:
        if any(k in ("mlstm", "slstm") for k in cfg.block_pattern):
            trips["ssm"] = s_time
        if "hymba" in cfg.block_pattern:
            trips["ssm"] = -(-s_time // min(cfg.ssd_chunk, s_time))
    return trips


def _measure_cfg(cfg: ModelConfig, shape: InputShape, mesh) -> Dict[str, Any]:
    """Lower/compile at each active knob and reconstruct true per-chip costs."""
    trips = trip_counts(cfg, shape)
    variants = {"base": cfg}
    if trips["layer"] > 1:
        variants["layer"] = dataclasses.replace(cfg, scan_unroll=2)
    if trips["attn"] > 1:
        variants["attn"] = dataclasses.replace(cfg, attn_scan_unroll=2)
    if trips["ssm"] > 1:
        variants["ssm"] = dataclasses.replace(cfg, time_scan_unroll=2)

    meas: Dict[str, Dict[str, float]] = {}
    base_compiled = None
    for name, vcfg in variants.items():
        lowered = lower_cell(vcfg, shape, mesh)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        wire = R.collective_wire_bytes(compiled.as_text())
        meas[name] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
            **{f"wire_{k}": wire[k] for k in
               ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")},
            "wire_total": wire["total"],
            "collective_ops": wire["ops"],
        }
        if name == "base":
            base_compiled = compiled

    keys = [k for k in meas["base"] if k != "collective_ops"]
    base = meas["base"]
    slopes = {}
    for knob in ("layer", "attn", "ssm"):
        if knob in meas:
            slopes[knob] = {k: meas[knob][k] - base[k] for k in keys}
        else:
            slopes[knob] = {k: 0.0 for k in keys}
    total = {}
    for k in keys:
        ls_pure = slopes["layer"][k] - slopes["attn"][k] - slopes["ssm"][k]
        out = base[k] - slopes["layer"][k]
        total[k] = (out + trips["layer"] * ls_pure
                    + trips["layer"] * max(1, trips["attn"]) * slopes["attn"][k]
                    + trips["layer"] * max(1, trips["ssm"]) * slopes["ssm"][k])
        total[k] = max(total[k], base[k])  # guard tiny negative extrapolation
    return {"trips": trips, "raw": meas, "corrected": total,
            "compiled": base_compiled}


# ---------------------------------------------------------------------------
# analytic per-chip memory budget (TPU-true; CPU memory_analysis is approximate)
# ---------------------------------------------------------------------------


def analytic_memory(cfg: ModelConfig, shape: InputShape, mesh) -> Dict[str, float]:
    p_struct = params_struct(cfg)
    specs = S.param_specs(p_struct)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def shard_div(spec):
        d = 1
        for entry in spec:
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            for nme in names:
                d *= axis_sizes[nme]
        return d

    def bytes_of(tree, spec_tree):
        flat, _ = jax.tree_util.tree_flatten(tree)
        sflat, _ = jax.tree_util.tree_flatten(
            spec_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        tot = 0.0
        for leaf, spec in zip(flat, sflat):
            tot += math.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize / shard_div(spec)
        return tot

    param_b = bytes_of(p_struct, specs)
    out = {"params": param_b}
    if shape.kind == "train":
        mom = jnp.dtype(cfg.optimizer_state_dtype).itemsize
        out["optimizer"] = 2 * param_b * mom / jnp.dtype(cfg.dtype).itemsize
        out["grads_transient"] = param_b * 4 / jnp.dtype(cfg.dtype).itemsize
        n_b = math.prod([axis_sizes[a] for a in S.batch_axes(mesh)])
        b_loc = max(1, shape.global_batch // n_b)
        # remat residuals: one [B,S,D] per super-layer + current layer temps
        out["residuals"] = (cfg.pattern_repeats * b_loc * shape.seq_len
                            * cfg.d_model * jnp.dtype(cfg.dtype).itemsize)
        v_shard = axis_sizes.get("model", 1)
        out["logits_f32"] = b_loc * shape.seq_len * cfg.vocab_size * 4 / v_shard
    else:
        cache = jax.eval_shape(lambda: M.init_cache(cfg, shape.global_batch,
                                                    shape.seq_len))
        cspecs = S.cache_specs_for(mesh, cache, shape.global_batch)
        out["kv_cache"] = bytes_of(cache, cspecs)
    out["total"] = sum(v for k, v in out.items())
    return out


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             mesh=None, verbose: bool = True,
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    t0 = time.time()
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                              "mesh": mesh_name, "ok": False}
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        result.update(skipped=True, reason=why, ok=True)
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: SKIP ({why})")
        return result
    result["overrides"] = overrides or {}
    try:
        mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
        m = _measure_cfg(cfg, shape, mesh)
        compiled = m.pop("compiled")
        try:
            mem = compiled.memory_analysis()
            result["memory"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "peak_memory_in_bytes")
                if hasattr(mem, k)}
        except Exception as e:
            result["memory"] = {"error": str(e)}
        result["memory_analytic"] = analytic_memory(cfg, shape, mesh)
        c = m["corrected"]
        cost = {"flops": c["flops"], "bytes accessed": c["bytes"],
                "transcendentals": c["transcendentals"]}
        terms = {
            "flops_per_chip": c["flops"],
            "bytes_per_chip": c["bytes"],
            "wire_bytes_per_chip": c["wire_total"],
            "wire_breakdown": {k: c[f"wire_{k}"] for k in
                               ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute")},
            "collective_ops": m["raw"]["base"]["collective_ops"],
            "compute_s": c["flops"] / R.PEAK_FLOPS,
            "memory_s": c["bytes"] / R.HBM_BW,
            "collective_s": c["wire_total"] / (R.LINK_BW * 2),
        }
        terms["dominant"] = max(
            [("compute", terms["compute_s"]), ("memory", terms["memory_s"]),
             ("collective", terms["collective_s"])], key=lambda kv: kv[1])[0]
        terms["step_s_lower_bound"] = max(terms["compute_s"], terms["memory_s"],
                                          terms["collective_s"])
        # useful-FLOPs ratio
        p_struct = params_struct(cfg)
        n_total = sum(math.prod(x.shape) for x in jax.tree_util.tree_leaves(p_struct))
        n_active = _active_params(cfg, p_struct)
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        mf = R.model_flops(n_active, tokens, shape.kind)
        n_chips = 512 if multi_pod else 256
        terms["model_flops_global"] = mf
        hlo_global = terms["flops_per_chip"] * n_chips
        terms["useful_flops_ratio"] = mf / hlo_global if hlo_global else 0.0
        terms["n_params"] = n_total
        terms["n_active_params"] = n_active
        result["trips"] = m["trips"]
        result["raw"] = m["raw"]  # per-knob measurements (slope analysis)
        result["roofline"] = terms
        result["compile_seconds"] = time.time() - t0
        result["ok"] = True
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
                  f"compute={terms['compute_s']:.4f}s memory={terms['memory_s']:.4f}s "
                  f"collective={terms['collective_s']:.4f}s dominant={terms['dominant']} "
                  f"useful={terms['useful_flops_ratio']:.2f} "
                  f"(compile {result['compile_seconds']:.0f}s)")
    except Exception as e:
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL {result['error']}")
    return result


def _active_params(cfg: ModelConfig, p_struct) -> int:
    flat = jax.tree_util.tree_flatten_with_path(p_struct)[0]
    active = 0
    for path, leaf in flat:
        size = math.prod(leaf.shape)
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if "/moe/" in pstr and "router" not in pstr:
            active += size * cfg.num_experts_per_tok // max(1, cfg.num_experts)
        else:
            active += size
    return active


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--variant", default=None,
                    help="cfg overrides key=val[,key=val...], e.g. "
                         "param_mode=tp or moe_groups=16 (named in output)")
    ap.add_argument("--tag", default=None, help="suffix for the output file")
    args = ap.parse_args()
    overrides = {}
    if args.variant:
        import ast
        for kv in args.variant.split(";"):
            k, v = kv.split("=", 1)
            try:
                overrides[k] = ast.literal_eval(v)
            except (ValueError, SyntaxError):
                overrides[k] = v

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    n_fail = 0
    for arch, shape in cells:
        res = run_cell(arch, shape, multi_pod=args.multi_pod, mesh=mesh,
                       overrides=overrides)
        tag = f"__{args.tag}" if args.tag else ""
        fname = f"{arch.replace('-', '_')}__{shape}__{mesh_name}{tag}.json"
        with open(os.path.join(args.out, fname), "w") as f:
            json.dump(res, f, indent=1)
        n_fail += 0 if res["ok"] else 1
    print(f"[dryrun] done: {len(cells) - n_fail}/{len(cells)} cells OK")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
