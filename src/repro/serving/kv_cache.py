"""Paged KV cache on the CMP slot pool.

Pages are the queue nodes of the paper, transplanted (DESIGN.md §2):

  * a page is produced (allocated) with a monotone cycle — type-stable pool,
    never freed, only recycled;
  * a finishing/preempted request *retires* its pages (AVAILABLE->CLAIMED);
  * the engine's step counter is the cycle clock: each step unilaterally
    publishes ``deque_cycle = step`` (monotone, no coordination), and retired
    pages are reclaimed only when ``retire_cycle < step - W`` — so any decode
    step, DMA, or cross-host read launched in the last W steps can never see
    a recycled page (bounded-window UAF/ABA safety instead of refcounts).

Replaces: reference-counted block pools (vLLM-style) which need atomic
refcount traffic per block per step and stop-the-world compaction.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import slotpool as sp


class PagedKVPool:
    def __init__(self, cfg: ModelConfig, *, num_pages: int, page_size: int,
                 window: int, dtype=None):
        self.cfg = cfg
        self.page_size = page_size
        self.num_pages = num_pages
        self.window = window
        r = cfg.pattern_repeats
        n_attn = sum(1 for k in cfg.block_pattern if k in ("dense", "moe", "hymba"))
        self.layers = r * n_attn
        dt = dtype or jnp.dtype(cfg.dtype)
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        # [L, P, KV, page, hd] — stacked over attention layers
        self.k_pages = jnp.zeros((self.layers, num_pages, kv, page_size, hd), dt)
        self.v_pages = jnp.zeros((self.layers, num_pages, kv, page_size, hd), dt)
        self.pool = sp.make(num_pages)

    # ------------------------------------------------------------------
    def tick(self, step: int) -> None:
        """Unilateral monotone boundary publish + window reclamation."""
        self.pool = sp.advance(self.pool, jnp.int32(step))
        self.pool, _ = sp.reclaim_retired(self.pool, self.window)

    def alloc(self, n: int) -> Tuple[jax.Array, jax.Array]:
        """Allocate n pages (FREE -> AVAILABLE/live). Returns (ids, valid)."""
        self.pool, ids, valid = sp.produce_with_reclaim(self.pool, n, self.window)
        return ids, valid

    def retire(self, ids: jax.Array) -> None:
        """Request done/preempted: pages become reclamation candidates after
        the window elapses. Never blocks; never coordinates."""
        valid = ids < self.num_pages
        self.pool = sp.claim_ids(self.pool, ids, valid)

    def free_pages(self) -> int:
        return sp.counts(self.pool)["free"]

    def live_pages(self) -> int:
        c = sp.counts(self.pool)
        return c["available"] + c["claimed"]
