"""Paged KV cache on the CMP slot pool.

Pages are the queue nodes of the paper, transplanted (DESIGN.md §2) — the
third embodiment of the unified protection domain
(:mod:`repro.core.domain`):

  * a page is produced (allocated) with a monotone cycle — type-stable pool,
    never freed, only recycled;
  * a finishing/preempted request *retires* its pages (AVAILABLE->CLAIMED);
  * the engine's step counter is the cycle clock: each step unilaterally
    publishes ``deque_cycle = step`` (monotone, no coordination), and retired
    pages are reclaimed only when ``retire_cycle < step - W``
    (``domain.reclaim_retired_mask``) — so any decode step, DMA, or
    cross-host read launched in the last W steps can never see a recycled
    page (bounded-window UAF/ABA safety instead of refcounts).

Replaces: reference-counted block pools (vLLM-style) which need atomic
refcount traffic per block per step and stop-the-world compaction.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import slotpool as sp
from repro.core.domain import (
    AVAILABLE,
    CLAIMED,
    FREE,
    compute_window,
    reclaim_retired_mask,
    safe_cycle,
)


class PagedKVPool:
    def __init__(self, cfg: ModelConfig, *, num_pages: int, page_size: int,
                 window: Optional[int] = None, dtype=None,
                 steps_per_sec: float = 100.0, resilience_s: float = 0.1):
        self.cfg = cfg
        self.page_size = page_size
        self.num_pages = num_pages
        # Window sizing is the domain formula W = max(MIN_WINDOW, OPS x R)
        # with OPS = decode steps/s and R = max request-preemption latency
        # before its blocks may be recycled (DESIGN.md §2).
        self.window = int(window) if window is not None else compute_window(
            steps_per_sec, resilience_s)
        r = cfg.pattern_repeats
        n_attn = sum(1 for k in cfg.block_pattern if k in ("dense", "moe", "hymba"))
        self.layers = r * n_attn
        dt = dtype or jnp.dtype(cfg.dtype)
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        # [L, P, KV, page, hd] — stacked over attention layers
        self.k_pages = jnp.zeros((self.layers, num_pages, kv, page_size, hd), dt)
        self.v_pages = jnp.zeros((self.layers, num_pages, kv, page_size, hd), dt)
        self.pool = sp.make(num_pages)
        # Optional tenant quota ledger (duck-typed: charge/credit, see
        # repro.sched.tenants.TenantQuotaLedger — kept out of the
        # constructor so engines stay ledger-agnostic). When attached,
        # alloc_for/retire_for meter per-tenant page occupancy against it;
        # the plain alloc/retire paths are untouched.
        self.ledger = None

    def attach_ledger(self, ledger, host: int = 0) -> None:
        """Attach a per-tenant page-quota ledger (any object with
        ``charge(tenant, host, pages) -> bool`` /
        ``credit(tenant, host, pages)``). Engine code keeps calling
        ``alloc``/``retire``; tenant-aware callers use
        ``alloc_for``/``retire_for`` instead."""
        self.ledger = ledger
        self._ledger_host = int(host)

    # ------------------------------------------------------------------
    def tick(self, step: int) -> None:
        """Unilateral monotone boundary publish + window reclamation."""
        self.pool = sp.advance(self.pool, jnp.int32(step))
        self.pool, _ = sp.reclaim_retired(self.pool, self.window)

    def alloc(self, n: int) -> Tuple[jax.Array, jax.Array]:
        """Allocate n pages (FREE -> AVAILABLE/live). Returns (ids, valid)."""
        self.pool, ids, valid = sp.produce_with_reclaim(self.pool, n, self.window)
        return ids, valid

    def retire(self, ids: jax.Array) -> None:
        """Request done/preempted: pages become reclamation candidates after
        the window elapses. Never blocks; never coordinates."""
        valid = ids < self.num_pages
        self.pool = sp.claim_ids(self.pool, ids, valid)

    # ---------------------------------------------------- tenant metering
    def alloc_for(self, tenant, n: int) -> Tuple[jax.Array, jax.Array]:
        """Tenant-metered ``alloc``: charge the attached ledger before
        touching the pool, so a tenant over quota is denied without
        consuming a produce cycle. Denials return (empty, empty) — the
        same shape callers already handle for a dry pool. Without a
        ledger this is exactly ``alloc``."""
        if self.ledger is not None and n > 0:
            if not self.ledger.charge(tenant, self._ledger_host, n):
                empty = jnp.zeros((0,), jnp.int32)
                return empty, empty
        ids, valid = self.alloc(n)
        if self.ledger is not None and n > 0:
            granted = int(jnp.sum(valid))
            if granted < n:  # pool dry: give back the unfilled estimate
                self.ledger.credit(tenant, self._ledger_host, n - granted)
        return ids, valid

    def retire_for(self, tenant, ids: jax.Array) -> None:
        """Tenant-metered ``retire``: credit the ledger for every page
        actually returned. Without a ledger this is exactly ``retire``."""
        pages = int(jnp.sum(ids < self.num_pages))
        self.retire(ids)
        if self.ledger is not None and pages > 0:
            self.ledger.credit(tenant, self._ledger_host, pages)

    # ------------------------------------------------------------------
    def free_pages(self) -> int:
        return int(jnp.sum(self.pool.state == FREE))

    def live_pages(self) -> int:
        return int(jnp.sum((self.pool.state == AVAILABLE)
                           | (self.pool.state == CLAIMED)))

    def reclaimable_pages(self) -> int:
        """Pages whose retire cycle fell behind the window — exactly the
        domain predicate the next ``tick`` will recycle."""
        return int(jnp.sum(reclaim_retired_mask(
            self.pool.state, self.pool.retire_cycle,
            self.pool.deque_cycle, self.window)))

    def protection_boundary(self) -> int:
        """Current safe cycle max(0, deque_cycle - W) (diagnostics)."""
        return int(safe_cycle(int(self.pool.deque_cycle), self.window))
