"""Paged decode/prefill paths: model forward where attention reads/writes
CMP-managed KV pages instead of a dense per-request cache.

Supports attention-bearing families (dense / moe / vlm / audio backbone).
Pages allocated to a request are *sequential in position* (page j covers
positions [j*page, (j+1)*page)), so the gathered page sequence is position-
ordered and the attention mask is a simple length mask.

The gather formulation lowers to XLA gathers (shardable); on TPU the
``repro.kernels.paged_attention`` Pallas kernel implements the same op with
scalar-prefetch DMA (validated against the same oracle).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import model as M


def _proj_qkv(x, p, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _scatter_pages(k_pages, v_pages, k_new, v_new, block_tables, positions):
    """k_pages [P,KV,pg,hd]; k_new [B,S,KV,hd]; positions [B,S] absolute."""
    pg = k_pages.shape[2]
    page_rows = jnp.take_along_axis(block_tables, positions // pg, axis=1)  # [B,S]
    slots = positions % pg
    k_pages = k_pages.at[page_rows, :, slots].set(k_new.astype(k_pages.dtype))
    v_pages = v_pages.at[page_rows, :, slots].set(v_new.astype(v_pages.dtype))
    return k_pages, v_pages


def _gathered_attention(q, k_pages, v_pages, block_tables, positions, seq_lens,
                        softcap: float = 0.0):
    """Gather each request's pages and run masked attention.
    q [B,S,H,hd]; returns [B,S,H,hd]."""
    B = q.shape[0]
    P, KV, pg, hd = k_pages.shape
    pps = block_tables.shape[1]
    kg = k_pages[block_tables]  # [B, pps, KV, pg, hd]
    vg = v_pages[block_tables]
    kg = jnp.moveaxis(kg, 2, 3).reshape(B, pps * pg, KV, hd)
    vg = jnp.moveaxis(vg, 2, 3).reshape(B, pps * pg, KV, hd)
    k_pos = jnp.arange(pps * pg, dtype=jnp.int32)[None, :].repeat(B, axis=0)
    k_pos = jnp.where(k_pos < seq_lens[:, None], k_pos, -1)  # mask invalid
    return L.cache_attention(q, kg, vg, positions, k_pos, softcap=softcap)


def _paged_block(x, p, cfg: ModelConfig, kind: str, k_pages, v_pages,
                 block_tables, positions, seq_lens):
    h_in = L.norm(x, p["ln1"], cfg.norm)
    q, k_new, v_new = _proj_qkv(h_in, p["attn"], cfg, positions)
    k_pages, v_pages = _scatter_pages(k_pages, v_pages, k_new, v_new,
                                      block_tables, positions)
    attn = _gathered_attention(q, k_pages, v_pages, block_tables, positions,
                               seq_lens, cfg.attn_softcap)
    B, S = x.shape[0], x.shape[1]
    attn = attn.reshape(B, S, cfg.num_heads * cfg.resolved_head_dim) @ p["attn"]["wo"]
    x = x + attn
    if kind == "moe":
        y, _ = MOE.moe_block(L.norm(x, p["ln2"], cfg.norm), p["moe"],
                             num_experts=cfg.num_experts,
                             top_k=cfg.num_experts_per_tok,
                             capacity_factor=cfg.capacity_factor, act=cfg.act)
        x = x + y
    else:
        x = x + L.swiglu(L.norm(x, p["ln2"], cfg.norm), p["mlp"], cfg.act)
    return x, k_pages, v_pages


def paged_forward(params, tokens, cfg: ModelConfig, k_pages, v_pages,
                  block_tables, seq_lens) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Shared prefill/decode body. tokens [B, S] start at position seq_lens
    (S=prompt for prefill with seq_lens=0, S=1 for decode).
    k/v_pages: [L_attn, P, KV, pg, hd] stacked over attention layers.
    Returns (last-token logits [B, V], k_pages', v_pages')."""
    x = params["embed"][tokens]
    B, S = tokens.shape
    positions = seq_lens[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    attn_kinds = [k for k in cfg.block_pattern if k in ("dense", "moe")]
    assert len(attn_kinds) == len(cfg.block_pattern), (
        "paged serving supports attention-based families only")

    def step(carry, xs):
        x = carry
        layer_p, kp, vp = xs
        new_kp, new_vp = [], []
        for j, kind in enumerate(cfg.block_pattern):
            x, nk, nv = _paged_block(x, layer_p[str(j)], cfg, kind,
                                     kp[j], vp[j], block_tables,
                                     positions, seq_lens + S)
            new_kp.append(nk)
            new_vp.append(nv)
        return x, (jnp.stack(new_kp), jnp.stack(new_vp))

    r = cfg.pattern_repeats
    n_pat = len(cfg.block_pattern)
    kp_s = k_pages.reshape((r, n_pat) + k_pages.shape[1:])
    vp_s = v_pages.reshape((r, n_pat) + v_pages.shape[1:])
    x, (new_kp, new_vp) = jax.lax.scan(step, x, (params["blocks"], kp_s, vp_s))
    x = L.norm(x, params["final_norm"], cfg.norm)
    logits = M._logits(x[:, -1:], params, cfg)[:, 0]
    return logits, new_kp.reshape(k_pages.shape), new_vp.reshape(v_pages.shape)
