"""Continuous-batching serving engine on the priority-class CMP queue fabric.

CMP end to end:
  * admission — requests enter through the :mod:`repro.sched` fabric: one
    :class:`QueueClass` per tenant/priority tier (strict FIFO *within* a
    class, window-bounded admission), a pluggable policy (strict-priority /
    weighted-fair / FIFO-across-classes) composing one batched drain per
    engine step. A single default class reproduces the original global
    strict-FIFO queue exactly;
  * KV memory — pages from :class:`PagedKVPool`; finished/preempted requests
    retire pages which recycle after the protection window W (no refcounts,
    no sweep barrier);
  * overload — if the pool runs dry the engine preempts the least entitled
    lane: lowest class priority first, youngest class cycle within it. The
    victim's pages retire and its request re-enters *its own* class queue at
    its original cycle position (served again before anything younger in the
    class). Recovery is automatic: the pages return to FREE after W steps.

The scheduler is vectorized: ``block_tables``/``seq_lens``/``last_tok`` live
on device across steps (no numpy re-wrap per iteration), per-lane decode
bookkeeping is array ops over the lane tables, page growth is one batched
allocation per step, and prefill/decode share a single compiled callable.

Scale-out is :class:`EngineReplicaGroup` (DESIGN.md §9): N of these engines
over one fabric, each fed by a :class:`~repro.sched.SchedulerReplica` that
owns a seat subset of every class, rebalanced purely by seat-claim steals,
with exact-seat frontier checkpointing via :meth:`EngineReplicaGroup.sched_state`.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sched import Envelope, QueueClass, ReplicaSet, Scheduler
from repro.serving.admission import DeviceAdmissionRing, resolve_device_admission
from repro.serving.kv_cache import PagedKVPool
from repro.serving.paged_model import paged_forward


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    qclass: str = "default"
    output: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0


def request_state(req: "Request") -> dict:
    """JSON-able snapshot of a request for frontier checkpointing. Decoded
    output is deliberately not captured: a restored request re-enters its
    class at its original cycle seat and re-prefills — the same contract as
    preemption."""
    return {"uid": req.uid, "prompt": list(req.prompt),
            "max_new_tokens": req.max_new_tokens, "qclass": req.qclass,
            "preemptions": req.preemptions}


def request_from_state(state: dict) -> "Request":
    req = Request(state["uid"], list(state["prompt"]),
                  state["max_new_tokens"], qclass=state["qclass"])
    req.preemptions = state["preemptions"]
    return req


class Engine:
    # flight-recorder attachment (repro.obs): the feeding replica's ring;
    # None until a MetricsHub attaches (re-applied on resize/fail_host,
    # which rebuild engines)
    _obs = None

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 page_size: int = 16, num_pages: int = 64, window: int = 4,
                 max_seq: int = 128,
                 classes: Optional[Sequence[QueueClass]] = None,
                 policy="strict", sched=None, forward_fn=None,
                 device_admission=False, admit_prefetch: int = 0):
        assert all(k in ("dense", "moe") for k in cfg.block_pattern), \
            "paged engine serves attention-based families"
        self.cfg, self.params = cfg, params
        self.max_batch, self.page_size, self.max_seq = max_batch, page_size, max_seq
        self.pps = max_seq // page_size
        self.pool = PagedKVPool(cfg, num_pages=num_pages, page_size=page_size,
                                window=window)
        # Reserve page 0 as the scratch target for inactive batch lanes
        # (their masked decode writes land here, never on live pages).
        scratch, ok = self.pool.alloc(1)
        assert bool(ok.all()) and int(scratch[0]) == 0
        if sched is None:
            if classes is None:
                classes = [QueueClass("default", window=max(64, window),
                                      reclaim_period=32)]
            sched = Scheduler(classes, policy=policy)
        # Any Scheduler-shaped drain source works: the engine only ever
        # calls drain/policy/classes/pending/submit — a SchedulerReplica
        # (sched/replica.py) plugs in here to make this engine one of N.
        self.sched = sched
        self.step_count = 0
        self._uid = itertools.count()
        # active request table (host side); lane tensors are device-resident
        # across steps — the decode path never round-trips through numpy.
        self.active: List[Optional[Request]] = [None] * max_batch
        # the envelope each lane was admitted with: (QueueClass, Envelope);
        # preemption requeues it so the request keeps its class-cycle seat
        self._lane_env: List[Optional[Tuple[QueueClass, Envelope]]] = \
            [None] * max_batch
        self.block_tables = jnp.zeros((max_batch, self.pps), jnp.int32)
        self.seq_lens = jnp.zeros((max_batch,), jnp.int32)
        self.last_tok = jnp.zeros((max_batch,), jnp.int32)
        self.completed: Dict[int, Request] = {}
        # Prefill and decode are the same function traced at different
        # sequence lengths — one jit, one compilation cache. Replicas pass a
        # shared callable so N engines share one compilation cache.
        self._forward = forward_fn or jax.jit(
            lambda p, t, kp, vp, bt, sl: paged_forward(p, t, cfg, kp, vp, bt, sl))
        # Device-resident admission (DESIGN.md §12): policy-drained batches
        # route through a bounded CMP ring on the accelerator — one fused
        # reclaim+enqueue+claim+publish invocation per step. "auto" enables
        # it only when a TPU is attached (host-fallback rule); True forces
        # the ring path (the jit'd oracle stands in for Pallas on CPU hosts).
        self._dev_admit = None
        self._admit_prefetch = 0
        if resolve_device_admission(device_admission):
            # claim look-ahead well past max_batch: the fused invocation's
            # fixed dispatch cost divides by claim_block, and the ordering
            # relaxation it buys stays bounded by the prefetch depth.
            self._dev_admit = DeviceAdmissionRing(
                k=max_batch, claim_block=max(8 * max_batch, 2 * max_batch))
            self._admit_prefetch = (int(admit_prefetch)
                                    or 2 * self._dev_admit.claim_block)

    @property
    def pending(self) -> int:
        """Accepted-but-not-laned items (incl. requeues and ring-resident
        prefetch), derived from the scheduler's and ring's own counters —
        no engine-side bookkeeping to drift."""
        return self.sched.pending() + self.ring_pending

    @property
    def ring_pending(self) -> int:
        """Entries prefetched into the device admission ring (0 on the
        host path)."""
        return 0 if self._dev_admit is None else self._dev_admit.pending

    def flush_admission(self) -> None:
        """Return every ring-resident entry to its exact class seat — the
        checkpoint / resize / fail-host boundary (no-op on the host path)."""
        if self._dev_admit is not None:
            for qc, env in self._dev_admit.flush():
                qc.requeue(env)

    # ---------------------------------------------------------------- client
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               qclass: Optional[str] = None) -> Optional[int]:
        """Enqueue one request into its class; returns its uid, or None when
        the class's admission window rejected it (backpressure)."""
        name = qclass or self.sched.default_class
        req = Request(next(self._uid), list(prompt), max_new_tokens,
                      qclass=name)
        if self.sched.submit(name, req) is None:
            return None
        return req.uid

    def submit_many(self, prompts: List[List[int]], max_new_tokens: int = 16,
                    qclass: Optional[str] = None) -> List[Optional[int]]:
        """Batched admission enqueue: one class-cycle-range fetch-add + one
        splice per shard for the whole burst. Window-rejected entries come
        back as None."""
        name = qclass or self.sched.default_class
        reqs = [Request(next(self._uid), list(p), max_new_tokens, qclass=name)
                for p in prompts]
        envs = self.sched.submit_many(name, reqs)
        return [r.uid if e is not None else None for r, e in zip(reqs, envs)]

    # ---------------------------------------------------------------- pages
    def _alloc_pages(self, n: int) -> Optional[np.ndarray]:
        if n == 0:
            return np.zeros((0,), np.int32)
        ids, valid = self.pool.alloc(n)
        ids, valid = np.asarray(ids), np.asarray(valid)
        if not valid.all():
            self.pool.retire(jnp.asarray(ids))  # return partial grab
            return None
        return ids

    def _retire_request(self, lane: int) -> None:
        used = (int(self.seq_lens[lane]) + self.page_size - 1) // self.page_size
        if used > 0:
            self.pool.retire(self.block_tables[lane, :used])
        self.block_tables = self.block_tables.at[lane].set(0)
        self.seq_lens = self.seq_lens.at[lane].set(0)
        self.active[lane] = None
        self._lane_env[lane] = None

    def _entitlement(self, lane: int):
        """Lane sort key, least entitled first: lowest class priority, then
        youngest arrival. Age ties are broken on the fabric-global arrival
        stamp, not the class cycle — class cycles are independent counters,
        so only the stamp is comparable across classes (within one class the
        two orders agree)."""
        qc, env = self._lane_env[lane]
        return (qc.priority, -env.stamp)

    def _evict_lane(self, lane: int) -> None:
        """Preempt one lane: retire its pages (they recycle after W steps)
        and requeue the request into *its own* class at its original cycle —
        its FIFO seat within the class is kept."""
        qc, env = self._lane_env[lane]
        req = self.active[lane]
        req.preemptions += 1
        req.output = []
        self._retire_request(lane)
        qc.requeue(env)

    def _preempt_for(self, prio: int, stamp: int) -> bool:
        """Free pages for a claimant entitled as (class priority, arrival
        stamp): evict the least entitled active lane — lowest class first,
        youngest arrival within it — but never one at least as entitled
        as the claimant (no priority inversion, no age inversion)."""
        lanes = [i for i, r in enumerate(self.active) if r is not None]
        if not lanes:
            return False
        lane = min(lanes, key=self._entitlement)
        if self._entitlement(lane) >= (prio, -stamp):
            return False
        self._evict_lane(lane)
        return True

    # ---------------------------------------------------------------- sched
    def _drain_admission(self, want: int):
        """Compose the admission batch of (QueueClass, Envelope) pairs.

        Host path: one policy drain. Ring path: top the device ring up from
        the scheduler (bulk drain when the fabric shape allows the O(1)
        frontier advance) and claim ``want`` lanes in one fused device step.
        Ring-rejected entries (ring full — rare by construction, the ring is
        sized for the prefetch depth) go straight back to their exact class
        seats. Prefetched entries admit in ring-cycle order, which relaxes
        cross-refill policy order by at most the prefetch depth (DESIGN.md
        §12); within one refill the policy's order is preserved exactly.
        """
        if self._dev_admit is None:
            return self.sched.drain(want)
        ring = self._dev_admit
        fresh = []
        if ring.buffered < want:  # a fused invocation is imminent: top up
            need = max(want, self._admit_prefetch) - ring.pending
            if need > 0:
                drain = (getattr(self.sched, "drain_bulk", None)
                         or self.sched.drain)
                fresh = drain(min(need, ring.room))
        claimed, rejected = ring.step(fresh, want)
        for qc, env in rejected:
            qc.requeue(env)
        return claimed

    def _admit(self) -> None:
        free = [i for i, r in enumerate(self.active) if r is None]
        # Class-aware lane preemption: pending work of a *strictly higher*
        # class claims lanes even when none are free, evicting the least
        # entitled occupants (equal-priority pending never lane-preempts —
        # it waits for a natural completion, as in the single-class engine).
        # Only under a priority-honoring policy: otherwise the next drain is
        # not guaranteed to admit the higher class, and the eviction could be
        # undone immediately (e.g. a FIFO merge re-admitting the victim).
        while self.sched.policy.honors_priority and len(free) < self.max_batch:
            occupied = [i for i, r in enumerate(self.active) if r is not None]
            lane = min(occupied, key=self._entitlement)
            victim_prio = self._lane_env[lane][0].priority
            higher_pending = sum(qc.pending() for qc in self.sched.classes
                                 if qc.priority > victim_prio)
            if higher_pending <= len(free):
                break
            self._evict_lane(lane)
            free.append(lane)
        if not free:
            return
        # ONE policy drain composes the admission batch across classes
        # (batched dequeue_many claims under the hood, strict FIFO per class);
        # on the ring path the batch instead comes out of one fused device
        # claim over the prefetched entries.
        batch = self._drain_admission(len(free))
        for idx, (lane, (qc, env)) in enumerate(zip(free, batch)):
            req: Request = env.payload
            need = (len(req.prompt) + self.page_size - 1) // self.page_size
            pages = self._alloc_pages(max(1, need))
            while pages is None:
                if not self._preempt_for(qc.priority, env.stamp):
                    # Pool dry, nothing less entitled to evict: every request
                    # not yet laned goes back to its own class, at its own
                    # cycle seat (redelivered first next drain).
                    for qc2, env2 in batch[idx:]:
                        qc2.requeue(env2)
                    return
                pages = self._alloc_pages(max(1, need))
            self.active[lane] = req
            self._lane_env[lane] = (qc, env)
            self.block_tables = self.block_tables.at[lane, :len(pages)].set(
                jnp.asarray(pages))
            self.seq_lens = self.seq_lens.at[lane].set(0)
            # prefill: process the whole prompt at once (same compiled
            # callable as decode, traced at the prompt length)
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            bt = self.block_tables[lane:lane + 1]
            sl = jnp.zeros((1,), jnp.int32)
            logits, self.pool.k_pages, self.pool.v_pages = self._forward(
                self.params, toks, self.pool.k_pages, self.pool.v_pages, bt, sl)
            tok = int(jnp.argmax(logits[0]))
            self.seq_lens = self.seq_lens.at[lane].set(len(req.prompt))
            self.last_tok = self.last_tok.at[lane].set(tok)
            req.output.append(tok)
            rec = self._obs
            if rec is not None and rec.sampled(env.seq):
                rec.emit("lane_prefill", qc.name, env.seq, arg=lane)

    def _grow_pages(self) -> None:
        """Allocate fresh pages for every lane whose next token crosses a page
        boundary — one batched allocation for all of them (pool pressure
        triggers preemption, paper Alg 1 Phase 1)."""
        sl = np.asarray(self.seq_lens)
        used = -(-sl // self.page_size)
        need = -(-(sl + 1) // self.page_size)
        lanes = [i for i, r in enumerate(self.active)
                 if r is not None and need[i] > used[i]]
        if not lanes:
            return
        # Fast path: enough FREE pages for every growing lane -> one batched
        # grab + one scatter. (Single scheduler thread: the check can't race.)
        if self.pool.free_pages() >= len(lanes):
            pages = self._alloc_pages(len(lanes))
            if pages is not None:
                rows = jnp.asarray(lanes, jnp.int32)
                cols = jnp.asarray(used[lanes], jnp.int32)
                self.block_tables = self.block_tables.at[rows, cols].set(
                    jnp.asarray(pages))
                return
        # Pool pressure: grow lane by lane (earliest lane first) so partial
        # availability is used instead of burned, preempting as needed (the
        # growing lane's own entitlement decides who may be evicted); a
        # lane preempted out from under us is skipped.
        for lane in lanes:
            if self.active[lane] is None:
                continue
            qc, env = self._lane_env[lane]
            page = self._alloc_pages(1)
            while page is None:
                if (not self._preempt_for(qc.priority, env.stamp)
                        or self.active[lane] is None):
                    break
                page = self._alloc_pages(1)
            if page is not None and self.active[lane] is not None:
                self.block_tables = self.block_tables.at[
                    lane, int(used[lane])].set(int(page[0]))
            elif page is None and self.active[lane] is not None:
                # Nobody less entitled to evict and the pool is dry: the
                # growing lane must preempt *itself* (requeue at its cycle
                # seat) — decoding on without the page would write this
                # position's KV into the scratch page and corrupt the output.
                self._evict_lane(lane)

    # ---------------------------------------------------------------- step
    def step(self) -> List[Request]:
        """One engine iteration: tick window clock, reclaim, admit, decode."""
        self.step_count += 1
        self.pool.tick(self.step_count)
        self._admit()
        self._grow_pages()
        active_np = np.array([r is not None for r in self.active])
        if not active_np.any():
            return []
        # Decode all lanes in one call on the device-resident tables.
        logits, self.pool.k_pages, self.pool.v_pages = self._forward(
            self.params, self.last_tok[:, None], self.pool.k_pages,
            self.pool.v_pages, self.block_tables, self.seq_lens)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        mask = jnp.asarray(active_np)
        self.seq_lens = self.seq_lens + mask.astype(jnp.int32)
        self.last_tok = jnp.where(mask, nxt, self.last_tok)
        # single host sync per step for completion bookkeeping
        nxt_np = np.asarray(nxt)
        sl_np = np.asarray(self.seq_lens)
        done = []
        rec = self._obs
        for lane in np.nonzero(active_np)[0]:
            req = self.active[lane]
            req.output.append(int(nxt_np[lane]))
            lane_env = self._lane_env[lane]
            traced = (rec is not None and lane_env is not None
                      and rec.sampled(lane_env[1].seq))
            if traced and len(req.output) == 2:
                # first post-prefill token: the lane has entered steady decode
                rec.emit("decode", lane_env[0].name, lane_env[1].seq,
                         arg=int(lane))
            if (len(req.output) >= req.max_new_tokens
                    or sl_np[lane] + 1 >= self.max_seq):
                done.append(req)
                self.completed[req.uid] = req
                if traced:
                    rec.emit("complete", lane_env[0].name, lane_env[1].seq,
                             arg=len(req.output))
                self._retire_request(int(lane))
        return done

    def run_until_idle(self, max_steps: int = 1000) -> Dict[int, Request]:
        for _ in range(max_steps):
            self.step()
            if all(r is None for r in self.active) and self.pending == 0:
                break
        return self.completed

    # ------------------------------------------------------------ telemetry
    def class_stats(self) -> dict:
        """Per-class fabric snapshot (occupancy, admission latency, rejects)
        — reads existing domain counters only."""
        return self.sched.snapshot()


def _split_budget(total: int, parts: int) -> List[int]:
    """Partition an integer budget as evenly as possible, every part >= 1."""
    assert total >= parts, f"budget {total} cannot cover {parts} replicas"
    base, rem = divmod(total, parts)
    return [base + (1 if i < rem else 0) for i in range(parts)]


def _split_budget_hosted(total: int, hosts: List[int],
                         min_per: int = 1) -> List[int]:
    """Host-aware budget partition: every replica is granted ``min_per``
    first (an engine needs 1 lane, and 2 pages — the reserved scratch page
    plus one live page — to serve at all), then the *remainder* splits
    evenly across the hosts (a host's lanes and pages are physically its
    own — DESIGN.md §11) and each host divides its share among its own
    replicas. With one host this degenerates to :func:`_split_budget`
    exactly; with replicas spread unevenly (e.g. 3 replicas on 2 hosts)
    each host still gets an equal share of the surplus without ever
    pushing a lone replica below the serving minimum."""
    n = len(hosts)
    assert total >= min_per * n, (
        f"budget {total} cannot give {n} replicas {min_per} each")
    out = [min_per] * n
    rem = total - min_per * n
    uniq = sorted(set(hosts))
    base, extra = divmod(rem, len(uniq))
    for j, h in enumerate(uniq):
        share = base + (1 if j < extra else 0)
        rids = [i for i, hh in enumerate(hosts) if hh == h]
        b, e = divmod(share, len(rids))
        for k, i in enumerate(rids):
            out[i] += b + (1 if k < e else 0)
    return out


class EngineReplicaGroup:
    """N engine replicas over one class fabric (DESIGN.md §9).

    Each replica is a full :class:`Engine` — its own lanes, its own page
    pool (the lane and page budgets are partitioned, not shared), its own
    policy drain — fed by a :class:`~repro.sched.SchedulerReplica` that
    owns a seat subset of every class. Replicas share the model params and
    one compiled forward (same shapes -> one jit cache). Rebalancing is
    pure stealing: a starved replica claims a whole cycle-run seat with one
    CAS; no replica ever blocks on another.

    The group is also the checkpoint boundary: :meth:`sched_state` is an
    exact-seat frontier snapshot taken between steps (active lanes are
    recorded at their original seats, like preemption victims), and
    :meth:`from_sched_state` restores a group in which every tenant resumes
    at its exact FIFO seat.
    """

    def __init__(self, cfg: ModelConfig, params, *, num_replicas: int = 2,
                 max_batch: int = 4, page_size: int = 16, num_pages: int = 64,
                 window: int = 4, max_seq: int = 128,
                 classes: Optional[Sequence[QueueClass]] = None,
                 policy="strict", min_steal: int = 1,
                 replica_set: Optional[ReplicaSet] = None,
                 forward_fn=None, uid_start: int = 0, transport=None,
                 device_admission=False):
        if replica_set is None:
            if classes is None:
                classes = [QueueClass("default", num_shards=num_replicas,
                                      window=max(64, window),
                                      reclaim_period=32)]
            replica_set = ReplicaSet(Scheduler(classes, policy=policy),
                                     num_replicas, policy=policy,
                                     min_steal=min_steal,
                                     transport=transport)
        self.replica_set = replica_set
        self.sched = replica_set.scheduler
        self.num_replicas = replica_set.num_replicas
        self._fwd = forward_fn or jax.jit(
            lambda p, t, kp, vp, bt, sl: paged_forward(p, t, cfg, kp, vp, bt, sl))
        # the fabric-wide budgets + geometry, retained so resize() can
        # re-partition them across a different replica count
        self.cfg, self.params = cfg, params
        self._budget = dict(max_batch=max_batch, page_size=page_size,
                            num_pages=num_pages, window=window,
                            max_seq=max_seq)
        self._device_admission = device_admission
        self._completed: Dict[int, Request] = {}  # survivors of resizes
        self.engines = self._build_engines()
        self._next_uid = int(uid_start)
        self.step_count = 0

    def _build_engines(self) -> List[Engine]:
        """One engine per *live* scheduler replica, the fabric-wide lane
        and page budgets partitioned host-first across them (each live
        transport host gets an equal hardware share, split among its
        replicas — a dead host's replicas get no engine and no budget),
        all sharing one compiled forward."""
        live = self.replica_set.live_replicas()
        assert live, "engine group with every host dead"
        hosts = [r.addr.host for r in live]
        lanes = _split_budget_hosted(self._budget["max_batch"], hosts,
                                     min_per=1)
        pages = _split_budget_hosted(self._budget["num_pages"], hosts,
                                     min_per=2)
        return [
            Engine(self.cfg, self.params, max_batch=lanes[i],
                   page_size=self._budget["page_size"], num_pages=pages[i],
                   window=self._budget["window"],
                   max_seq=self._budget["max_seq"],
                   sched=r, forward_fn=self._fwd,
                   device_admission=self._device_admission)
            for i, r in enumerate(live)]

    # ---------------------------------------------------------------- client
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               qclass: Optional[str] = None) -> Optional[int]:
        name = qclass or self.sched.default_class
        req = Request(self._next_uid, list(prompt), max_new_tokens,
                      qclass=name)
        if self.sched.submit(name, req) is None:
            return None
        self._next_uid += 1
        return req.uid

    def submit_many(self, prompts: List[List[int]], max_new_tokens: int = 16,
                    qclass: Optional[str] = None) -> List[Optional[int]]:
        name = qclass or self.sched.default_class
        reqs = []
        for p in prompts:
            reqs.append(Request(self._next_uid + len(reqs), list(p),
                                max_new_tokens, qclass=name))
        envs = self.sched.submit_many(name, reqs)
        self._next_uid += len(reqs)
        return [r.uid if e is not None else None for r, e in zip(reqs, envs)]

    # ---------------------------------------------------------------- step
    def step(self) -> List[Request]:
        """One group iteration: every live replica runs its own
        admit/decode step, then one steal pass rebalances starved
        replicas (dead hosts' engines are skipped — their lanes were
        evicted to exact seats by :meth:`fail_host`)."""
        self.step_count += 1
        done: List[Request] = []
        for eng in self.engines:
            if eng.sched.alive:
                done.extend(eng.step())
        self.replica_set.rebalance()
        return done

    def idle(self) -> bool:
        return (self.replica_set.pending() == 0
                and all(eng.ring_pending == 0 for eng in self.engines)
                and all(r is None for eng in self.engines
                        for r in eng.active))

    def run_until_idle(self, max_steps: int = 1000) -> Dict[int, Request]:
        for _ in range(max_steps):
            self.step()
            if self.idle():
                break
        return self.completed

    @property
    def completed(self) -> Dict[int, Request]:
        out: Dict[int, Request] = dict(self._completed)
        for eng in self.engines:
            out.update(eng.completed)
        return out

    # ------------------------------------------------------------- elasticity
    def resize(self, num_replicas: int) -> "EngineReplicaGroup":
        """Live replica elasticity: grow/shrink the running group to
        ``num_replicas`` engines with no drain pause — producers keep
        submitting throughout, nothing waits for in-flight work to finish.

        A resize is exactly two CMP moves:

          * every active lane is preempted to its exact class-cycle seat
            (the preemption contract — the request re-prefills on its next
            admission, served before anything younger in its class), which
            frees the lanes and pages for re-partitioning;
          * the scheduler fabric reseats via a batch of seat claims
            (:meth:`~repro.sched.ReplicaSet.resize`) and the fabric-wide
            lane/page budgets are re-split over the new engine count.

        Per-class FIFO delivery order is preserved exactly (asserted in
        tests/test_fabric.py under concurrent producers).
        """
        n = int(num_replicas)
        assert n >= 1
        if n == self.num_replicas:
            return self
        for eng in self.engines:
            eng.flush_admission()  # ring entries back to exact seats
            for lane, req in enumerate(eng.active):
                if req is not None:
                    eng._evict_lane(lane)  # exact-seat requeue
            self._completed.update(eng.completed)
        self.replica_set.resize(n)
        self.num_replicas = n
        self.engines = self._build_engines()
        return self

    def fail_host(self, host: int) -> int:
        """Kill one transport host mid-run: every lane on the dead host's
        engines is preempted to its exact class-cycle seat (the preemption
        contract — KV pages die with the host, the request re-prefills on
        its next admission), completed requests are carried, and the
        scheduler fabric replays the host's frontier state into the
        survivors (:meth:`~repro.sched.ReplicaSet.fail_host`). Returns the
        number of seats reassigned."""
        for eng in self.engines:
            if eng.sched.addr.host != host or not eng.sched.alive:
                continue
            eng.flush_admission()  # ring entries back to exact seats
            for lane, req in enumerate(eng.active):
                if req is not None:
                    eng._evict_lane(lane)  # exact-seat requeue
            self._completed.update(eng.completed)
        moved = self.replica_set.fail_host(host)
        # drop the dead engines: their KV pools die with the host and
        # step()/idle()/completed stop scanning them
        self.engines = [e for e in self.engines if e.sched.alive]
        return moved

    # ------------------------------------------------------------ checkpoint
    def sched_state(self) -> dict:
        """Exact-seat frontier snapshot of the serving fabric, taken
        between steps. Undrained seats are captured in place; requests
        currently *on a lane* are recorded at their original seats as
        requeue entries (their KV pages are not checkpointed — on restore
        they re-prefill, the preemption contract). The dict is plain JSON
        data: hand it to the async checkpointer's aux channel."""
        for eng in self.engines:
            eng.flush_admission()  # ring entries back to exact seats
        st = self.replica_set.state(encode=request_state)
        for eng in self.engines:
            for lane_env in eng._lane_env:
                if lane_env is None:
                    continue
                qc, env = lane_env
                st["classes"][qc.name]["requeue"].append(
                    [env.seq, env.stamp, request_state(env.payload)])
        for cs in st["classes"].values():
            cs["requeue"].sort(key=lambda rec: rec[0])
        st["next_uid"] = self._next_uid
        return st

    @classmethod
    def from_sched_state(cls, cfg: ModelConfig, params, state: dict, *,
                         policy="strict", min_steal: int = 1,
                         forward_fn=None, window: int = 4, transport=None,
                         **engine_kw) -> "EngineReplicaGroup":
        """Restore a replica group from :meth:`sched_state`: every tenant
        resumes at its exact FIFO seat (in-flight requests re-prefill),
        under whatever transport/host layout the restoring caller runs
        (seat owners re-address by replica). Each class's shard CMPQueue
        configuration is restored from the snapshot itself; ``window``
        here is only the KV pools' protection window."""
        rs = ReplicaSet.from_state(
            state, decode=request_from_state, policy=policy,
            min_steal=min_steal, transport=transport)
        return cls(cfg, params, replica_set=rs, forward_fn=forward_fn,
                   window=window, uid_start=state.get("next_uid", 0),
                   **engine_kw)

    # ------------------------------------------------------------ telemetry
    def class_stats(self) -> dict:
        """Fabric-wide per-class roll-up, same ``{name: snap}`` shape as
        :meth:`Engine.class_stats` — consumers never branch on replica
        count. Per-replica detail lives in :meth:`replica_stats`."""
        return self.replica_set.snapshot()["classes"]

    def replica_stats(self) -> dict:
        """Per-replica steal/idle/pending detail (domain counters only)."""
        return self.replica_set.snapshot()["replicas"]
