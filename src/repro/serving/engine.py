"""Continuous-batching serving engine.

CMP end to end:
  * admission — requests enter through a strict-FIFO :class:`CMPQueue`
    (global arrival order across submitter threads = fairness, the paper's
    strict-FIFO property doing real work); the scheduler drains it with one
    batched ``dequeue_many`` per step instead of a dequeue per lane;
  * KV memory — pages from :class:`PagedKVPool`; finished/preempted requests
    retire pages which recycle after the protection window W (no refcounts,
    no sweep barrier);
  * overload — if the pool runs dry the engine *preempts* the youngest
    request (retires its pages, requeues it). Recovery is automatic: the
    pages return to FREE after W steps. A stalled writer/reader can delay
    nothing (bounded reclamation).

The scheduler is vectorized: ``block_tables``/``seq_lens``/``last_tok`` live
on device across steps (no numpy re-wrap per iteration), per-lane decode
bookkeeping is array ops over the lane tables, page growth is one batched
allocation per step, and prefill/decode share a single compiled callable.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cmp import CMPQueue
from repro.serving.kv_cache import PagedKVPool
from repro.serving.paged_model import paged_forward


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    output: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 page_size: int = 16, num_pages: int = 64, window: int = 4,
                 max_seq: int = 128):
        assert all(k in ("dense", "moe") for k in cfg.block_pattern), \
            "paged engine serves attention-based families"
        self.cfg, self.params = cfg, params
        self.max_batch, self.page_size, self.max_seq = max_batch, page_size, max_seq
        self.pps = max_seq // page_size
        self.pool = PagedKVPool(cfg, num_pages=num_pages, page_size=page_size,
                                window=window)
        # Reserve page 0 as the scratch target for inactive batch lanes
        # (their masked decode writes land here, never on live pages).
        scratch, ok = self.pool.alloc(1)
        assert bool(ok.all()) and int(scratch[0]) == 0
        self.queue = CMPQueue(window=max(64, window), reclaim_period=32)
        self.step_count = 0
        self._uid = itertools.count()
        # active request table (host side); lane tensors are device-resident
        # across steps — the decode path never round-trips through numpy.
        self.active: List[Optional[Request]] = [None] * max_batch
        self.block_tables = jnp.zeros((max_batch, self.pps), jnp.int32)
        self.seq_lens = jnp.zeros((max_batch,), jnp.int32)
        self.last_tok = jnp.zeros((max_batch,), jnp.int32)
        self.completed: Dict[int, Request] = {}
        self.pending = 0  # submitted - admitted (emptiness check w/o dequeue)
        self._backlog: List[Request] = []  # head-of-line retries (keeps FIFO)
        # Prefill and decode are the same function traced at different
        # sequence lengths — one jit, one compilation cache.
        self._forward = jax.jit(
            lambda p, t, kp, vp, bt, sl: paged_forward(p, t, cfg, kp, vp, bt, sl))

    # ---------------------------------------------------------------- client
    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        uid = next(self._uid)
        self.pending += 1
        self.queue.enqueue(Request(uid, list(prompt), max_new_tokens))
        return uid

    def submit_many(self, prompts: List[List[int]], max_new_tokens: int = 16) -> List[int]:
        """Batched admission enqueue: one cycle-range fetch-add + one splice
        for the whole burst (CMPQueue.enqueue_many)."""
        reqs = [Request(next(self._uid), list(p), max_new_tokens) for p in prompts]
        self.pending += len(reqs)
        self.queue.enqueue_many(reqs)
        return [r.uid for r in reqs]

    # ---------------------------------------------------------------- pages
    def _alloc_pages(self, n: int) -> Optional[np.ndarray]:
        if n == 0:
            return np.zeros((0,), np.int32)
        ids, valid = self.pool.alloc(n)
        ids, valid = np.asarray(ids), np.asarray(valid)
        if not valid.all():
            self.pool.retire(jnp.asarray(ids))  # return partial grab
            return None
        return ids

    def _retire_request(self, lane: int) -> None:
        used = (int(self.seq_lens[lane]) + self.page_size - 1) // self.page_size
        if used > 0:
            self.pool.retire(self.block_tables[lane, :used])
        self.block_tables = self.block_tables.at[lane].set(0)
        self.seq_lens = self.seq_lens.at[lane].set(0)
        self.active[lane] = None

    def _preempt_youngest(self) -> bool:
        lanes = [i for i, r in enumerate(self.active) if r is not None]
        if not lanes:
            return False
        lane = max(lanes, key=lambda i: self.active[i].uid)
        req = self.active[lane]
        req.preemptions += 1
        req.output = []
        self._retire_request(lane)
        self.pending += 1
        self.queue.enqueue(req)  # back of the FIFO
        return True

    # ---------------------------------------------------------------- sched
    def _admit(self) -> None:
        free = [i for i, r in enumerate(self.active) if r is None]
        if not free:
            return
        # Head-of-line retries first, then ONE batched dequeue for the rest
        # of the free lanes (amortized claim, strict FIFO preserved).
        reqs = self._backlog[:len(free)]
        del self._backlog[:len(reqs)]
        if len(reqs) < len(free):
            reqs.extend(self.queue.dequeue_many(len(free) - len(reqs)))
        for idx, (lane, req) in enumerate(zip(free, reqs)):
            self.pending -= 1
            need = (len(req.prompt) + self.page_size - 1) // self.page_size
            pages = self._alloc_pages(max(1, need))
            while pages is None:
                if not self._preempt_youngest():
                    # Pool dry, nothing to preempt: park this and every
                    # not-yet-admitted request at the backlog head (FIFO).
                    # Only the current request's pending decrement has run;
                    # the rest still carry their submit-time count.
                    self.pending += 1
                    self._backlog = reqs[idx:] + self._backlog
                    return
                pages = self._alloc_pages(max(1, need))
            self.active[lane] = req
            self.block_tables = self.block_tables.at[lane, :len(pages)].set(
                jnp.asarray(pages))
            self.seq_lens = self.seq_lens.at[lane].set(0)
            # prefill: process the whole prompt at once (same compiled
            # callable as decode, traced at the prompt length)
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            bt = self.block_tables[lane:lane + 1]
            sl = jnp.zeros((1,), jnp.int32)
            logits, self.pool.k_pages, self.pool.v_pages = self._forward(
                self.params, toks, self.pool.k_pages, self.pool.v_pages, bt, sl)
            tok = int(jnp.argmax(logits[0]))
            self.seq_lens = self.seq_lens.at[lane].set(len(req.prompt))
            self.last_tok = self.last_tok.at[lane].set(tok)
            req.output.append(tok)

    def _grow_pages(self) -> None:
        """Allocate fresh pages for every lane whose next token crosses a page
        boundary — one batched allocation for all of them (pool pressure
        triggers preemption, paper Alg 1 Phase 1)."""
        sl = np.asarray(self.seq_lens)
        used = -(-sl // self.page_size)
        need = -(-(sl + 1) // self.page_size)
        lanes = [i for i, r in enumerate(self.active)
                 if r is not None and need[i] > used[i]]
        if not lanes:
            return
        # Fast path: enough FREE pages for every growing lane -> one batched
        # grab + one scatter. (Single scheduler thread: the check can't race.)
        if self.pool.free_pages() >= len(lanes):
            pages = self._alloc_pages(len(lanes))
            if pages is not None:
                rows = jnp.asarray(lanes, jnp.int32)
                cols = jnp.asarray(used[lanes], jnp.int32)
                self.block_tables = self.block_tables.at[rows, cols].set(
                    jnp.asarray(pages))
                return
        # Pool pressure: grow lane by lane (earliest lane first) so partial
        # availability is used instead of burned, preempting as needed; a
        # lane preempted out from under us is skipped.
        for lane in lanes:
            if self.active[lane] is None:
                continue
            page = self._alloc_pages(1)
            while page is None:
                if not self._preempt_youngest() or self.active[lane] is None:
                    break
                page = self._alloc_pages(1)
            if page is not None and self.active[lane] is not None:
                self.block_tables = self.block_tables.at[
                    lane, int(used[lane])].set(int(page[0]))

    # ---------------------------------------------------------------- step
    def step(self) -> List[Request]:
        """One engine iteration: tick window clock, reclaim, admit, decode."""
        self.step_count += 1
        self.pool.tick(self.step_count)
        self._admit()
        self._grow_pages()
        active_np = np.array([r is not None for r in self.active])
        if not active_np.any():
            return []
        # Decode all lanes in one call on the device-resident tables.
        logits, self.pool.k_pages, self.pool.v_pages = self._forward(
            self.params, self.last_tok[:, None], self.pool.k_pages,
            self.pool.v_pages, self.block_tables, self.seq_lens)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        mask = jnp.asarray(active_np)
        self.seq_lens = self.seq_lens + mask.astype(jnp.int32)
        self.last_tok = jnp.where(mask, nxt, self.last_tok)
        # single host sync per step for completion bookkeeping
        nxt_np = np.asarray(nxt)
        sl_np = np.asarray(self.seq_lens)
        done = []
        for lane in np.nonzero(active_np)[0]:
            req = self.active[lane]
            req.output.append(int(nxt_np[lane]))
            if (len(req.output) >= req.max_new_tokens
                    or sl_np[lane] + 1 >= self.max_seq):
                done.append(req)
                self.completed[req.uid] = req
                self._retire_request(int(lane))
        return done

    def run_until_idle(self, max_steps: int = 1000) -> Dict[int, Request]:
        for _ in range(max_steps):
            self.step()
            if all(r is None for r in self.active) and self.pending == 0:
                break
        return self.completed
