"""Continuous-batching serving engine.

CMP end to end:
  * admission — requests enter through a strict-FIFO :class:`CMPQueue`
    (global arrival order across submitter threads = fairness, the paper's
    strict-FIFO property doing real work);
  * KV memory — pages from :class:`PagedKVPool`; finished/preempted requests
    retire pages which recycle after the protection window W (no refcounts,
    no sweep barrier);
  * overload — if the pool runs dry the engine *preempts* the youngest
    request (retires its pages, requeues it). Recovery is automatic: the
    pages return to FREE after W steps. A stalled writer/reader can delay
    nothing (bounded reclamation).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cmp import CMPQueue
from repro.models import model as M
from repro.serving.kv_cache import PagedKVPool
from repro.serving.paged_model import paged_forward


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    output: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 page_size: int = 16, num_pages: int = 64, window: int = 4,
                 max_seq: int = 128):
        assert all(k in ("dense", "moe") for k in cfg.block_pattern), \
            "paged engine serves attention-based families"
        self.cfg, self.params = cfg, params
        self.max_batch, self.page_size, self.max_seq = max_batch, page_size, max_seq
        self.pps = max_seq // page_size
        self.pool = PagedKVPool(cfg, num_pages=num_pages, page_size=page_size,
                                window=window)
        # Reserve page 0 as the scratch target for inactive batch lanes
        # (their masked decode writes land here, never on live pages).
        scratch, ok = self.pool.alloc(1)
        assert bool(ok.all()) and int(scratch[0]) == 0
        self.queue = CMPQueue(window=max(64, window), reclaim_period=32)
        self.step_count = 0
        self._uid = itertools.count()
        # active request table (host side)
        self.active: List[Optional[Request]] = [None] * max_batch
        self.block_tables = np.zeros((max_batch, self.pps), np.int32)
        self.seq_lens = np.zeros((max_batch,), np.int32)
        self.last_tok = np.zeros((max_batch,), np.int32)
        self.completed: Dict[int, Request] = {}
        self.pending = 0  # submitted - admitted (emptiness check w/o dequeue)
        self._backlog: List[Request] = []  # head-of-line retries (keeps FIFO)
        fwd = lambda p, t, kp, vp, bt, sl: paged_forward(p, t, cfg, kp, vp, bt, sl)
        self._decode = jax.jit(fwd)
        self._prefill = jax.jit(fwd)

    # ---------------------------------------------------------------- client
    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        uid = next(self._uid)
        self.pending += 1
        self.queue.enqueue(Request(uid, list(prompt), max_new_tokens))
        return uid

    def _next_request(self) -> Optional[Request]:
        if self._backlog:
            return self._backlog.pop(0)
        req = self.queue.dequeue()
        return req

    # ---------------------------------------------------------------- pages
    def _alloc_pages(self, n: int) -> Optional[np.ndarray]:
        if n == 0:
            return np.zeros((0,), np.int32)
        ids, valid = self.pool.alloc(n)
        ids, valid = np.asarray(ids), np.asarray(valid)
        if not valid.all():
            self.pool.retire(jnp.asarray(ids))  # return partial grab
            return None
        return ids

    def _retire_request(self, lane: int) -> None:
        used = (int(self.seq_lens[lane]) + self.page_size - 1) // self.page_size
        if used > 0:
            self.pool.retire(jnp.asarray(self.block_tables[lane, :used]))
        self.block_tables[lane] = 0
        self.seq_lens[lane] = 0
        self.active[lane] = None

    def _preempt_youngest(self) -> bool:
        lanes = [i for i, r in enumerate(self.active) if r is not None]
        if not lanes:
            return False
        lane = max(lanes, key=lambda i: self.active[i].uid)
        req = self.active[lane]
        req.preemptions += 1
        req.output = []
        self._retire_request(lane)
        self.pending += 1
        self.queue.enqueue(req)  # back of the FIFO
        return True

    # ---------------------------------------------------------------- sched
    def _admit(self) -> None:
        for lane in range(self.max_batch):
            if self.active[lane] is not None:
                continue
            req = self._next_request()
            if req is None:
                return
            self.pending -= 1
            need = (len(req.prompt) + self.page_size - 1) // self.page_size
            pages = self._alloc_pages(max(1, need))
            while pages is None:
                if not self._preempt_youngest():
                    self._backlog.insert(0, req)  # retry at head (strict FIFO)
                    self.pending += 1
                    return
                pages = self._alloc_pages(max(1, need))
            self.active[lane] = req
            self.block_tables[lane, :len(pages)] = pages
            self.seq_lens[lane] = 0
            # prefill: process the whole prompt at once
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            bt = jnp.asarray(self.block_tables[lane:lane + 1])
            sl = jnp.zeros((1,), jnp.int32)
            logits, self.pool.k_pages, self.pool.v_pages = self._prefill(
                self.params, toks, self.pool.k_pages, self.pool.v_pages, bt, sl)
            self.seq_lens[lane] = len(req.prompt)
            self.last_tok[lane] = int(jnp.argmax(logits[0]))
            req.output.append(int(self.last_tok[lane]))

    def _grow_pages(self) -> None:
        """Allocate a fresh page for any lane whose next token crosses a page
        boundary (pool pressure triggers preemption, paper Alg 1 Phase 1)."""
        for lane, req in enumerate(self.active):
            if req is None:
                continue
            used = (int(self.seq_lens[lane]) + self.page_size - 1) // self.page_size
            need = (int(self.seq_lens[lane]) + 1 + self.page_size - 1) // self.page_size
            if need > used:
                pages = self._alloc_pages(need - used)
                while pages is None:
                    if not self._preempt_youngest() or self.active[lane] is None:
                        break
                    pages = self._alloc_pages(need - used)
                if pages is not None and self.active[lane] is not None:
                    self.block_tables[lane, used:need] = pages

    # ---------------------------------------------------------------- step
    def step(self) -> List[Request]:
        """One engine iteration: tick window clock, reclaim, admit, decode."""
        self.step_count += 1
        self.pool.tick(self.step_count)
        self._admit()
        self._grow_pages()
        lanes = [i for i, r in enumerate(self.active) if r is not None]
        if not lanes:
            return []
        toks = jnp.asarray(self.last_tok[:, None])
        logits, self.pool.k_pages, self.pool.v_pages = self._decode(
            self.params, toks, self.pool.k_pages, self.pool.v_pages,
            jnp.asarray(self.block_tables), jnp.asarray(self.seq_lens))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        done = []
        for lane in lanes:
            req = self.active[lane]
            self.seq_lens[lane] += 1
            self.last_tok[lane] = nxt[lane]
            req.output.append(int(nxt[lane]))
            if (len(req.output) >= req.max_new_tokens
                    or self.seq_lens[lane] + 1 >= self.max_seq):
                done.append(req)
                self.completed[req.uid] = req
                self._retire_request(lane)
        return done

    def run_until_idle(self, max_steps: int = 1000) -> Dict[int, Request]:
        for _ in range(max_steps):
            self.step()
            if all(r is None for r in self.active) and self.pending == 0:
                break
        return self.completed
