"""Device-resident admission for the serving engine (DESIGN.md §12).

:class:`DeviceAdmissionRing` wraps the fused CMP ring kernel
(:mod:`repro.kernels.cmp_ring`) for the engine's admission path: the
policy-drained batch is pushed into a bounded device ring and claim lanes are
filled in one fused device invocation — ring reclaim, batched enqueue, the
k-way earliest-cycle claim cascade and the frontier publish all happen
without a host sync in between (one device->host read per invocation returns
the claimed cycles).

Amortization works on both axes. Pushes batch naturally (enqueue-many is one
stage of the fused kernel). Claims amortize across engine steps via
*claim look-ahead*: one invocation claims up to ``claim_block >= k`` lanes
into a host-side FIFO buffer that subsequent steps serve without touching
the device — the claim cascade's fixed dispatch cost divides by
``claim_block``, the exact analogue of the host queue's batched
``dequeue_many``. Ring claims are earliest-cycle-first, so look-ahead
changes *when* claims commit, never their order.

The payload handle is the ring cycle number: the host keeps the authoritative
``cycle -> (QueueClass, Envelope)`` mirror, which is what makes checkpoints,
resizes and host failures exact — :meth:`flush` returns every ring-resident
entry (claim-buffered first, then unclaimed, both in cycle order) so callers
can requeue them at their original class seats before any fabric surgery.

Host-fallback rules (DESIGN.md §12): ``device_admission=True`` forces the
ring path (on CPU hosts the bit-identical jit'd oracle runs instead of the
Pallas kernel); ``"auto"`` enables it only when a TPU is attached; ``False``
keeps the pure host path.
"""

from __future__ import annotations

from collections import deque
from typing import Any, List, Tuple

import jax
import numpy as np

from repro.kernels import ops as kernel_ops


def resolve_device_admission(flag) -> bool:
    """Map a config flag (False | True | "auto") to an enable decision."""
    if flag == "auto":
        return jax.devices()[0].platform == "tpu"
    return bool(flag)


class DeviceAdmissionRing:
    """Bounded CMP ring on the accelerator feeding engine admission.

    Args:
      k: claim lanes the caller consumes per step (the engine's max_batch).
      claim_block: lanes claimed per fused invocation (the kernel's static
        cascade width); >= k enables claim look-ahead. Defaults to ``2*k``.
      capacity: ring slots. Sized so the steady state never rejects:
        non-FREE slots are bounded by unclaimed backlog + the claimed window,
        both well under capacity/2 for the engine's prefetch depth.
        Defaults to ``max(64, 2*claim_block)`` — the measured sweet spot
        (the oracle's cost grows with capacity, so oversizing the ring
        erodes the look-ahead amortization).
      window: protection window W for ring-slot recycling (paper Alg 4);
        defaults to capacity // 4.
      use_pallas: force the Pallas kernel (True) or the jit'd oracle (False);
        None picks by platform (Pallas on TPU).
    """

    def __init__(self, *, k: int, claim_block: int = 0, capacity: int = 0,
                 window: int = 0, use_pallas=None):
        self.k = int(k)
        self.claim_block = int(claim_block) if claim_block else 2 * self.k
        assert self.claim_block >= self.k
        self.capacity = int(capacity) if capacity else max(
            64, 2 * self.claim_block)
        self.window = int(window) if window else self.capacity // 4
        self.use_pallas = use_pallas
        self.state = np.zeros((self.capacity,), np.int32)
        self.cycle = np.zeros((self.capacity,), np.int32)
        self.meta = np.zeros((2,), np.int32)  # [enq_cycle, deque_cycle]
        self._enq = 0  # host mirror of meta[0]
        # Host mirror of the ring's unclaimed slots, FIFO by ring cycle —
        # claims always take the earliest cycles, so claimed entries leave
        # from the front and a dict keyed by cycle is never needed. Both
        # FIFOs are flat lists served by slicing (C-speed), the consumed
        # front dropped wholesale at each kernel call.
        self._mirror: List[Any] = []
        self._claimed: List[Any] = []  # look-ahead buffer, cycle order
        self._served = 0  # consumed front of _claimed
        self.stats = {"steps": 0, "kernel_calls": 0, "pushed": 0,
                      "claimed": 0, "rejected": 0}

    # flight-recorder attachment (repro.obs): kernel calls and flushes are
    # already amortized/rare, so both are recorded unconditionally when a
    # MetricsHub has attached a recorder here.
    _obs = None

    @property
    def pending(self) -> int:
        """Entries resident in the admission path: unclaimed ring slots plus
        the claim look-ahead buffer (pushed, not yet handed to a lane)."""
        return len(self._mirror) + len(self._claimed) - self._served

    @property
    def buffered(self) -> int:
        """Claimed-ahead entries servable without a device invocation."""
        return len(self._claimed) - self._served

    @property
    def room(self) -> int:
        """How many pushes are guaranteed accepted next invocation
        (conservative: half the ring stays headroom for the
        claimed-but-windowed slots)."""
        return max(0, self.capacity // 2 - len(self._mirror))

    def step(self, entries: List[Any], want: int
             ) -> Tuple[List[Any], List[Any]]:
        """One engine admission step: push ``entries`` and take up to
        ``want`` claimed lanes. Serves from the look-ahead buffer when it
        can; otherwise ONE fused device invocation pushes the entries and
        claims the next ``claim_block`` earliest cycles. Returns
        ``(claimed, rejected)`` — claimed entries in exact ring-cycle (FIFO)
        order, rejected entries (ring full; rare by construction) for the
        caller to requeue on the host."""
        self.stats["steps"] += 1
        rejected: List[Any] = []
        if entries or (self.buffered < want and self._mirror):
            self._claimed = self._claimed[self._served:]  # drop served front
            self._served = 0
            req = np.asarray([len(entries), self.claim_block], np.int32)
            self.state, self.cycle, self.meta, claimed = kernel_ops.ring_step(
                self.state, self.cycle, self.meta, req,
                k=self.claim_block, window=self.window,
                use_pallas=self.use_pallas)
            # single host sync per invocation: new meta + claimed cycles
            meta_np, claimed_np = jax.device_get((self.meta, claimed))
            accepted = int(meta_np[0]) - self._enq
            self._enq = int(meta_np[0])
            if accepted:
                self._mirror.extend(entries[:accepted])
            # the kernel claims the n earliest cycles = the mirror's first n
            n_claimed = int((claimed_np >= 0).sum())
            self._claimed.extend(self._mirror[:n_claimed])
            del self._mirror[:n_claimed]
            self.stats["kernel_calls"] += 1
            self.stats["pushed"] += accepted
            self.stats["rejected"] += len(entries) - accepted
            rejected = list(entries[accepted:])
            if self._obs is not None:
                self._obs.emit("claim_block", "_ring", self._enq,
                               arg={"pushed": accepted,
                                    "claimed": n_claimed})
        lo = self._served
        hi = min(lo + want, len(self._claimed))
        out = self._claimed[lo:hi]
        self._served = hi
        self.stats["claimed"] += len(out)
        return out, rejected

    def flush(self) -> List[Any]:
        """Return every ring-resident entry in exact cycle order — the claim
        look-ahead buffer first (its cycles precede every unclaimed slot's),
        then the unclaimed mirror — and reset the slot states (cycle
        counters stay monotone). The checkpoint / resize / fail-host
        boundary: callers requeue the returned entries at their original
        class seats, so no seat is lost or reordered."""
        out = self._claimed[self._served:]
        out.extend(self._mirror)
        self._claimed = []
        self._served = 0
        self._mirror = []
        self.state = np.zeros_like(self.state)
        self.meta = np.asarray([self._enq, self._enq], np.int32)
        if self._obs is not None:
            self._obs.emit("flush", "_ring", self._enq, arg=len(out))
        return out
