"""Typed signal views for the control loop (signals → decision → actions).

``read_signals(fabric)`` condenses everything the controller is allowed to
see into one frozen :class:`ControlSignals`: per-class depth/weight/SLO
headroom from the fabric's versioned ``stats_view()``, live policy weights
from the scheduler, and the pending-depth trend across the obs plane's
rolling gauge window (``Fabric.obs.window()``). The fabric argument is
duck-typed — this package never imports ``repro.fabric``, mirroring how
``repro.obs`` stays import-light.

Two depth signals with different jobs:

  * ``pending`` / ``backlog_per_replica`` come from the live queue-class
    counters — the *responsive* signal the deadband acts on.
  * ``admit_p99_ms`` / ``headroom_ms`` come from the reservoir latency
    window — the *conformance record*. The reservoir is cumulative, so a
    past breach lingers after the queue drains; the controller therefore
    treats a breach as load only while backlog is also elevated (see
    ``Controller._overloaded``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ClassSignal:
    """One queue class as the controller sees it."""

    name: str
    pending: int
    weight: float          # live policy weight (possibly already nudged)
    base_weight: float     # the weight declared in the ClassSpec
    priority: int
    slo_target_ms: Optional[float]
    admit_p99_ms: Optional[float]
    headroom_ms: Optional[float]  # target - p99; negative = target missed


@dataclasses.dataclass(frozen=True)
class ControlSignals:
    """Everything one decision tick reads, frozen at read time."""

    step: int
    num_replicas: int
    max_replicas: int
    num_hosts: int
    transport_kind: str    # "local" | "sim"
    policy: str            # "strict" | "wfq" | "fifo"
    pending_total: int
    backlog_per_replica: float
    pending_trend: Optional[float]  # Δ pending across the obs gauge window
    delivered_total: int   # cumulative deliveries (rate = Δ across ticks)
    capacity_per_step: float  # fleet drain budget per step at current size
    classes: Tuple[ClassSignal, ...]

    def cls(self, name: str) -> ClassSignal:
        for c in self.classes:
            if c.name == name:
                return c
        raise KeyError(name)


def read_signals(fabric) -> ControlSignals:
    """Snapshot the control inputs from a live fabric (duck-typed)."""
    view = fabric.stats_view()
    cfg = fabric.config
    base = {spec.name: spec for spec in cfg.classes}
    sched = fabric.replica_set.scheduler

    classes = []
    pending_total = 0
    delivered_total = 0
    for name, cs in sorted(view.classes.items()):
        qc = sched.by_name.get(name)
        slo = view.slo.get(name)
        spec = base.get(name)
        pending_total += cs.pending
        delivered_total += cs.delivered
        classes.append(ClassSignal(
            name=name,
            pending=cs.pending,
            weight=float(qc.weight) if qc is not None else 1.0,
            base_weight=float(spec.weight) if spec is not None else 1.0,
            priority=int(qc.priority) if qc is not None else 0,
            slo_target_ms=slo.target_ms if slo is not None else None,
            admit_p99_ms=slo.admit_p99_ms if slo is not None else None,
            headroom_ms=slo.headroom_ms if slo is not None else None,
        ))

    # Pending trend across the rolling gauge window: positive = the
    # backlog grew over the window even if the instantaneous depth looks
    # tolerable. None until the obs plane has sampled at least twice.
    trend: Optional[float] = None
    hub = getattr(fabric, "obs", None)
    if hub is not None:
        window = hub.window()
        if len(window) >= 2:
            first = window[0][1].get("pending")
            last = window[-1][1].get("pending")
            if first is not None and last is not None:
                trend = float(last) - float(first)

    # Fleet drain budget per step: scheduler-only fabrics drain drain_k
    # per replica per step; serving fabrics are lane-bound (max_batch is
    # the fabric-wide lane budget, re-split across replicas on resize).
    if getattr(fabric, "serving", False):
        capacity = float(cfg.max_batch)
    else:
        capacity = float(cfg.drain_k * view.num_replicas)

    return ControlSignals(
        step=view.step,
        num_replicas=view.num_replicas,
        max_replicas=cfg.max_replicas,
        num_hosts=fabric.transport.num_hosts,
        transport_kind=cfg.transport,
        policy=cfg.policy,
        pending_total=pending_total,
        backlog_per_replica=pending_total / max(1, view.num_replicas),
        pending_trend=trend,
        delivered_total=delivered_total,
        capacity_per_step=capacity,
        classes=tuple(classes),
    )
