"""Typed actuation commands emitted by the controller.

Each action is a frozen record naming one lever the fabric already has —
the controller never reaches into scheduler internals directly. Actions
carry a human-readable ``reason`` that flows into the decision log and
the obs plane's control events, so a trace answers *why* the fabric
resized, not just when.

``ControlHandle.apply`` (controller.py) is the single dispatch point; in
dry-run mode the action is recorded but not dispatched.
"""

from __future__ import annotations

import dataclasses
from typing import Union


@dataclasses.dataclass(frozen=True)
class Resize:
    """Grow or shrink the live replica fan-out to ``replicas``."""

    replicas: int
    reason: str


@dataclasses.dataclass(frozen=True)
class GrowHost:
    """Add one simulated host, then resize to ``replicas`` so the reseat
    spreads seats over the enlarged fleet (sim transport only)."""

    replicas: int
    reason: str


@dataclasses.dataclass(frozen=True)
class SetWeight:
    """Set a class's live WFQ weight (read by every replica's next drain)."""

    qclass: str
    weight: float
    reason: str


@dataclasses.dataclass(frozen=True)
class SetPriority:
    """Set a class's live strict-drain priority."""

    qclass: str
    priority: int
    reason: str


Action = Union[Resize, GrowHost, SetWeight, SetPriority]


def action_kind(action: Action) -> str:
    return type(action).__name__.lower()


def action_to_json(action: Action) -> dict:
    out = {"kind": action_kind(action)}
    out.update(dataclasses.asdict(action))
    return out
