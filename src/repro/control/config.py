"""Closed-loop control-plane configuration (DESIGN.md §14).

Plain host-only data, exactly like :class:`~repro.obs.recorder.ObsConfig`:
no fabric import, JSON round-trip through ``FabricConfig.to_json`` (the
controller's knobs ride checkpoint snapshots with everything else).

The controller is *pure policy* over mechanisms that already exist —
``Fabric.resize`` is a sub-ms batch of seat CASes, a sim host grow is one
transport counter bump plus a reseat, and WFQ weights are plain data read
live by every replica's drain policy. What this config tunes is therefore
only *when* to pull those levers:

  * **deadband** (``grow_backlog`` ≫ ``shrink_backlog``): the backlog band
    in which the controller does nothing. A steady signal inside the band
    can never cause an action; a steady signal outside it causes a
    monotone walk to the matching bound and then silence — the
    no-oscillation property tests/test_control.py asserts.
  * **hysteresis** (``hysteresis_up`` / ``hysteresis_down``): consecutive
    out-of-band decisions required before acting, so one noisy sample
    cannot trigger a resize.
  * **cooldowns** (``resize_cooldown`` / ``weight_cooldown``, in decision
    ticks): a floor on the spacing between actions of one kind — the
    flapping guard. Over a run of ``D`` decisions the resize count is
    bounded by ``D / resize_cooldown`` no matter what the signal does.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    """Knobs for the SLO-driven autoscaler (``FabricConfig(control=...)``).

    Attributes:
      enabled: master switch; disabled configs wire nothing.
      dry_run: record every decision (obs control events + the decision
        log) but actuate nothing — the shadow-mode rollout path.
      decide_every_n_steps: decision cadence in ``Fabric.step`` calls.
      grow_backlog: pending items per replica above which the fabric is
        overloaded (grow pressure).
      shrink_backlog: pending items per replica below which shrinking is
        safe. Must be well under ``grow_backlog`` (the deadband).
      shrink_fill_frac: second shrink guard — shrink only when the
        observed delivery rate would fill at most this fraction of the
        *smaller* fleet's per-step drain budget. End-of-step backlog is
        ~0 whenever capacity exceeds arrivals, so depth alone would
        shrink a fully-loaded fleet and immediately regrow it; the
        throughput guard is what makes the deadband hold between
        capacity levels.
      hysteresis_up / hysteresis_down: consecutive overloaded / idle
        decisions required before a grow / shrink fires.
      resize_cooldown / weight_cooldown: minimum decision ticks between
        two actions of the same kind (the flapping guard).
      min_replicas: shrink floor; the grow ceiling is the fabric's
        ``max_replicas`` (seats are provisioned at open).
      replicas_per_host: past this many replicas per transport host, a
        grow prefers adding a sim host (capacity) over packing another
        replica onto the existing hosts. ``None`` = never grow hosts.
      slo_margin_frac: a class *breaches* when its measured p99 headroom
        drops under ``slo_margin_frac * slo_ms`` — i.e. the controller
        acts slightly before the target is actually missed.
      nudge_weights: under the ``wfq`` policy, multiplicatively boost a
        breaching class's weight (and relax it back toward the declared
        weight once it drains) instead of / in addition to resizing.
      weight_step: multiplicative nudge per weight action.
      weight_max_boost: hard bound — a nudged weight stays within
        ``[declared, declared * weight_max_boost]``.
    """

    enabled: bool = True
    dry_run: bool = False
    decide_every_n_steps: int = 2
    grow_backlog: float = 8.0
    shrink_backlog: float = 2.0
    shrink_fill_frac: float = 0.8
    hysteresis_up: int = 1
    hysteresis_down: int = 3
    resize_cooldown: int = 2
    weight_cooldown: int = 4
    min_replicas: int = 1
    replicas_per_host: Optional[int] = None
    slo_margin_frac: float = 0.1
    nudge_weights: bool = True
    weight_step: float = 1.25
    weight_max_boost: float = 4.0

    def validate(self) -> None:
        def bad(msg: str) -> None:
            raise ValueError(f"ControlConfig: {msg}")

        if self.decide_every_n_steps < 1:
            bad(f"decide_every_n_steps must be >= 1 "
                f"(got {self.decide_every_n_steps})")
        if self.grow_backlog <= 0:
            bad(f"grow_backlog must be > 0 (got {self.grow_backlog})")
        if not (0 <= self.shrink_backlog < self.grow_backlog):
            bad(f"need 0 <= shrink_backlog < grow_backlog (got "
                f"shrink_backlog={self.shrink_backlog}, grow_backlog="
                f"{self.grow_backlog}): the gap is the deadband that "
                f"prevents grow/shrink oscillation on a steady signal")
        for field in ("hysteresis_up", "hysteresis_down",
                      "resize_cooldown", "weight_cooldown", "min_replicas"):
            if getattr(self, field) < 1:
                bad(f"{field} must be >= 1 (got {getattr(self, field)})")
        if self.replicas_per_host is not None and self.replicas_per_host < 1:
            bad(f"replicas_per_host must be >= 1 or None "
                f"(got {self.replicas_per_host})")
        if not (0.0 < self.shrink_fill_frac <= 1.0):
            bad(f"shrink_fill_frac must be in (0, 1] "
                f"(got {self.shrink_fill_frac})")
        if not (0.0 <= self.slo_margin_frac < 1.0):
            bad(f"slo_margin_frac must be in [0, 1) "
                f"(got {self.slo_margin_frac})")
        if self.weight_step <= 1.0:
            bad(f"weight_step must be > 1 (got {self.weight_step}); it is "
                f"a multiplicative nudge")
        if self.weight_max_boost < 1.0:
            bad(f"weight_max_boost must be >= 1 "
                f"(got {self.weight_max_boost})")
