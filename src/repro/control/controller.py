"""Deterministic SLO-driven controller + the ``Fabric.control`` handle.

Two halves, deliberately split:

  * :class:`Controller` is the *decision* function — pure policy over a
    :class:`~repro.control.signals.ControlSignals` snapshot, returning a
    list of typed actions. It holds only its own hysteresis counters and
    cooldown clocks, so unit tests drive it with synthetic signals and
    never need a fabric.
  * :class:`ControlHandle` is the *actuation surface* — the one public
    object (``fabric.control``) through which anything, human or
    controller, pulls the levers. It dispatches typed actions onto the
    fabric, records every decision (dry-run records without dispatching),
    and emits each as an obs ``control`` event so the flight recorder
    shows *why* the fabric resized.

Flapping guard (DESIGN.md §14): with deadband ``shrink_backlog <
grow_backlog``, hysteresis ``h_up``/``h_down`` and cooldown ``c`` ticks,
a steady signal produces a monotone action sequence (grows only, or
shrinks only) that stops at a bound; any signal at all is limited to
``decisions / c`` resizes.
"""

from __future__ import annotations

from typing import List, Optional

from repro.control.actions import (Action, GrowHost, Resize, SetPriority,
                                   SetWeight, action_to_json)
from repro.control.config import ControlConfig
from repro.control.signals import ClassSignal, ControlSignals, read_signals


class Controller:
    """signals → [actions], deterministically.

    Call :meth:`decide` once per decision tick. All state is small and
    explicit: two consecutive-breach counters (hysteresis) and one
    cooldown clock per action kind (flapping guard).
    """

    def __init__(self, config: ControlConfig):
        config.validate()
        self.config = config
        self.decisions = 0
        self._over = 0      # consecutive overloaded ticks
        self._under = 0     # consecutive idle ticks
        self._cooldown = {"resize": 0, "weights": 0}
        self._last_delivered: Optional[int] = None
        self._last_step: Optional[int] = None

    # ------------------------------------------------------------ signals
    def _breaching(self, sig: ControlSignals) -> List[ClassSignal]:
        """Classes whose measured p99 headroom is inside the SLO margin."""
        out = []
        for c in sig.classes:
            if c.slo_target_ms is None or c.headroom_ms is None:
                continue
            if c.headroom_ms < self.config.slo_margin_frac * c.slo_target_ms:
                out.append(c)
        return out

    def _overloaded(self, sig: ControlSignals,
                    breaching: List[ClassSignal]) -> bool:
        """Grow pressure. The latency reservoir is cumulative, so a breach
        with a drained queue is history, not load — a breach only counts
        while backlog sits above the shrink band (or is still climbing)."""
        cfg = self.config
        if sig.backlog_per_replica > cfg.grow_backlog:
            return True
        if breaching and sig.backlog_per_replica > cfg.shrink_backlog:
            return True
        if (breaching and sig.pending_trend is not None
                and sig.pending_trend > 0):
            return True
        return False

    def _delivery_rate(self, sig: ControlSignals) -> Optional[float]:
        """Deliveries per step since the previous decision tick (None on
        the first tick, or when the step clock has not advanced)."""
        last_d, last_s = self._last_delivered, self._last_step
        self._last_delivered = sig.delivered_total
        self._last_step = sig.step
        if last_d is None or last_s is None or sig.step <= last_s:
            return None
        return (sig.delivered_total - last_d) / (sig.step - last_s)

    def _fits_smaller(self, sig: ControlSignals,
                      rate: Optional[float]) -> bool:
        """Would the observed delivery rate fit comfortably in one fewer
        replica? End-of-step backlog is ~0 whenever capacity exceeds
        arrivals, so depth alone would shrink a fully-loaded fleet and
        regrow it next tick (capacity-level oscillation); this throughput
        guard is the other half of the deadband."""
        if rate is None:
            return False
        per_replica = sig.capacity_per_step / max(1, sig.num_replicas)
        smaller_cap = per_replica * (sig.num_replicas - 1)
        return rate <= self.config.shrink_fill_frac * smaller_cap

    # ------------------------------------------------------------- decide
    def decide(self, sig: ControlSignals) -> List[Action]:
        cfg = self.config
        self.decisions += 1
        for k in self._cooldown:
            if self._cooldown[k] > 0:
                self._cooldown[k] -= 1

        breaching = self._breaching(sig)
        rate = self._delivery_rate(sig)
        over = self._overloaded(sig, breaching)
        idle = (sig.backlog_per_replica < cfg.shrink_backlog and not over
                and self._fits_smaller(sig, rate))
        self._over = self._over + 1 if over else 0
        self._under = self._under + 1 if idle else 0

        actions: List[Action] = []
        actions.extend(self._decide_resize(sig, breaching))
        actions.extend(self._decide_weights(sig, breaching))
        return actions

    def _decide_resize(self, sig: ControlSignals,
                       breaching: List[ClassSignal]) -> List[Action]:
        cfg = self.config
        if self._cooldown["resize"] > 0:
            return []

        if self._over >= cfg.hysteresis_up and sig.num_replicas < sig.max_replicas:
            # Multiplicative grow: a burst that doubled the backlog wants
            # doubled drain bandwidth, and the ceiling bounds the walk.
            n_new = min(sig.max_replicas, max(sig.num_replicas + 1,
                                              sig.num_replicas * 2))
            why = (f"backlog/replica {sig.backlog_per_replica:.1f} > "
                   f"{cfg.grow_backlog:g}")
            if breaching:
                worst = min(breaching, key=lambda c: c.headroom_ms or 0.0)
                why += (f"; slo breach {worst.name} "
                        f"p99 {worst.admit_p99_ms:.2f}ms / "
                        f"target {worst.slo_target_ms:g}ms")
            self._cooldown["resize"] = cfg.resize_cooldown
            self._over = 0
            if (sig.transport_kind == "sim"
                    and cfg.replicas_per_host is not None
                    and n_new > cfg.replicas_per_host * sig.num_hosts):
                return [GrowHost(replicas=n_new, reason=(
                    f"{why}; {n_new} replicas would exceed "
                    f"{cfg.replicas_per_host}/host on {sig.num_hosts} "
                    f"host(s) — adding a host"))]
            return [Resize(replicas=n_new, reason=why)]

        if (self._under >= cfg.hysteresis_down
                and sig.num_replicas > cfg.min_replicas):
            # Additive shrink: cautious on the way down.
            self._cooldown["resize"] = cfg.resize_cooldown
            self._under = 0
            return [Resize(replicas=sig.num_replicas - 1, reason=(
                f"idle {cfg.hysteresis_down} ticks: backlog/replica "
                f"{sig.backlog_per_replica:.1f} < {cfg.shrink_backlog:g}"))]
        return []

    def _decide_weights(self, sig: ControlSignals,
                        breaching: List[ClassSignal]) -> List[Action]:
        """WFQ weight nudges: boost a breaching class toward its ``slo_ms``
        target, decay back toward the declared weight once comfortable.
        Always bounded to [base, base * weight_max_boost]."""
        cfg = self.config
        if (not cfg.nudge_weights or sig.policy != "wfq"
                or self._cooldown["weights"] > 0):
            return []
        breach_names = {c.name for c in breaching}
        drained = sig.backlog_per_replica < cfg.shrink_backlog

        actions: List[Action] = []
        for c in sig.classes:
            if c.slo_target_ms is None:
                continue
            lo, hi = c.base_weight, c.base_weight * cfg.weight_max_boost
            if c.name in breach_names and not drained and c.weight < hi:
                w = min(hi, c.weight * cfg.weight_step)
                actions.append(SetWeight(qclass=c.name, weight=w, reason=(
                    f"slo breach: p99 {c.admit_p99_ms:.2f}ms vs target "
                    f"{c.slo_target_ms:g}ms; weight {c.weight:g} -> {w:g} "
                    f"(cap {hi:g})")))
            elif c.name not in breach_names and c.weight > lo:
                w = max(lo, c.weight / cfg.weight_step)
                actions.append(SetWeight(qclass=c.name, weight=w, reason=(
                    f"headroom recovered; decaying weight {c.weight:g} -> "
                    f"{w:g} toward declared {lo:g}")))
        if actions:
            self._cooldown["weights"] = cfg.weight_cooldown
        return actions


class ControlHandle:
    """``fabric.control`` — the redesigned actuation surface.

    Always present on an open fabric. Typed reads via :meth:`signals`,
    typed writes via :meth:`resize` / :meth:`grow_host` /
    :meth:`set_weight` / :meth:`set_priority` (all funnel through
    :meth:`apply`), and — when ``FabricConfig.control`` is set — a
    :class:`Controller` that :meth:`step` runs on its configured cadence
    from inside ``Fabric.step``.
    """

    def __init__(self, fabric, config: Optional[ControlConfig] = None):
        self._fabric = fabric
        self.config = config
        self.controller = Controller(config) if (
            config is not None and config.enabled) else None
        self.decisions: List[dict] = []
        self.applied = {"resize": 0, "growhost": 0, "setweight": 0,
                        "setpriority": 0}

    # -------------------------------------------------------------- reads
    def signals(self) -> ControlSignals:
        return read_signals(self._fabric)

    # ------------------------------------------------------------- writes
    def resize(self, replicas: int, reason: str = "manual") -> bool:
        return self.apply(Resize(replicas=replicas, reason=reason))

    def grow_host(self, replicas: int, reason: str = "manual") -> bool:
        return self.apply(GrowHost(replicas=replicas, reason=reason))

    def set_weight(self, qclass: str, weight: float,
                   reason: str = "manual") -> bool:
        return self.apply(SetWeight(qclass=qclass, weight=weight,
                                    reason=reason))

    def set_priority(self, qclass: str, priority: int,
                     reason: str = "manual") -> bool:
        return self.apply(SetPriority(qclass=qclass, priority=priority,
                                      reason=reason))

    def apply(self, action: Action, *, actuate: Optional[bool] = None
              ) -> bool:
        """Dispatch one typed action onto the fabric.

        ``actuate=None`` follows the config (dry-run records only);
        explicit True/False overrides. Returns whether the action was
        actually dispatched. Every call — applied or not — lands in the
        decision log and the obs plane's control-event stream.
        """
        if actuate is None:
            actuate = not (self.config is not None and self.config.dry_run)
        if actuate:
            fab = self._fabric
            if isinstance(action, Resize):
                fab.resize(action.replicas)
            elif isinstance(action, GrowHost):
                fab.add_host()
                fab.resize(action.replicas)
            elif isinstance(action, SetWeight):
                qc = fab.replica_set.scheduler.by_name[action.qclass]
                qc.weight = float(action.weight)
            elif isinstance(action, SetPriority):
                qc = fab.replica_set.scheduler.by_name[action.qclass]
                qc.priority = int(action.priority)
            else:  # pragma: no cover - exhaustive over Action
                raise TypeError(f"unknown action {action!r}")
            self.applied[type(action).__name__.lower()] += 1

        decision = action_to_json(action)
        decision["step"] = self._fabric.step_count
        decision["applied"] = bool(actuate)
        self.decisions.append(decision)
        self._emit_obs(action, decision)
        return bool(actuate)

    def _emit_obs(self, action: Action, decision: dict) -> None:
        hub = getattr(self._fabric, "obs", None)
        if hub is None:
            return
        from repro.obs.recorder import CONTROL, PRODUCER_RID
        rec = hub.recorder(PRODUCER_RID)
        rec.emit(CONTROL, cls=getattr(action, "qclass", ""),
                 seq=len(self.decisions), arg=dict(decision))

    # --------------------------------------------------------------- loop
    def step(self) -> List[Action]:
        """One closed-loop tick, called by ``Fabric.step`` every
        ``decide_every_n_steps`` steps. No-op without a controller."""
        if self.controller is None:
            return []
        actions = self.controller.decide(self.signals())
        for action in actions:
            self.apply(action)
        return actions

    # ----------------------------------------------------------- snapshot
    def snapshot(self) -> dict:
        """The ``stats_view().control`` section."""
        out = {
            "enabled": self.controller is not None,
            "dry_run": bool(self.config.dry_run) if self.config else False,
            "decisions": len(self.decisions),
            "applied": dict(self.applied),
            "last": self.decisions[-8:],
        }
        if self.controller is not None:
            out["ticks"] = self.controller.decisions
            out["cooldowns"] = dict(self.controller._cooldown)
        return out
