"""Closed-loop control plane: SLO-driven autoscaling over fabric levers.

DESIGN.md §14. The package is pure policy — it imports nothing from
``repro.fabric`` (the fabric passes itself in, duck-typed) and actuates
only through public surfaces: ``Fabric.resize``, ``Fabric.add_host`` and
the scheduler's live policy weights.
"""

from repro.control.actions import (Action, GrowHost, Resize, SetPriority,
                                   SetWeight, action_to_json)
from repro.control.config import ControlConfig
from repro.control.controller import Controller, ControlHandle
from repro.control.signals import ClassSignal, ControlSignals, read_signals

__all__ = [
    "Action",
    "ClassSignal",
    "ControlConfig",
    "ControlHandle",
    "ControlSignals",
    "Controller",
    "GrowHost",
    "Resize",
    "SetPriority",
    "SetWeight",
    "action_to_json",
    "read_signals",
]
