"""Coordination-free work stealing between CMP shards (DESIGN.md §8).

The stealing invariant: **a steal is a claim.** A stealer is just another
consumer running the paper's dequeue — the state CAS hands it the item
exactly once, and the protection window already guarantees the node it
touched stays type-stable for W cycles. No new synchronization is introduced
anywhere in this module; every primitive below is composed from
``dequeue_many`` (the claim) and ``enqueue_many`` (the republish), so window
safety is *inherited*, not re-proven.

Two modes:

  * **Migration** (:func:`steal_into`, :func:`rebalance`) — move a batch of
    items from a deep shard to a shallow one. Under a :class:`QueueClass`
    frontier drain this is order-invisible: delivery is by cycle stamp, not
    by placement.
  * **Consuming steal** (:class:`ShardConsumer`) — a worker bound to a home
    shard consumes it first and, when idle, claims directly from the deepest
    sibling. This bounds shard idle time without any shared scan state:
    victim selection reads the domain counters (zero added atomics).
"""

from __future__ import annotations

from typing import List, Optional

from repro.core.cmp import CMPQueue
from repro.sched.classes import ShardSet, queue_depth  # noqa: F401 (re-export)


def steal_into(victim: CMPQueue, thief: CMPQueue, max_items: int = 8) -> int:
    """Migrate up to ``max_items`` from victim to thief: one batched claim,
    one batched republish. Exactly-once is the claim CAS's property; if the
    stealer dies between the two calls the items are lost with it — the same
    contract as any consumer that claimed and crashed, which is why callers
    that need stronger guarantees steal *consumingly* (ShardConsumer)."""
    batch = victim.dequeue_many(max_items)
    if batch:
        thief.enqueue_many(batch)
    return len(batch)


def rebalance(shards: ShardSet, max_items: int = 8) -> int:
    """One rebalance step: migrate from the deepest to the shallowest shard
    when the imbalance exceeds the batch size. Safe to run from any number
    of concurrent rebalancer threads (it is only claims + republishes)."""
    if len(shards) < 2:
        return 0
    depths = shards.depths()
    hi = max(range(len(depths)), key=depths.__getitem__)
    lo = min(range(len(depths)), key=depths.__getitem__)
    if hi == lo or depths[hi] - depths[lo] <= max_items:
        return 0
    return steal_into(shards.queues[hi], shards.queues[lo],
                      min(max_items, (depths[hi] - depths[lo]) // 2))


def claim_seat(seat, thief) -> bool:
    """Replica-level steal (DESIGN.md §9/§11): claim a whole shard
    cycle-run by CASing the :class:`~repro.sched.replica.ShardSeat` owner
    cell to the thief's host-addressed
    :class:`~repro.sched.transport.HostAddr`. One CAS, no victim
    participation — ownership of the run (its backlog *and* all its future
    cycles, since placement is ``seq % S``) moves atomically; when the
    victim lives on another host this is the body of the one claim RPC the
    transport carries. The victim discovers the loss lazily and republishes
    anything it had staged from that shard; the seat cursor, not queue
    position, keeps the thief's delivery in exact run order. Returns False
    when the CAS lost a race (or the thief already owns the seat) — retry
    next step."""
    owner = seat.owner.load()
    if owner == thief:
        return False
    return seat.owner.cas(owner, thief)


class ShardConsumer:
    """A consumer with a home shard that steals when the home runs dry.

    ``take(k)`` drains the home shard first (locality); on emptiness it
    picks the deepest sibling and claims from it directly. ``idle_polls``
    counts takes that found nothing anywhere — the quantity stealing is
    meant to bound."""

    def __init__(self, shards: ShardSet, home: int, *,
                 steal_batch: Optional[int] = None):
        self.shards = shards
        self.home = int(home)
        self.steal_batch = steal_batch
        self.steals = 0        # successful steal events
        self.stolen_items = 0  # items claimed from non-home shards
        self.idle_polls = 0

    def take(self, k: int = 1) -> List:
        got = self.shards.queues[self.home].dequeue_many(k)
        if got:
            return got
        order = sorted((i for i in range(len(self.shards)) if i != self.home),
                       key=lambda i: -self.shards.depth(i))
        for victim in order:
            got = self.shards.queues[victim].dequeue_many(
                min(k, self.steal_batch or k))
            if got:
                self.steals += 1
                self.stolen_items += len(got)
                return got
        self.idle_polls += 1
        return []
