"""Pluggable drain policies: how one admission batch is composed from many
priority classes (DESIGN.md §8).

A policy's ``drain(classes, k)`` returns up to ``k`` ``(qclass, envelope)``
pairs — one batched admission per engine step, built from per-class
``QueueClass.drain`` calls (which are themselves batched ``dequeue_many``
claims underneath). Policies only decide the *cross-class* interleaving;
within a class the frontier drain already fixed the order.

  * :class:`StrictPriority` — higher ``priority`` empties first. Interactive
    traffic starves background under load, by design.
  * :class:`WeightedFair` — deficit round robin over ``weight``: each round a
    class earns quantum × weight credits and spends one per item drained;
    an emptied class forfeits its credit (no hoarding). Long-run throughput
    shares converge to the weights.
  * :class:`ClassFifo` — FIFO *across* classes, recovered by merging class
    heads on the fabric-global arrival stamp: the single-queue behavior,
    re-expressed over the sharded fabric (exact when quiesced, races resolve
    like the base queue's).
"""

from __future__ import annotations

import heapq

from typing import Dict, List, Optional, Sequence, Tuple

from repro.sched.classes import Envelope, QueueClass

Drained = List[Tuple[QueueClass, Envelope]]


class DrainPolicy:
    # True iff this policy admits strictly by class priority, which is what
    # makes priority-driven *lane* preemption in the engine meaningful: the
    # freed lane is guaranteed to go to the higher class. Weight- or
    # stamp-driven policies must leave it False or an eviction can be
    # immediately undone by the policy re-admitting the victim.
    honors_priority = False

    def drain(self, classes: Sequence[QueueClass], k: int) -> Drained:
        raise NotImplementedError

    def held(self) -> int:
        """Envelopes drained from their class but not yet handed out (some
        policies buffer class heads between calls). Counted as pending by
        the scheduler's emptiness check."""
        return 0

    def held_items(self) -> Drained:
        """The buffered ``(qclass, envelope)`` pairs behind :meth:`held` —
        checkpointing records them as requeued seats (their class cursor
        already advanced past them, exactly like a preempted lane)."""
        return []

    def take_held(self) -> Drained:
        """Remove and return the buffered heads — the destructive variant
        used when a replica's local state is handed off (resize, host
        failure): the heads ride to the new seat owners and must not stay
        counted here."""
        return []


class StrictPriority(DrainPolicy):
    honors_priority = True

    def __init__(self):
        # Priority order cached per class-set: the set is stable between
        # calls (same Scheduler, or same active subset), so the common
        # case pays an O(C) identity check instead of an O(C log C) sort.
        self._order_key: Optional[Tuple[int, ...]] = None
        self._order: List[QueueClass] = []

    def _ordered(self, classes: Sequence[QueueClass]) -> List[QueueClass]:
        key = tuple(map(id, classes))
        if key != self._order_key:
            self._order = sorted(classes, key=lambda c: -c.priority)
            self._order_key = key
        return self._order

    def drain(self, classes: Sequence[QueueClass], k: int) -> Drained:
        out: Drained = []
        for qc in self._ordered(classes):
            if len(out) >= k:
                break
            out.extend((qc, env) for env in qc.drain(k - len(out)))
        return out


class WeightedFair(DrainPolicy):
    """Deficit round robin over ``weight``. Each ``drain`` call is one DRR
    round: every backlogged class earns its weight-share of the ``k`` slots
    (fractions carry over as deficit, so a small-weight class still gets a
    slot every few rounds), then classes spend their credit round-robin until
    the batch is full or everyone is dry. An emptied class forfeits its
    credit; accumulated credit is burst-capped so a class returning from idle
    cannot monopolize a batch."""

    def __init__(self, quantum: float = 1.0):
        self.quantum = float(quantum)
        self._deficit: Dict[str, float] = {}

    def drain(self, classes: Sequence[QueueClass], k: int) -> Drained:
        out: Drained = []
        backlogged = [qc for qc in classes if qc.pending() > 0]
        for qc in classes:
            if qc.pending() == 0:
                self._deficit[qc.name] = 0.0  # forfeit: no credit hoarding
        if not backlogged:
            return out
        # One round's credit: k slots split in weight proportion (quantum
        # scales the round size), accumulated onto carried-over deficit.
        total_w = sum(qc.weight for qc in backlogged)
        for qc in backlogged:
            share = self.quantum * k * qc.weight / total_w
            d = self._deficit.get(qc.name, 0.0) + share
            self._deficit[qc.name] = min(d, 2.0 * share + 1.0)  # burst cap
        # Spend the credit round-robin; ~k+len iterations always suffice.
        for _ in range(2 * k + len(backlogged) + 2):
            if len(out) >= k:
                break
            progressed = False
            for qc in backlogged:
                if len(out) >= k:
                    break
                take = min(k - len(out), int(self._deficit[qc.name]))
                got = qc.drain(take) if take > 0 else []
                self._deficit[qc.name] -= len(got)
                if take > 0 and len(got) < take:
                    self._deficit[qc.name] = 0.0  # ran dry mid-quantum
                if got:
                    progressed = True
                    out.extend((qc, env) for env in got)
            if not progressed:
                break
        if not out:
            # All deficits still fractional (many classes, small k): grant
            # the largest creditor one item so every call makes progress.
            qc = max(backlogged, key=lambda c: self._deficit[c.name])
            got = qc.drain(1)
            self._deficit[qc.name] -= len(got)
            out.extend((qc, env) for env in got)
        return out


class ClassFifo(DrainPolicy):
    """Cycle-timestamp merge: repeatedly deliver the class head with the
    smallest fabric arrival stamp. Heads drained but not yet merged persist
    in the policy between calls (they count as pending deliveries)."""

    def __init__(self):
        self._heads: Dict[str, Tuple[QueueClass, Envelope]] = {}
        # Min-heap of (stamp, name) mirroring _heads with lazy deletion:
        # take_held()/supersession leave stale entries behind, and drain
        # skips any popped entry whose stamp no longer matches the live
        # head. One drain is O(C + k log C) — the per-class top-up runs
        # once per call, and each emitted item refills only its own
        # class — instead of the old O(C·k) min-scan per item.
        self._heap: List[Tuple[int, str]] = []

    def held(self) -> int:
        return len(self._heads)

    def held_items(self) -> Drained:
        return list(self._heads.values())

    def take_held(self) -> Drained:
        out = list(self._heads.values())
        self._heads.clear()
        self._heap.clear()
        return out

    def _fill(self, qc: QueueClass) -> None:
        got = qc.drain(1)
        if got:
            self._heads[qc.name] = (qc, got[0])
            heapq.heappush(self._heap, (got[0].stamp, qc.name))

    def drain(self, classes: Sequence[QueueClass], k: int) -> Drained:
        out: Drained = []
        for qc in classes:
            if qc.name not in self._heads:
                self._fill(qc)
        while len(out) < k and self._heap:
            stamp, name = heapq.heappop(self._heap)
            entry = self._heads.get(name)
            if entry is None or entry[1].stamp != stamp:
                continue  # stale heap entry (head taken or superseded)
            del self._heads[name]
            out.append(entry)
            self._fill(entry[0])
        return out


class HierarchicalWFQ(DrainPolicy):
    """Two-level drain for the tenant fabric (DESIGN.md §16): deficit
    round robin *across class groups* (equal shares — groups are hash
    buckets of tenants, so fairness between buckets is fairness between
    tenant populations), strict priority *within* a group (interactive
    beats batch beats background for the tenants sharing the bucket).

    Groups are recovered from the class-name prefix before ``:`` (the
    ``g017:interactive`` convention from sched/tenants.py); a class
    without a prefix forms its own group. The group partition and each
    group's priority order are cached per class-set, so with an active-
    set filter a drain touches only backlogged groups.

    ``honors_priority`` stays False: admission is weight-driven across
    groups, so a priority-evicted lane could be immediately re-admitted.
    """

    def __init__(self, quantum: float = 1.0):
        self.quantum = float(quantum)
        self._deficit: Dict[str, float] = {}
        self._cache_key: Optional[Tuple[int, ...]] = None
        self._groups: List[Tuple[str, List[QueueClass]]] = []

    def _grouped(self, classes: Sequence[QueueClass]):
        key = tuple(map(id, classes))
        if key != self._cache_key:
            by_key: Dict[str, List[QueueClass]] = {}
            for qc in classes:
                by_key.setdefault(qc.name.partition(":")[0], []).append(qc)
            self._groups = [
                (gkey, sorted(members, key=lambda c: -c.priority))
                for gkey, members in by_key.items()]
            self._cache_key = key
        return self._groups

    @staticmethod
    def _drain_group(members: List[QueueClass], k: int) -> Drained:
        out: Drained = []
        for qc in members:  # already priority-sorted
            if len(out) >= k:
                break
            out.extend((qc, env) for env in qc.drain(k - len(out)))
        return out

    def drain(self, classes: Sequence[QueueClass], k: int) -> Drained:
        # No pending() pre-sweep: with an active-set filter the offered
        # groups almost all hold work, so probing every member first costs
        # O(active x tiers) atomic loads per step for nothing. A group
        # that turns out dry forfeits its deficit the first time its
        # quantum comes up empty (the ran-dry reset below) — same
        # no-hoarding guarantee, paid only by groups that are actually
        # empty.
        out: Drained = []
        backlogged = self._grouped(classes)
        if not backlogged:
            return out
        share = self.quantum * k / len(backlogged)
        for gkey, _ in backlogged:
            d = self._deficit.get(gkey, 0.0) + share
            self._deficit[gkey] = min(d, 2.0 * share + 1.0)  # burst cap
        dry = {}  # groups observed empty this call: no point re-probing
        dry_passes = 0
        for _ in range(2 * k + len(backlogged) + 2):
            if len(out) >= k:
                break
            progressed = False
            for gkey, members in backlogged:
                if len(out) >= k:
                    break
                if gkey in dry:
                    continue
                take = min(k - len(out), int(self._deficit[gkey]))
                got = self._drain_group(members, take) if take > 0 else []
                self._deficit[gkey] -= len(got)
                if take > 0 and len(got) < take:
                    self._deficit[gkey] = 0.0  # ran dry mid-quantum
                    dry[gkey] = True
                if got:
                    progressed = True
                    out.extend(got)
            if progressed:
                dry_passes = 0
                continue
            # Work-conserving re-credit: every deficit may be fractional
            # (many groups, small k) while some group still holds items —
            # classic DRR runs more rounds until the budget is spent, so
            # grant another share and retry; two consecutive no-progress
            # passes mean everything offered is actually dry.
            dry_passes += 1
            if dry_passes >= 2:
                break
            for gkey, _ in backlogged:
                if gkey not in dry:
                    self._deficit[gkey] += share
        if not out:
            # All deficits still fractional (many groups, small k): grant
            # the largest creditor one item so every call makes progress.
            gkey, members = max(backlogged,
                                key=lambda g: self._deficit[g[0]])
            got = self._drain_group(members, 1)
            self._deficit[gkey] -= len(got)
            out.extend(got)
        return out


_POLICIES = {
    "strict": StrictPriority,
    "wfq": WeightedFair,
    "fifo": ClassFifo,
    "hier": HierarchicalWFQ,
}


def make_policy(policy) -> DrainPolicy:
    """Accept a policy instance or a name: strict | wfq | fifo | hier."""
    if isinstance(policy, DrainPolicy):
        return policy
    try:
        return _POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown policy {policy!r}; "
                         f"choose from {sorted(_POLICIES)}") from None
