"""Pluggable drain policies: how one admission batch is composed from many
priority classes (DESIGN.md §8).

A policy's ``drain(classes, k)`` returns up to ``k`` ``(qclass, envelope)``
pairs — one batched admission per engine step, built from per-class
``QueueClass.drain`` calls (which are themselves batched ``dequeue_many``
claims underneath). Policies only decide the *cross-class* interleaving;
within a class the frontier drain already fixed the order.

  * :class:`StrictPriority` — higher ``priority`` empties first. Interactive
    traffic starves background under load, by design.
  * :class:`WeightedFair` — deficit round robin over ``weight``: each round a
    class earns quantum × weight credits and spends one per item drained;
    an emptied class forfeits its credit (no hoarding). Long-run throughput
    shares converge to the weights.
  * :class:`ClassFifo` — FIFO *across* classes, recovered by merging class
    heads on the fabric-global arrival stamp: the single-queue behavior,
    re-expressed over the sharded fabric (exact when quiesced, races resolve
    like the base queue's).
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.sched.classes import Envelope, QueueClass

Drained = List[Tuple[QueueClass, Envelope]]


class DrainPolicy:
    # True iff this policy admits strictly by class priority, which is what
    # makes priority-driven *lane* preemption in the engine meaningful: the
    # freed lane is guaranteed to go to the higher class. Weight- or
    # stamp-driven policies must leave it False or an eviction can be
    # immediately undone by the policy re-admitting the victim.
    honors_priority = False

    def drain(self, classes: Sequence[QueueClass], k: int) -> Drained:
        raise NotImplementedError

    def held(self) -> int:
        """Envelopes drained from their class but not yet handed out (some
        policies buffer class heads between calls). Counted as pending by
        the scheduler's emptiness check."""
        return 0

    def held_items(self) -> Drained:
        """The buffered ``(qclass, envelope)`` pairs behind :meth:`held` —
        checkpointing records them as requeued seats (their class cursor
        already advanced past them, exactly like a preempted lane)."""
        return []

    def take_held(self) -> Drained:
        """Remove and return the buffered heads — the destructive variant
        used when a replica's local state is handed off (resize, host
        failure): the heads ride to the new seat owners and must not stay
        counted here."""
        return []


class StrictPriority(DrainPolicy):
    honors_priority = True

    def drain(self, classes: Sequence[QueueClass], k: int) -> Drained:
        out: Drained = []
        for qc in sorted(classes, key=lambda c: -c.priority):
            if len(out) >= k:
                break
            out.extend((qc, env) for env in qc.drain(k - len(out)))
        return out


class WeightedFair(DrainPolicy):
    """Deficit round robin over ``weight``. Each ``drain`` call is one DRR
    round: every backlogged class earns its weight-share of the ``k`` slots
    (fractions carry over as deficit, so a small-weight class still gets a
    slot every few rounds), then classes spend their credit round-robin until
    the batch is full or everyone is dry. An emptied class forfeits its
    credit; accumulated credit is burst-capped so a class returning from idle
    cannot monopolize a batch."""

    def __init__(self, quantum: float = 1.0):
        self.quantum = float(quantum)
        self._deficit: Dict[str, float] = {}

    def drain(self, classes: Sequence[QueueClass], k: int) -> Drained:
        out: Drained = []
        backlogged = [qc for qc in classes if qc.pending() > 0]
        for qc in classes:
            if qc.pending() == 0:
                self._deficit[qc.name] = 0.0  # forfeit: no credit hoarding
        if not backlogged:
            return out
        # One round's credit: k slots split in weight proportion (quantum
        # scales the round size), accumulated onto carried-over deficit.
        total_w = sum(qc.weight for qc in backlogged)
        for qc in backlogged:
            share = self.quantum * k * qc.weight / total_w
            d = self._deficit.get(qc.name, 0.0) + share
            self._deficit[qc.name] = min(d, 2.0 * share + 1.0)  # burst cap
        # Spend the credit round-robin; ~k+len iterations always suffice.
        for _ in range(2 * k + len(backlogged) + 2):
            if len(out) >= k:
                break
            progressed = False
            for qc in backlogged:
                if len(out) >= k:
                    break
                take = min(k - len(out), int(self._deficit[qc.name]))
                got = qc.drain(take) if take > 0 else []
                self._deficit[qc.name] -= len(got)
                if take > 0 and len(got) < take:
                    self._deficit[qc.name] = 0.0  # ran dry mid-quantum
                if got:
                    progressed = True
                    out.extend((qc, env) for env in got)
            if not progressed:
                break
        if not out:
            # All deficits still fractional (many classes, small k): grant
            # the largest creditor one item so every call makes progress.
            qc = max(backlogged, key=lambda c: self._deficit[c.name])
            got = qc.drain(1)
            self._deficit[qc.name] -= len(got)
            out.extend((qc, env) for env in got)
        return out


class ClassFifo(DrainPolicy):
    """Cycle-timestamp merge: repeatedly deliver the class head with the
    smallest fabric arrival stamp. Heads drained but not yet merged persist
    in the policy between calls (they count as pending deliveries)."""

    def __init__(self):
        self._heads: Dict[str, Tuple[QueueClass, Envelope]] = {}

    def held(self) -> int:
        return len(self._heads)

    def held_items(self) -> Drained:
        return list(self._heads.values())

    def take_held(self) -> Drained:
        out = list(self._heads.values())
        self._heads.clear()
        return out

    def drain(self, classes: Sequence[QueueClass], k: int) -> Drained:
        out: Drained = []
        while len(out) < k:
            for qc in classes:
                if qc.name not in self._heads:
                    got = qc.drain(1)
                    if got:
                        self._heads[qc.name] = (qc, got[0])
            if not self._heads:
                break
            name = min(self._heads, key=lambda n: self._heads[n][1].stamp)
            out.append(self._heads.pop(name))
        return out


_POLICIES = {
    "strict": StrictPriority,
    "wfq": WeightedFair,
    "fifo": ClassFifo,
}


def make_policy(policy) -> DrainPolicy:
    """Accept a policy instance or one of the names: strict | wfq | fifo."""
    if isinstance(policy, DrainPolicy):
        return policy
    try:
        return _POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown policy {policy!r}; "
                         f"choose from {sorted(_POLICIES)}") from None
