"""Sharded scheduler replicas: N drain loops over one class fabric
(DESIGN.md §9), host-addressed and transport-agnostic (DESIGN.md §11).

PR 2 made the fabric many-producer but left it one-consumer: a single
policy drain loop feeds the engine, and that loop is the scalability
ceiling the paper says a CMP system should not have. This module splits the
*consumer* side into N :class:`SchedulerReplica`\\ s, each owning a subset of
every class's shards and running its own policy drain — no replica ever
waits on another. Two CMP ideas carry the whole design:

  * **Ownership is a claim.** Each (class, shard) pair has a
    :class:`ShardSeat` whose ``owner`` field is a single CAS-published cell
    holding a host-addressed :class:`~repro.sched.transport.HostAddr`
    ``(host, rid)``. A starved replica *steals the seat* — one claim RPC
    through the :class:`~repro.sched.transport.Transport`, no handshake, no
    victim participation — and with it the shard's entire cycle-run, past
    and future (placement is ``seq % S``, so a seat carries the arithmetic
    sequence ``s, s+S, s+2S, …`` of class cycles forever). Stealing items
    one batch at a time would poke holes in a peer's frontier arithmetic;
    stealing the seat moves the *run*, which is exactly the granularity at
    which class-cycle order is preserved — and exactly one message when the
    peer lives on another host.
  * **The seat cursor makes delivery exact.** ``ShardSeat.next_seat`` is
    the next undelivered class cycle of that shard. Only the replica
    holding the claimed envelope for that cycle advances the cursor
    (the queue's claim CAS already made holding exclusive, so the advance
    needs no CAS of its own). A replica's drain is a frontier merge over
    its owned seats: always deliver the lowest pending cycle it owns —
    which is why transport-level reordering of a fetched batch is
    invisible to delivery order.

Ordering contract: *within every shard's cycle-run, delivery is exactly the
class-cycle order; across the fabric, each class's seats are delivered
exactly once, and merging the replica streams by seat recovers the dense
class-cycle order 0,1,2,….* With static ownership each replica's stream is
itself seat-monotone; a steal splices a run between replicas but never
reorders within one, never loses a seat, never delivers one twice — on one
host or across simulated hosts under message drop/delay/reorder.

Crash contract: a replica that dies holding claimed-but-undelivered
envelopes takes them with it — the same contract as any crashed consumer in
the paper. Recovery is :meth:`ReplicaSet.state` / :meth:`ReplicaSet.from_state`
(an exact-seat frontier snapshot from which every tenant resumes at its
exact FIFO seat) — and, live, :meth:`ReplicaSet.fail_host`: the lost host's
final frontier state is replayed through the wire codec into the survivors
(the DESIGN.md §9 observation that the checkpoint format *is* the wire
format, as one running operation).
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.atomics import AtomicCell, cpu_pause
from repro.sched.classes import (_GAP_PATIENCE, Envelope, QueueClass,
                                 Scheduler, decode_envelope,
                                 encode_envelopes)
from repro.sched.policy import make_policy
from repro.sched.stats import ClassStats, aggregate_class_snapshots
from repro.sched.transport import (HostAddr, LocalTransport, Transport,
                                   decode_owner, wire_decode, wire_encode)

# Active-set retirement sweep cadence (rebalance calls between sweeps).
# The sweep is O(active x replicas) pending() probes; a stale entry only
# costs one empty policy visit per drain, so amortizing it is pure win.
_RETIRE_EVERY = 8


class ShardSeat:
    """Ownership + delivery cursor for one (class, shard) pair.

    ``owner`` is the :class:`HostAddr` of the replica currently entitled to
    drain the shard — CAS-published, so a steal is literally one claim (one
    RPC when the thief is on another host). ``next_seat`` is the next
    undelivered class cycle of the shard's run (always ≡ shard index mod
    S); it is advanced with a plain store by whichever replica holds the
    claimed envelope for that cycle — the queue's claim CAS already made
    that replica unique, so the cursor needs no second CAS.
    """

    __slots__ = ("owner", "next_seat")

    def __init__(self, owner: HostAddr, shard: int):
        self.owner = AtomicCell(owner)
        self.next_seat = AtomicCell(int(shard))


class ClassView:
    """One replica's drain view of one :class:`QueueClass`.

    Quacks like a ``QueueClass`` for everything a drain policy or the
    engine touches (``name``/``priority``/``weight``/``drain``/``pending``/
    ``requeue``/``snapshot``), but delivers only the cycle-runs of the
    seats this replica currently owns. All shard I/O goes through the
    transport (claim = ``fetch``, republish = ``publish``); shard *depth*
    sampling stays a direct domain-counter read — telemetry, zero messages,
    same as PR 2.
    """

    def __init__(self, qclass: QueueClass, seats: List[ShardSeat],
                 addr: HostAddr, transport: Transport):
        self.qclass = qclass
        self.seats = seats
        self.addr = addr
        self.transport = transport
        self._stride = len(qclass.shards)
        self._stage: Dict[int, Envelope] = {}  # claimed, awaiting their seat
        self._requeue: List[Envelope] = []     # preempted (seat already spent)
        # cross-thread relocation inbox (resize / host recovery): carried
        # seat-spent envelopes land here under a lock and are absorbed into
        # the requeue heap by the single drainer thread — heap operations
        # stay single-threaded, handoff is race-free
        self._handoff: List[Envelope] = []
        self._handoff_lock = threading.Lock()
        self.stats = ClassStats(qclass.name)

    # flight-recorder attachment (repro.obs): the owning replica's ring;
    # None until a MetricsHub attaches (one `is None` check un-observed)
    _obs = None

    # ---- QueueClass facade ------------------------------------------------
    @property
    def name(self) -> str:
        return self.qclass.name

    @property
    def priority(self) -> int:
        return self.qclass.priority

    @property
    def weight(self) -> float:
        return self.qclass.weight

    @property
    def rid(self) -> int:
        return self.addr.rid

    def owned(self) -> List[int]:
        return [s for s, seat in enumerate(self.seats)
                if seat.owner.load() == self.addr]

    def _remaining(self, shard: int) -> int:
        """Undelivered seats left in one owned shard's cycle-run."""
        nxt = self.seats[shard].next_seat.load()
        seq = self.qclass._seq.load()
        if nxt >= seq:
            return 0
        return (seq - nxt + self._stride - 1) // self._stride

    def pending(self) -> int:
        return (len(self._requeue) + len(self._handoff)
                + sum(self._remaining(s) for s in self.owned()))

    def handoff(self, env: Envelope) -> None:
        """Relocate a seat-spent envelope to this view from another thread
        (resize / host recovery). Not a preemption: the requeued counter is
        not bumped — the seat's delivery telemetry rode into the retired
        roll-up with its old owner."""
        with self._handoff_lock:
            self._handoff.append(env)
        act = self.qclass._active
        if act is not None:
            act.mark(self.name)  # after the append: never strands the item

    def _absorb_handoff(self) -> None:
        if self._handoff:  # racy peek is fine: a miss is absorbed next round
            with self._handoff_lock:
                arrived, self._handoff = self._handoff, []
            for env in arrived:
                heapq.heappush(self._requeue, env)

    def requeue(self, env: Envelope) -> None:
        """Return a delivered envelope (preemption) to *this replica*: its
        seat was already spent, so it re-enters through the local requeue
        heap, served before any frontier seat — exactly the QueueClass
        contract, replica-local."""
        heapq.heappush(self._requeue, env)
        act = self.qclass._active
        if act is not None:
            act.mark(self.name)
        self.stats.requeued += 1
        rec = self._obs
        if rec is not None and rec.sampled(env.seq):
            rec.emit("requeue", self.name, env.seq)

    # ---- drain ------------------------------------------------------------
    def _release_lost(self) -> None:
        """Republish staged envelopes whose seat was stolen out from under
        us: one batched publish per home shard, through the transport. The
        thief's seat cursor (not queue position) drives its delivery order,
        so a republish at the tail is order-safe — even when the publish
        crosses hosts."""
        lost = [e for e in self._stage.values()
                if self.seats[e.seq % self._stride].owner.load() != self.addr]
        by_shard: Dict[int, List[Envelope]] = {}
        for env in sorted(lost):
            del self._stage[env.seq]
            by_shard.setdefault(env.seq % self._stride, []).append(env)
        for s, envs in by_shard.items():
            self.transport.publish(self.name, s, envs, self.addr)

    def _deliver(self, env: Envelope, first: bool) -> None:
        qc = self.qclass
        if first:
            if qc.admit_window is not None:
                qc._inflight.fetch_add(-1)  # window seat freed
            self.stats.record_delivery(env)
        self.stats.delivered += 1

    def drain(self, k: int) -> List[Envelope]:
        """Deliver up to ``k`` envelopes: requeued seats first, then the
        frontier merge over owned seats — always the lowest pending class
        cycle this replica owns, claimed from its home shard through the
        transport. Never delivers past a gap in a run: a missing seat is a
        producer mid-submit, a claimed envelope still held by the seat's
        previous owner (who will deliver it — the cursor advances — or
        republish it), or a message in flight on a lossy transport; all of
        them resolve on a later round, so we spin briefly and otherwise
        return short."""
        out: List[Envelope] = []
        self._absorb_handoff()
        while self._requeue and len(out) < k:
            env = heapq.heappop(self._requeue)
            self._deliver(env, first=False)
            out.append(env)
        self._release_lost()
        spins = 0
        while len(out) < k:
            best: Optional[Tuple[int, int]] = None  # (next_seat, shard)
            for s in self.owned():
                nxt = self.seats[s].next_seat.load()
                if nxt < self.qclass._seq.load() and \
                        (best is None or nxt < best[0]):
                    best = (nxt, s)
            if best is None:
                break  # nothing pending in any owned run
            nxt, s = best
            env = self._stage.pop(nxt, None)
            claimed_any = False
            if env is None:
                rec = self._obs
                for e in self.transport.fetch(self.name, s, k, self.addr):
                    claimed_any = True
                    if rec is not None and rec.sampled(e.seq):
                        rec.emit("drain", self.name, e.seq, arg=s)
                    if e.seq == nxt:
                        env = e
                    else:
                        self._stage[e.seq] = e
            if env is None:
                if claimed_any or self.seats[s].next_seat.load() != nxt:
                    spins = 0
                    continue  # progress was made / seat advanced meanwhile
                spins += 1
                if spins > _GAP_PATIENCE:
                    self.stats.gap_waits += 1
                    break
                cpu_pause()
                continue
            spins = 0
            # We hold the claimed envelope -> we are the unique advancer.
            self.seats[s].next_seat.store(nxt + self._stride)
            self._deliver(env, first=True)
            rec = self._obs
            if rec is not None and rec.sampled(env.seq):
                rec.emit("seat", self.name, env.seq, arg=s)
            out.append(env)
        return out

    def snapshot(self) -> dict:
        return self.stats.snapshot(
            pending=self.pending(),
            shard_depths=[self.qclass.shards.depth(s) for s in self.owned()])


class SchedulerReplica:
    """One drain loop's worth of the fabric: a policy over per-class views.

    Presents the same surface as :class:`Scheduler` (``drain``/``policy``/
    ``classes``/``pending``/``snapshot``/``submit``…), so an engine built
    against the scheduler runs unchanged against a replica. Submissions
    delegate to the shared fabric — producers never care which replica will
    drain their item. The replica's :class:`HostAddr` pins it to a
    transport host; ``alive`` goes False when that host is failed.
    """

    def __init__(self, rid: int, scheduler: Scheduler,
                 seats: Dict[str, List[ShardSeat]], *, policy="strict",
                 min_steal: int = 2,
                 transport: Optional[Transport] = None):
        self.rid = rid
        self.scheduler = scheduler
        if transport is None:  # standalone construction (outside ReplicaSet)
            transport = LocalTransport()
            transport.bind(scheduler, seats)
        self.transport = transport
        self.addr = self.transport.addr_of(rid)
        self.alive = self.transport.alive(self.addr.host)
        self.policy = make_policy(policy)
        self.min_steal = int(min_steal)
        self.views: List[ClassView] = [
            ClassView(qc, seats[qc.name], self.addr, self.transport)
            for qc in scheduler.classes]
        self.by_name = {v.name: v for v in self.views}
        self.steals = 0         # successful seat claims
        self.stolen_cycles = 0  # pending cycles acquired via steals
        self.empty_drains = 0   # drain calls that found nothing (idleness)
        self._in_drain = False  # fence for fail_host (plain GIL-atomic bool)

    # flight-recorder attachment (repro.obs); steals are rare control
    # events, recorded unconditionally when a hub is attached
    _obs = None

    # ---- Scheduler facade -------------------------------------------------
    @property
    def classes(self) -> List[ClassView]:
        return self.views

    def _offered(self) -> List[ClassView]:
        """Views offered to the policy / scans: all of them, or — with the
        fabric's active tracking on — only classes that currently hold
        work (the mark-after-enqueue invariant makes the filter safe; a
        racing producer's class shows up by the next call)."""
        act = self.scheduler.active
        if act is None:
            return self.views
        return [self.by_name[n] for n in act.names()]

    @property
    def default_class(self) -> str:
        return self.scheduler.default_class

    def submit(self, qclass: str, payload: Any) -> Optional[Envelope]:
        return self.scheduler.submit(qclass, payload)

    def submit_many(self, qclass: str, payloads: Sequence[Any]
                    ) -> List[Optional[Envelope]]:
        return self.scheduler.submit_many(qclass, payloads)

    def drain(self, k: int) -> List[Tuple[ClassView, Envelope]]:
        # Raise the activity flag BEFORE the liveness check (and lower it
        # after): fail_host sets ``alive`` False and then waits for the
        # flag, so any drain that saw ``alive`` True is waited out and any
        # drain that starts after the wait sees ``alive`` False — no
        # window where recovery and a dying drain touch the same state.
        self._in_drain = True
        try:
            if not self.alive:
                return []
            got = self.policy.drain(self._offered(), k)
        finally:
            self._in_drain = False
        if not got:
            self.empty_drains += 1
        return got

    def pending(self) -> int:
        return sum(v.pending() for v in self._offered()) + self.policy.held()

    def snapshot(self, *, active_only: bool = False) -> dict:
        views = self._offered() if active_only else self.views
        return {v.name: v.snapshot() for v in views}

    # ---- stealing ---------------------------------------------------------
    def steal_if_starved(self) -> int:
        """Starvation rebalance: when this replica has nothing pending,
        claim the seat with the deepest remaining cycle-run from the most
        loaded peer — one claim RPC through the transport, nothing else.
        Returns the number of pending cycles acquired (0 when not starved,
        nothing worth stealing, or the claim failed — CAS race or a
        dropped message, all fine, try again next step)."""
        # Same fence discipline as drain(): a steal by a replica whose
        # host is being failed must either complete before recovery
        # reassigns seats (the wait covers it) or observe alive=False and
        # claim nothing — otherwise a dying thief could CAS a seat back to
        # a dead owner after reassignment and strand the run.
        self._in_drain = True
        try:
            if not self.alive or self.pending() > 0:
                return 0
            return self._steal_best()
        finally:
            self._in_drain = False

    def _steal_best(self) -> int:
        """Pick the victim seat by *unclaimed shard depth* (the domain
        counters: ``cycle − deque_cycle``), not by cursor arithmetic: depth
        counts only items physically claimable from the queue, so a seat
        whose backlog is staged inside a busy peer (claimed, awaiting its
        turn) is never chosen — stealing it would buy nothing until the
        peer republishes, and near a wave's tail that hostage-chasing
        degenerates into seat ping-pong.

        Concurrently starved thieves must also not converge on the single
        deepest seat (they would steal it from each other faster than any
        of them drains it — a thundering herd that starves everyone), so
        each thief indexes into the depth-ranked candidates by its replica
        id: distinct thieves disperse across distinct runs with no shared
        scan state."""
        cands = []
        for v in self._offered():
            for s, seat in enumerate(v.seats):
                owner = seat.owner.load()
                if owner == self.addr:
                    continue
                depth = v.qclass.shards.depth(s)
                if depth >= self.min_steal:
                    cands.append((depth, id(v), v, s))
        if not cands:
            return 0
        cands.sort(key=lambda c: -c[0])
        depth, _, v, s = cands[self.rid % len(cands)]
        if self.transport.claim_seat(v.name, s, self.addr):
            self.steals += 1
            self.stolen_cycles += v._remaining(s)
            rec = self._obs
            if rec is not None:
                rec.emit("steal", v.name, -1,
                         arg={"shard": s, "depth": depth})
            return depth
        return 0


class ReplicaSet:
    """N coordination-free scheduler replicas over one class fabric, spread
    across the transport's hosts.

    Seat ownership starts round-robin (replica ``s % R`` owns shard ``s`` of
    every class — which, under the sim transport's round-robin host layout,
    home-aligns every seat with its shard's host); from then on ownership
    evolves purely through claim RPCs. The set is also the checkpoint
    boundary: :meth:`state` captures an exact-seat frontier snapshot of
    every class — call it between replica steps (or quiesced) and hand the
    plain dict to an async writer.
    """

    def __init__(self, scheduler: Scheduler, num_replicas: int, *,
                 policy="strict", min_steal: int = 2,
                 transport: Optional[Transport] = None):
        assert num_replicas >= 1
        self.scheduler = scheduler
        self.num_replicas = int(num_replicas)
        self.transport = transport if transport is not None \
            else LocalTransport()
        self._policy_spec = policy
        self.min_steal = int(min_steal)
        self.resizes = 0
        self.host_failures = 0
        self._retire_tick = 0
        # per-class roll-up of retired replicas' stats (resize survivors)
        self._retired: Dict[str, dict] = {}
        self.seats: Dict[str, List[ShardSeat]] = {}
        for qc in scheduler.classes:
            S = len(qc.shards)
            assert S >= num_replicas, (
                f"class {qc.name!r} has {S} shards; needs >= {num_replicas} "
                f"(one seat per replica)")
            self.seats[qc.name] = [
                ShardSeat(self.transport.addr_of(s % num_replicas), s)
                for s in range(S)]
        self.transport.bind(scheduler, self.seats)
        self.replicas = self._build_replicas(self.num_replicas)

    def _build_replicas(self, n: int) -> List[SchedulerReplica]:
        return [
            SchedulerReplica(rid, self.scheduler, self.seats,
                             policy=self._policy_spec,
                             min_steal=self.min_steal,
                             transport=self.transport)
            for rid in range(n)]

    def submit(self, qclass: str, payload: Any) -> Optional[Envelope]:
        return self.scheduler.submit(qclass, payload)

    def submit_many(self, qclass: str, payloads: Sequence[Any]
                    ) -> List[Optional[Envelope]]:
        return self.scheduler.submit_many(qclass, payloads)

    def pending(self) -> int:
        return sum(r.pending() for r in self.replicas)

    def rebalance(self) -> int:
        """One steal pass: every starved live replica claims one deep run.
        With active tracking on, the same pass retires drained-empty
        classes from the active set (a class is only fabric-empty when
        every replica's view of it is empty — no single drain loop can
        decide that, so the sweep lives here at the set level)."""
        self._retire_tick += 1
        if self._retire_tick % _RETIRE_EVERY == 0:
            self._retire_idle()
        return sum(r.steal_if_starved() for r in self.replicas if r.alive)

    def _retire_idle(self) -> None:
        # O(active x replicas) pending() probes — correct every step, but
        # retirement is purely an optimization (a stale active entry costs
        # one empty policy visit), so the sweep runs every _RETIRE_EVERY
        # rebalances instead of all of them.
        act = self.scheduler.active
        if act is None:
            return
        for name in act.names():
            if all(r.by_name[name].pending() == 0 for r in self.replicas):
                act.discard(name)

    def live_replicas(self) -> List[SchedulerReplica]:
        return [r for r in self.replicas if r.alive]

    # ---- replica-local state handoff (resize + host recovery) -------------
    def _gather_local(self, replicas: Sequence[SchedulerReplica]
                      ) -> Dict[str, List[Envelope]]:
        """Strip the given replicas of their local state: requeued and
        policy-held envelopes (seats already spent — they must ride to a
        new owner) are returned per class; staged claims (seat not yet
        reached) are republished into their home shard — the new owner's
        cursor, not queue position, drives delivery, so a tail republish is
        order-safe (the same move a steal victim makes in
        :meth:`ClassView._release_lost`). Each replica's counters retire
        into the per-class roll-up so fabric-wide stats survive."""
        carried: Dict[str, List[Envelope]] = {
            qc.name: [] for qc in self.scheduler.classes}
        for r in replicas:
            for view, env in r.policy.take_held():
                carried[view.name].append(env)
            for v in r.views:
                carried[v.name].extend(v._requeue)
                v._requeue = []
                with v._handoff_lock:  # relocated but not yet absorbed
                    carried[v.name].extend(v._handoff)
                    v._handoff = []
                by_shard: Dict[int, List[Envelope]] = {}
                for env in sorted(v._stage.values()):
                    by_shard.setdefault(env.seq % len(v.seats),
                                        []).append(env)
                for s, envs in by_shard.items():
                    self.transport.publish(v.name, s, envs, r.addr)
                v._stage.clear()
                # retire the view's counters into the per-class roll-up so
                # fabric-wide stats (and the SLO view) survive
                snaps = [v.stats.snapshot(pending=0, shard_depths=[])]
                if v.name in self._retired:
                    snaps.append(self._retired[v.name])
                self._retired[v.name] = aggregate_class_snapshots(snaps)
        return carried

    def _reinject(self, carried: Dict[str, List[Envelope]]) -> None:
        """Hand carried (seat-spent) envelopes to their seats' current
        owners through the thread-safe handoff inbox (the owner's drain
        loop may be running concurrently during host recovery; its heap is
        only ever touched by its own thread). A relocation, not a
        preemption — the requeued telemetry is not inflated."""
        for name, envs in carried.items():
            seats = self.seats[name]
            for env in sorted(envs):
                rid = seats[env.seq % len(seats)].owner.load().rid
                self.replicas[rid].by_name[name].handoff(env)

    # ---- live elasticity --------------------------------------------------
    def resize(self, num_replicas: int) -> int:
        """Grow/shrink to ``num_replicas`` drain loops over the same fabric:
        a batch of seat claims plus replica-local state handoff — producers
        are never paused, and every class keeps its exact delivery order.

        Mechanics (call from the drain control thread, i.e. between drain
        rounds — producers may keep submitting concurrently): every
        replica's local state is gathered (:meth:`_gather_local`), seat
        ownership is re-claimed round-robin over the *live-host* replicas
        (seat ``s`` -> the s-th live replica, one CAS per moving seat;
        ``next_seat`` cursors are untouched, so delivery resumes at the
        exact frontier), and carried envelopes land on the new owners.

        Returns the number of seats that changed owner.
        """
        new_n = int(num_replicas)
        assert new_n >= 1
        if new_n == self.num_replicas:
            return 0
        for qc in self.scheduler.classes:
            assert len(qc.shards) >= new_n, (
                f"class {qc.name!r} has {len(qc.shards)} shards; resize to "
                f"{new_n} replicas needs one seat per replica")
        self.transport.quiesce()  # delayed in-flight envelopes re-shard
        carried = self._gather_local(self.replicas)
        self.num_replicas = new_n
        self.replicas = self._build_replicas(new_n)
        live = [r.addr for r in self.replicas if r.alive]
        assert live, "resize with every host dead"
        # One reseat batch for the whole sweep: in-process transports CAS
        # the seat cells directly; the wire transport coalesces each host's
        # slice into one batched claim frame.
        moved = self.transport.reseat(
            [(name, s, live[s % len(live)])
             for name, seats in self.seats.items()
             for s in range(len(seats))])
        self._reinject(carried)
        self.resizes += 1
        return moved

    # ---- host failure recovery --------------------------------------------
    def fail_host(self, host: int) -> int:
        """Kill one transport host mid-run and recover its seats into the
        survivors. The dead host's drain loops stop (``alive`` goes False);
        its final frontier state — requeued seats, policy-held heads,
        staged claims — is serialized through the wire codec (the frontier
        checkpoint format, DESIGN.md §9/§11) and replayed into the
        surviving owners; its seats are re-claimed round-robin across the
        survivors. Per-class delivery order is preserved exactly: spent
        seats ride as requeues, unreached seats republish to their home
        shards, cursors are untouched.

        In deployment the replay source is the host's latest frontier
        snapshot; in the sim it is the host's in-process state — the bytes
        are identical, which is the point. Returns the number of seats
        reassigned.
        """
        dead = [r for r in self.replicas
                if r.alive and r.addr.host == host]
        assert dead, f"no live replicas on host {host}"
        survivors = [r for r in self.replicas
                     if r.alive and r.addr.host != host]
        assert survivors, "cannot fail the last live host"
        # Fence: kill the dead replicas' drain/steal loops BEFORE touching
        # their local state. Both drain() and steal_if_starved() raise
        # ``_in_drain`` before checking ``alive``, so after this wait no
        # dead replica can deliver an envelope this recovery republishes
        # (delivered twice) or CAS a seat back to a dead owner after the
        # reassignment below (stranded run).
        for r in dead:
            r.alive = False
        while any(r._in_drain for r in dead):
            cpu_pause()
        self.transport.fail_host(host)  # marks dead, flushes in-flight
        carried = self._gather_local(dead)
        # The recovery replay rides the wire: encode -> bytes -> decode,
        # preserving submit stamps (same monotonic clock in the sim).
        for name, envs in carried.items():
            if not envs:
                continue
            stamps = [e.t_submit for e in sorted(envs)]
            carried[name] = wire_decode(
                wire_encode(envs, self.transport._encode),
                self.transport._decode, t_submit=stamps)
        # Reassign the dead host's seats round-robin over the survivors —
        # recovery is control-plane: a reseat batch, not chaos-lossy RPCs,
        # conditional on the owner still being the dead host (a concurrent
        # steal that got there first wins). One cycle shared across ALL
        # classes: restarting it per class would hand every class's dead
        # seat to the same survivor and concentrate the dead host's whole
        # backlog on one replica.
        tgt = itertools.cycle(survivors)
        assignments = []
        for name, seats in self.seats.items():
            for s, seat in enumerate(seats):
                if seat.owner.load().host == host:
                    assignments.append((name, s, next(tgt).addr))
        moved = self.transport.reseat(assignments, expect_host=host)
        self._reinject(carried)
        self.host_failures += 1
        return moved

    def snapshot(self, *, active_only: bool = False) -> dict:
        out: dict = {"replicas": {}, "classes": {},
                     "transport": self.transport.stats()}
        for r in self.replicas:
            out["replicas"][r.rid] = {
                "host": r.addr.host, "alive": r.alive,
                "steals": r.steals, "stolen_cycles": r.stolen_cycles,
                "empty_drains": r.empty_drains, "pending": r.pending(),
                "classes": r.snapshot(active_only=active_only),
            }
        act = self.scheduler.active
        if active_only and act is not None:
            classes = [self.scheduler.by_name[n] for n in act.names()]
        else:
            classes = self.scheduler.classes
        for qc in classes:
            snaps = [r.by_name[qc.name].snapshot() for r in self.replicas]
            if qc.name in self._retired:  # counters from pre-resize replicas
                snaps.append(self._retired[qc.name])
            agg = aggregate_class_snapshots(snaps)
            # submit-side counters live on the class, not the views
            agg["submitted"] = qc.stats.submitted
            agg["rejected"] = qc.stats.rejected
            out["classes"][qc.name] = agg
        return out

    # ---- checkpoint -------------------------------------------------------
    def state(self, *, encode=None) -> dict:
        """Exact-seat frontier snapshot of the whole fabric: per class the
        cycle counter, per-seat cursors/owners (owners as host-addressed
        ``[host, rid]`` pairs), and every undelivered envelope (in-flight
        transport envelopes are quiesced back first; shard leftovers are
        claimed, recorded, and republished in place — the snapshot consumes
        nothing). Take it at a step boundary (no replica mid-drain); the
        returned dict is plain data for an async writer. Restoring resumes
        every tenant at its exact seat — under any transport/host layout,
        because owners are recorded by replica and re-addressed on
        restore."""
        self.transport.quiesce()
        out = {"num_replicas": self.num_replicas,
               "stamp": self.scheduler._stamp.load(),
               "transport": self.transport.spec(),
               "classes": {}}
        for qc in self.scheduler.classes:
            seats = self.seats[qc.name]
            S = len(qc.shards)
            seq = qc._seq.load()
            # every undelivered seat the cursors say exists must be captured
            expected = sum(
                (seq - seat.next_seat.load() + S - 1) // S
                for seat in seats if seat.next_seat.load() < seq)
            claimed: List[Envelope] = []
            staged: List[Envelope] = []
            requeue: List[Envelope] = []
            for r in self.replicas:
                v = r.by_name[qc.name]
                staged.extend(v._stage.values())
                requeue.extend(v._requeue)
                requeue.extend(v._handoff)  # relocated, not yet absorbed
                # envelopes buffered inside the policy (e.g. a fifo-merge
                # head pulled but not yet emitted): their seat cursor has
                # already advanced, so they checkpoint as requeued seats
                requeue.extend(env for view, env in r.policy.held_items()
                               if view.name == qc.name)
            # Claim-accumulate until the cursors' count is covered: a seat
            # can be momentarily invisible while a producer sits between
            # its stamp fetch-add and its shard splice — same bounded-spin
            # head-of-line contract as QueueClass._capture_pending; an
            # uncaptured seat is reported in ``gaps``, never silent.
            spins = 0
            while True:
                got_any = False
                for q in qc.shards.queues:
                    while True:
                        got = q.dequeue_many(64)
                        if not got:
                            break
                        claimed.extend(got)
                        got_any = True
                if len(claimed) + len(staged) >= expected:
                    break
                if not got_any:
                    spins += 1
                    if spins > _GAP_PATIENCE:
                        break
                    cpu_pause()
            for env in claimed:  # republish in place: snapshot, not drain
                qc.shards.queues[env.seq % S].enqueue(env)
            pending = claimed + staged
            out["classes"][qc.name] = {
                **qc._meta_state(),
                "owners": [list(s.owner.load()) for s in seats],
                "next_seats": [s.next_seat.load() for s in seats],
                "frontier": min((s.next_seat.load() for s in seats),
                                default=0),
                "gaps": max(0, expected - len(pending)),
                "pending": encode_envelopes(pending, encode),
                "requeue": encode_envelopes(requeue, encode),
            }
        return out

    @classmethod
    def from_state(cls, state: dict, *, decode=None, policy="strict",
                   min_steal: int = 2,
                   transport: Optional[Transport] = None,
                   **queue_kw) -> "ReplicaSet":
        """Rebuild the fabric at the checkpointed seats: cycle counters,
        seat cursors and ownership resume exactly; undelivered envelopes
        re-enter their home shard (``seq % S``); requeued seats land on the
        replica owning their home seat. Owners are recorded by replica id
        and re-addressed through the *restoring* transport, so a snapshot
        taken under one host layout (e.g. ``LocalTransport``) restores onto
        another (e.g. a multi-host ``SimHostTransport``) — the host half of
        the address is derived, the seat protocol state is what transfers.
        Continuing delivers every tenant's remaining items from its exact
        FIFO seat — nothing lost, nothing reordered within a run."""
        classes = []
        for name, cs in state["classes"].items():
            qc = QueueClass._from_meta(cs, **queue_kw)
            # keep the Scheduler facade's counters coherent too: its
            # pending() is frontier-based (under replica management the
            # authoritative emptiness check is ReplicaSet.pending(), which
            # reads the live seat cursors)
            qc._frontier = cs["frontier"]
            if qc.admit_window is not None:
                # undelivered (pending) items still hold window seats;
                # requeued ones freed theirs at first delivery
                qc._inflight.store(len(cs["pending"]))
            classes.append(qc)
        sched = Scheduler(classes, policy=policy)
        sched._stamp.store(state["stamp"])
        rs = cls(sched, state["num_replicas"], policy=policy,
                 min_steal=min_steal, transport=transport)
        now = time.monotonic()
        for name, cs in state["classes"].items():
            qc = sched.by_name[name]
            S = len(qc.shards)
            seats = rs.seats[name]
            assignments = []
            for s, (owner, nxt) in enumerate(zip(cs["owners"],
                                                 cs["next_seats"])):
                _, rid = decode_owner(owner)
                assignments.append((name, s, rs.transport.addr_of(rid)))
                seats[s].next_seat.store(int(nxt))
            # restore is a reseat sweep like resize: in-process transports
            # CAS the cells; the wire transport also updates the spawned
            # fleet's authoritative seat tables
            rs.transport.reseat(assignments)
            for rec in cs["pending"]:
                env = decode_envelope(rec, decode, now=now)
                qc.shards.queues[env.seq % S].enqueue(env)
            for rec in cs["requeue"]:
                env = decode_envelope(rec, decode, now=now)
                rid = seats[env.seq % S].owner.load().rid
                rs.replicas[rid].by_name[name].requeue(env)
        return rs
