"""Sharded scheduler replicas: N drain loops over one class fabric
(DESIGN.md §9).

PR 2 made the fabric many-producer but left it one-consumer: a single
policy drain loop feeds the engine, and that loop is the scalability
ceiling the paper says a CMP system should not have. This module splits the
*consumer* side into N :class:`SchedulerReplica`\\ s, each owning a subset of
every class's shards and running its own policy drain — no replica ever
waits on another. Two CMP ideas carry the whole design:

  * **Ownership is a claim.** Each (class, shard) pair has a
    :class:`ShardSeat` whose ``owner`` field is a single CAS-published cell.
    A starved replica *steals the seat* — one CAS, no handshake, no victim
    participation — and with it the shard's entire cycle-run, past and
    future (placement is ``seq % S``, so a seat carries the arithmetic
    sequence ``s, s+S, s+2S, …`` of class cycles forever). Stealing items
    one batch at a time would poke holes in a peer's frontier arithmetic;
    stealing the seat moves the *run*, which is exactly the granularity at
    which class-cycle order is preserved.
  * **The seat cursor makes delivery exact.** ``ShardSeat.next_seat`` is
    the next undelivered class cycle of that shard. Only the replica
    holding the claimed envelope for that cycle advances the cursor
    (the queue's claim CAS already made holding exclusive, so the advance
    needs no CAS of its own). A replica's drain is a frontier merge over
    its owned seats: always deliver the lowest pending cycle it owns.

Ordering contract: *within every shard's cycle-run, delivery is exactly the
class-cycle order; across the fabric, each class's seats are delivered
exactly once, and merging the replica streams by seat recovers the dense
class-cycle order 0,1,2,….* With static ownership each replica's stream is
itself seat-monotone; a steal splices a run between replicas but never
reorders within one, never loses a seat, never delivers one twice.

Crash contract: a replica that dies holding claimed-but-undelivered
envelopes takes them with it — the same contract as any crashed consumer in
the paper. Recovery is :meth:`ReplicaSet.state` / :meth:`ReplicaSet.from_state`:
an exact-seat frontier snapshot (taken at a step boundary, written
asynchronously) from which every tenant resumes at its exact FIFO seat.
"""

from __future__ import annotations

import heapq
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.atomics import AtomicCell, cpu_pause
from repro.sched.classes import (_GAP_PATIENCE, Envelope, QueueClass,
                                 Scheduler, decode_envelope,
                                 encode_envelopes)
from repro.sched.policy import make_policy
from repro.sched.steal import claim_seat
from repro.sched.stats import ClassStats, aggregate_class_snapshots


class ShardSeat:
    """Ownership + delivery cursor for one (class, shard) pair.

    ``owner`` is the replica id currently entitled to drain the shard —
    CAS-published, so a steal is literally one claim. ``next_seat`` is the
    next undelivered class cycle of the shard's run (always ≡ shard index
    mod S); it is advanced with a plain store by whichever replica holds
    the claimed envelope for that cycle — the queue's claim CAS already
    made that replica unique, so the cursor needs no second CAS.
    """

    __slots__ = ("owner", "next_seat")

    def __init__(self, owner: int, shard: int):
        self.owner = AtomicCell(int(owner))
        self.next_seat = AtomicCell(int(shard))


class ClassView:
    """One replica's drain view of one :class:`QueueClass`.

    Quacks like a ``QueueClass`` for everything a drain policy or the
    engine touches (``name``/``priority``/``weight``/``drain``/``pending``/
    ``requeue``/``snapshot``), but delivers only the cycle-runs of the
    seats this replica currently owns.
    """

    def __init__(self, qclass: QueueClass, seats: List[ShardSeat], rid: int):
        self.qclass = qclass
        self.seats = seats
        self.rid = rid
        self._stride = len(qclass.shards)
        self._stage: Dict[int, Envelope] = {}  # claimed, awaiting their seat
        self._requeue: List[Envelope] = []     # preempted (seat already spent)
        self.stats = ClassStats(qclass.name)

    # ---- QueueClass facade ------------------------------------------------
    @property
    def name(self) -> str:
        return self.qclass.name

    @property
    def priority(self) -> int:
        return self.qclass.priority

    @property
    def weight(self) -> float:
        return self.qclass.weight

    def owned(self) -> List[int]:
        return [s for s, seat in enumerate(self.seats)
                if seat.owner.load() == self.rid]

    def _remaining(self, shard: int) -> int:
        """Undelivered seats left in one owned shard's cycle-run."""
        nxt = self.seats[shard].next_seat.load()
        seq = self.qclass._seq.load()
        if nxt >= seq:
            return 0
        return (seq - nxt + self._stride - 1) // self._stride

    def pending(self) -> int:
        return (len(self._requeue)
                + sum(self._remaining(s) for s in self.owned()))

    def requeue(self, env: Envelope) -> None:
        """Return a delivered envelope (preemption) to *this replica*: its
        seat was already spent, so it re-enters through the local requeue
        heap, served before any frontier seat — exactly the QueueClass
        contract, replica-local."""
        heapq.heappush(self._requeue, env)
        self.stats.requeued += 1

    # ---- drain ------------------------------------------------------------
    def _release_lost(self) -> None:
        """Republish staged envelopes whose seat was stolen out from under
        us: one batched re-enqueue into the home shard. The thief's seat
        cursor (not queue position) drives its delivery order, so a
        republish at the tail is order-safe."""
        lost = [e for e in self._stage.values()
                if self.seats[e.seq % self._stride].owner.load() != self.rid]
        for env in sorted(lost):
            del self._stage[env.seq]
            self.qclass.shards.queues[env.seq % self._stride].enqueue(env)

    def _deliver(self, env: Envelope, first: bool) -> None:
        qc = self.qclass
        if first:
            if qc.admit_window is not None:
                qc._inflight.fetch_add(-1)  # window seat freed
            self.stats.record_delivery(env)
        self.stats.delivered += 1

    def drain(self, k: int) -> List[Envelope]:
        """Deliver up to ``k`` envelopes: requeued seats first, then the
        frontier merge over owned seats — always the lowest pending class
        cycle this replica owns, claimed from its home shard. Never
        delivers past a gap in a run: a missing seat is a producer
        mid-submit or a claimed envelope still held by the seat's previous
        owner (who will deliver it — the cursor advances — or republish
        it), so we spin briefly and otherwise return short."""
        out: List[Envelope] = []
        while self._requeue and len(out) < k:
            env = heapq.heappop(self._requeue)
            self._deliver(env, first=False)
            out.append(env)
        self._release_lost()
        queues = self.qclass.shards.queues
        spins = 0
        while len(out) < k:
            best: Optional[Tuple[int, int]] = None  # (next_seat, shard)
            for s in self.owned():
                nxt = self.seats[s].next_seat.load()
                if nxt < self.qclass._seq.load() and \
                        (best is None or nxt < best[0]):
                    best = (nxt, s)
            if best is None:
                break  # nothing pending in any owned run
            nxt, s = best
            env = self._stage.pop(nxt, None)
            claimed_any = False
            if env is None:
                for e in queues[s].dequeue_many(k):
                    claimed_any = True
                    if e.seq == nxt:
                        env = e
                    else:
                        self._stage[e.seq] = e
            if env is None:
                if claimed_any or self.seats[s].next_seat.load() != nxt:
                    spins = 0
                    continue  # progress was made / seat advanced meanwhile
                spins += 1
                if spins > _GAP_PATIENCE:
                    self.stats.gap_waits += 1
                    break
                cpu_pause()
                continue
            spins = 0
            # We hold the claimed envelope -> we are the unique advancer.
            self.seats[s].next_seat.store(nxt + self._stride)
            self._deliver(env, first=True)
            out.append(env)
        return out

    def snapshot(self) -> dict:
        return self.stats.snapshot(
            pending=self.pending(),
            shard_depths=[self.qclass.shards.depth(s) for s in self.owned()])


class SchedulerReplica:
    """One drain loop's worth of the fabric: a policy over per-class views.

    Presents the same surface as :class:`Scheduler` (``drain``/``policy``/
    ``classes``/``pending``/``snapshot``/``submit``…), so an engine built
    against the scheduler runs unchanged against a replica. Submissions
    delegate to the shared fabric — producers never care which replica will
    drain their item.
    """

    def __init__(self, rid: int, scheduler: Scheduler,
                 seats: Dict[str, List[ShardSeat]], *, policy="strict",
                 min_steal: int = 2):
        self.rid = rid
        self.scheduler = scheduler
        self.policy = make_policy(policy)
        self.min_steal = int(min_steal)
        self.views: List[ClassView] = [
            ClassView(qc, seats[qc.name], rid) for qc in scheduler.classes]
        self.by_name = {v.name: v for v in self.views}
        self.steals = 0         # successful seat claims
        self.stolen_cycles = 0  # pending cycles acquired via steals
        self.empty_drains = 0   # drain calls that found nothing (idleness)

    # ---- Scheduler facade -------------------------------------------------
    @property
    def classes(self) -> List[ClassView]:
        return self.views

    @property
    def default_class(self) -> str:
        return self.scheduler.default_class

    def submit(self, qclass: str, payload: Any) -> Optional[Envelope]:
        return self.scheduler.submit(qclass, payload)

    def submit_many(self, qclass: str, payloads: Sequence[Any]
                    ) -> List[Optional[Envelope]]:
        return self.scheduler.submit_many(qclass, payloads)

    def drain(self, k: int) -> List[Tuple[ClassView, Envelope]]:
        got = self.policy.drain(self.views, k)
        if not got:
            self.empty_drains += 1
        return got

    def pending(self) -> int:
        return sum(v.pending() for v in self.views) + self.policy.held()

    def snapshot(self) -> dict:
        return {v.name: v.snapshot() for v in self.views}

    # ---- stealing ---------------------------------------------------------
    def steal_if_starved(self) -> int:
        """Starvation rebalance: when this replica has nothing pending,
        claim the seat with the deepest remaining cycle-run from the most
        loaded peer — one CAS on the owner cell, nothing else. Returns the
        number of pending cycles acquired (0 when not starved, nothing
        worth stealing, or the CAS lost a race — all fine, try again next
        step)."""
        if self.pending() > 0:
            return 0
        return self._steal_best()

    def _steal_best(self) -> int:
        """Pick the victim seat by *unclaimed shard depth* (the domain
        counters: ``cycle − deque_cycle``), not by cursor arithmetic: depth
        counts only items physically claimable from the queue, so a seat
        whose backlog is staged inside a busy peer (claimed, awaiting its
        turn) is never chosen — stealing it would buy nothing until the
        peer republishes, and near a wave's tail that hostage-chasing
        degenerates into seat ping-pong.

        Concurrently starved thieves must also not converge on the single
        deepest seat (they would steal it from each other faster than any
        of them drains it — a thundering herd that starves everyone), so
        each thief indexes into the depth-ranked candidates by its replica
        id: distinct thieves disperse across distinct runs with no shared
        scan state."""
        cands = []
        for v in self.views:
            for s, seat in enumerate(v.seats):
                owner = seat.owner.load()
                if owner == self.rid:
                    continue
                depth = v.qclass.shards.depth(s)
                if depth >= self.min_steal:
                    cands.append((depth, id(v), v, s))
        if not cands:
            return 0
        cands.sort(key=lambda c: -c[0])
        depth, _, v, s = cands[self.rid % len(cands)]
        if claim_seat(v.seats[s], self.rid):
            self.steals += 1
            self.stolen_cycles += v._remaining(s)
            return depth
        return 0


class ReplicaSet:
    """N coordination-free scheduler replicas over one class fabric.

    Seat ownership starts round-robin (replica ``s % R`` owns shard ``s`` of
    every class); from then on it evolves purely through steal CASes. The
    set is also the checkpoint boundary: :meth:`state` captures an
    exact-seat frontier snapshot of every class — call it between replica
    steps (or quiesced) and hand the plain dict to an async writer.
    """

    def __init__(self, scheduler: Scheduler, num_replicas: int, *,
                 policy="strict", min_steal: int = 2):
        assert num_replicas >= 1
        self.scheduler = scheduler
        self.num_replicas = int(num_replicas)
        self._policy_spec = policy
        self.min_steal = int(min_steal)
        self.resizes = 0
        # per-class roll-up of retired replicas' stats (resize survivors)
        self._retired: Dict[str, dict] = {}
        self.seats: Dict[str, List[ShardSeat]] = {}
        for qc in scheduler.classes:
            S = len(qc.shards)
            assert S >= num_replicas, (
                f"class {qc.name!r} has {S} shards; needs >= {num_replicas} "
                f"(one seat per replica)")
            self.seats[qc.name] = [ShardSeat(s % num_replicas, s)
                                   for s in range(S)]
        self.replicas = [
            SchedulerReplica(rid, scheduler, self.seats, policy=policy,
                             min_steal=min_steal)
            for rid in range(self.num_replicas)]

    def submit(self, qclass: str, payload: Any) -> Optional[Envelope]:
        return self.scheduler.submit(qclass, payload)

    def submit_many(self, qclass: str, payloads: Sequence[Any]
                    ) -> List[Optional[Envelope]]:
        return self.scheduler.submit_many(qclass, payloads)

    def pending(self) -> int:
        return sum(r.pending() for r in self.replicas)

    def rebalance(self) -> int:
        """One steal pass: every starved replica claims one deep run."""
        return sum(r.steal_if_starved() for r in self.replicas)

    # ---- live elasticity --------------------------------------------------
    def resize(self, num_replicas: int) -> int:
        """Grow/shrink to ``num_replicas`` drain loops over the same fabric:
        a batch of seat claims plus replica-local state handoff — producers
        are never paused, and every class keeps its exact delivery order.

        Mechanics (call from the drain control thread, i.e. between drain
        rounds — producers may keep submitting concurrently):

          * every replica-local envelope whose seat cursor has already
            advanced (requeue heaps, policy-held heads) is carried to the
            seat's *new* owner, seat-ordered;
          * staged claims (seat not yet reached) are republished into their
            home shard — the new owner's cursor, not queue position, drives
            delivery, so a tail republish is order-safe (the same move a
            steal victim makes in :meth:`ClassView._release_lost`);
          * seat ownership is re-claimed round-robin (seat ``s`` -> replica
            ``s % n``), one CAS per moving seat; ``next_seat`` cursors are
            untouched, so delivery resumes at the exact frontier.

        Returns the number of seats that changed owner.
        """
        new_n = int(num_replicas)
        assert new_n >= 1
        if new_n == self.num_replicas:
            return 0
        for qc in self.scheduler.classes:
            assert len(qc.shards) >= new_n, (
                f"class {qc.name!r} has {len(qc.shards)} shards; resize to "
                f"{new_n} replicas needs one seat per replica")
        # Gather replica-local state. Requeued + policy-held envelopes have
        # spent their seats (cursor already advanced) and must ride to the
        # new owner; staged claims go back to their home shard.
        carried: Dict[str, List[Envelope]] = {
            qc.name: [] for qc in self.scheduler.classes}
        for r in self.replicas:
            for view, env in r.policy.held_items():
                carried[view.name].append(env)
            for v in r.views:
                carried[v.name].extend(v._requeue)
                v._requeue = []
                S = len(v.qclass.shards)
                for env in sorted(v._stage.values()):
                    v.qclass.shards.queues[env.seq % S].enqueue(env)
                v._stage.clear()
                # retire the view's counters into the per-class roll-up so
                # fabric-wide stats (and the SLO view) survive the resize
                snaps = [v.stats.snapshot(pending=0, shard_depths=[])]
                if v.name in self._retired:
                    snaps.append(self._retired[v.name])
                self._retired[v.name] = aggregate_class_snapshots(snaps)
        # The batch of seat claims: reseat round-robin over the new count.
        moved = 0
        for seats in self.seats.values():
            for s, seat in enumerate(seats):
                target = s % new_n
                cur = seat.owner.load()
                while cur != target:
                    if seat.owner.cas(cur, target):
                        moved += 1
                        break
                    cur = seat.owner.load()
        self.num_replicas = new_n
        self.replicas = [
            SchedulerReplica(rid, self.scheduler, self.seats,
                             policy=self._policy_spec,
                             min_steal=self.min_steal)
            for rid in range(new_n)]
        for name, envs in carried.items():
            seats = self.seats[name]
            for env in sorted(envs):
                rid = seats[env.seq % len(seats)].owner.load()
                # direct heap push, not ClassView.requeue(): a carried seat
                # is a relocation, not a new preemption — the requeued
                # counter already rode into _retired (and policy-held heads
                # were never preemptions at all)
                heapq.heappush(self.replicas[rid].by_name[name]._requeue,
                               env)
        self.resizes += 1
        return moved

    def snapshot(self) -> dict:
        out: dict = {"replicas": {}, "classes": {}}
        for r in self.replicas:
            out["replicas"][r.rid] = {
                "steals": r.steals, "stolen_cycles": r.stolen_cycles,
                "empty_drains": r.empty_drains, "pending": r.pending(),
                "classes": r.snapshot(),
            }
        for qc in self.scheduler.classes:
            snaps = [r.by_name[qc.name].snapshot() for r in self.replicas]
            if qc.name in self._retired:  # counters from pre-resize replicas
                snaps.append(self._retired[qc.name])
            agg = aggregate_class_snapshots(snaps)
            # submit-side counters live on the class, not the views
            agg["submitted"] = qc.stats.submitted
            agg["rejected"] = qc.stats.rejected
            out["classes"][qc.name] = agg
        return out

    # ---- checkpoint -------------------------------------------------------
    def state(self, *, encode=None) -> dict:
        """Exact-seat frontier snapshot of the whole fabric: per class the
        cycle counter, per-seat cursors/owners, and every undelivered
        envelope (shard leftovers are claimed, recorded, and republished in
        place — the snapshot consumes nothing). Take it at a step boundary
        (no replica mid-drain); the returned dict is plain data for an
        async writer. Restoring resumes every tenant at its exact seat."""
        out = {"num_replicas": self.num_replicas,
               "stamp": self.scheduler._stamp.load(),
               "classes": {}}
        for qc in self.scheduler.classes:
            seats = self.seats[qc.name]
            S = len(qc.shards)
            seq = qc._seq.load()
            # every undelivered seat the cursors say exists must be captured
            expected = sum(
                (seq - seat.next_seat.load() + S - 1) // S
                for seat in seats if seat.next_seat.load() < seq)
            claimed: List[Envelope] = []
            staged: List[Envelope] = []
            requeue: List[Envelope] = []
            for r in self.replicas:
                v = r.by_name[qc.name]
                staged.extend(v._stage.values())
                requeue.extend(v._requeue)
                # envelopes buffered inside the policy (e.g. a fifo-merge
                # head pulled but not yet emitted): their seat cursor has
                # already advanced, so they checkpoint as requeued seats
                requeue.extend(env for view, env in r.policy.held_items()
                               if view.name == qc.name)
            # Claim-accumulate until the cursors' count is covered: a seat
            # can be momentarily invisible while a producer sits between
            # its stamp fetch-add and its shard splice — same bounded-spin
            # head-of-line contract as QueueClass._capture_pending; an
            # uncaptured seat is reported in ``gaps``, never silent.
            spins = 0
            while True:
                got_any = False
                for q in qc.shards.queues:
                    while True:
                        got = q.dequeue_many(64)
                        if not got:
                            break
                        claimed.extend(got)
                        got_any = True
                if len(claimed) + len(staged) >= expected:
                    break
                if not got_any:
                    spins += 1
                    if spins > _GAP_PATIENCE:
                        break
                    cpu_pause()
            for env in claimed:  # republish in place: snapshot, not drain
                qc.shards.queues[env.seq % S].enqueue(env)
            pending = claimed + staged
            out["classes"][qc.name] = {
                **qc._meta_state(),
                "owners": [s.owner.load() for s in seats],
                "next_seats": [s.next_seat.load() for s in seats],
                "frontier": min((s.next_seat.load() for s in seats),
                                default=0),
                "gaps": max(0, expected - len(pending)),
                "pending": encode_envelopes(pending, encode),
                "requeue": encode_envelopes(requeue, encode),
            }
        return out

    @classmethod
    def from_state(cls, state: dict, *, decode=None, policy="strict",
                   min_steal: int = 2, **queue_kw) -> "ReplicaSet":
        """Rebuild the fabric at the checkpointed seats: cycle counters,
        seat cursors and ownership resume exactly; undelivered envelopes
        re-enter their home shard (``seq % S``); requeued seats land on the
        replica owning their home seat. Continuing delivers every tenant's
        remaining items from its exact FIFO seat — nothing lost, nothing
        reordered within a run."""
        classes = []
        for name, cs in state["classes"].items():
            qc = QueueClass._from_meta(cs, **queue_kw)
            # keep the Scheduler facade's counters coherent too: its
            # pending() is frontier-based (under replica management the
            # authoritative emptiness check is ReplicaSet.pending(), which
            # reads the live seat cursors)
            qc._frontier = cs["frontier"]
            if qc.admit_window is not None:
                # undelivered (pending) items still hold window seats;
                # requeued ones freed theirs at first delivery
                qc._inflight.store(len(cs["pending"]))
            classes.append(qc)
        sched = Scheduler(classes, policy=policy)
        sched._stamp.store(state["stamp"])
        rs = cls(sched, state["num_replicas"], policy=policy,
                 min_steal=min_steal)
        now = time.monotonic()
        for name, cs in state["classes"].items():
            qc = sched.by_name[name]
            S = len(qc.shards)
            seats = rs.seats[name]
            for s, (owner, nxt) in enumerate(zip(cs["owners"],
                                                 cs["next_seats"])):
                seats[s].owner.store(int(owner))
                seats[s].next_seat.store(int(nxt))
            for rec in cs["pending"]:
                env = decode_envelope(rec, decode, now=now)
                qc.shards.queues[env.seq % S].enqueue(env)
            for rec in cs["requeue"]:
                env = decode_envelope(rec, decode, now=now)
                rid = seats[env.seq % S].owner.load()
                rs.replicas[rid].by_name[name].requeue(env)
        return rs
