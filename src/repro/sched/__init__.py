"""Coordination-free multi-tenant scheduler: a sharded, priority-class CMP
queue fabric (DESIGN.md §8).

  - :mod:`repro.sched.classes` — :class:`QueueClass` (sharded CMP queues,
    dense class-cycle stamps, frontier drain, window-based admission) and
    :class:`Scheduler` (the fabric).
  - :mod:`repro.sched.policy`  — strict-priority / weighted-fair /
    FIFO-across-classes drain policies.
  - :mod:`repro.sched.steal`   — work stealing between shards (a steal is a
    claim; window safety is inherited from the protection domain).
  - :mod:`repro.sched.replica` — N scheduler replicas over one fabric
    (DESIGN.md §9): host-addressed seat ownership claimed by CAS,
    per-replica frontier merges, exact-seat checkpoint/restore, host-loss
    recovery.
  - :mod:`repro.sched.transport` — the pluggable seat-protocol transport
    (DESIGN.md §11): `LocalTransport` (in-process, zero-copy) and
    `SimHostTransport` (N simulated hosts, serialized wire envelopes,
    injectable drop/delay/reorder chaos).
  - :mod:`repro.sched.stats`   — per-class occupancy/latency/steal telemetry
    sampled from domain state, zero added atomics.
  - :mod:`repro.sched.tenants` — O(active)-cost tenant scale (DESIGN.md
    §16): hashed tenant->class-group routing, the active-set index, lazy
    per-tenant stats, and per-tenant KV page quotas.
"""

from repro.sched.classes import (Envelope, QueueClass, Scheduler, ShardSet,
                                 shard_for)
from repro.sched.policy import (ClassFifo, DrainPolicy, HierarchicalWFQ,
                                StrictPriority, WeightedFair, make_policy)
from repro.sched.replica import (ClassView, ReplicaSet, SchedulerReplica,
                                 ShardSeat)
from repro.sched.stats import (ClassStats, LatencyWindow,
                               aggregate_class_snapshots)
from repro.sched.steal import (ShardConsumer, claim_seat, queue_depth,
                               rebalance, steal_into)
from repro.sched.tenants import (TIERS, ActiveSet, TenantMap,
                                 TenantQuotaLedger, TenantRouter,
                                 TenantStatsTable, group_class_name,
                                 split_class_name, tenant_hash)
from repro.sched.transport import (HostAddr, LocalTransport,
                                   SimHostTransport, Transport,
                                   decode_owner, make_transport)

__all__ = [
    "Envelope", "QueueClass", "Scheduler", "ShardSet", "shard_for",
    "DrainPolicy", "StrictPriority", "WeightedFair", "ClassFifo",
    "HierarchicalWFQ", "make_policy",
    "ClassStats", "LatencyWindow", "aggregate_class_snapshots",
    "ShardConsumer", "queue_depth", "rebalance", "steal_into", "claim_seat",
    "ClassView", "ReplicaSet", "SchedulerReplica", "ShardSeat",
    "TIERS", "ActiveSet", "TenantMap", "TenantQuotaLedger", "TenantRouter",
    "TenantStatsTable", "group_class_name", "split_class_name", "tenant_hash",
    "HostAddr", "LocalTransport", "SimHostTransport", "Transport",
    "decode_owner", "make_transport",
]
