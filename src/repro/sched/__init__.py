"""Coordination-free multi-tenant scheduler: a sharded, priority-class CMP
queue fabric (DESIGN.md §8).

  - :mod:`repro.sched.classes` — :class:`QueueClass` (sharded CMP queues,
    dense class-cycle stamps, frontier drain, window-based admission) and
    :class:`Scheduler` (the fabric).
  - :mod:`repro.sched.policy`  — strict-priority / weighted-fair /
    FIFO-across-classes drain policies.
  - :mod:`repro.sched.steal`   — work stealing between shards (a steal is a
    claim; window safety is inherited from the protection domain).
  - :mod:`repro.sched.stats`   — per-class occupancy/latency/steal telemetry
    sampled from domain state, zero added atomics.
"""

from repro.sched.classes import (Envelope, QueueClass, Scheduler, ShardSet,
                                 shard_for)
from repro.sched.policy import (ClassFifo, DrainPolicy, StrictPriority,
                                WeightedFair, make_policy)
from repro.sched.stats import ClassStats, LatencyWindow
from repro.sched.steal import ShardConsumer, queue_depth, rebalance, steal_into

__all__ = [
    "Envelope", "QueueClass", "Scheduler", "ShardSet", "shard_for",
    "DrainPolicy", "StrictPriority", "WeightedFair", "ClassFifo",
    "make_policy", "ClassStats", "LatencyWindow",
    "ShardConsumer", "queue_depth", "rebalance", "steal_into",
]
