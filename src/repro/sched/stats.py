"""Per-class scheduler telemetry with zero added atomics (DESIGN.md §8).

Everything here is sampled from state that already exists for correctness:
shard occupancy comes from the domain counters (``cycle`` − ``deque_cycle``,
plain atomic loads), class depth from the class cycle vs. the drain frontier,
and admission latency from the wall-clock stamp every envelope already
carries. Delivery-side counters are plain ints written by the single drainer;
submit-side counters (submitted/rejected) have arbitrarily many writers, so
they are fetch-adds — reads are diagnostic snapshots, exact when quiesced.
"""

from __future__ import annotations

import time
from typing import List, Optional

from repro.core.atomics import AtomicCell

#: Merged-snapshot reservoirs are decimated past this size so chained
#: roll-ups (the resize-retirement path folds old aggregates into new ones)
#: stay bounded. Decimation strides over the *sorted* pool, preserving the
#: distribution shape.
_POOL_CAP = 8192


def _interp_percentile(s: List[float], p: float) -> float:
    """Percentile with linear interpolation between closest ranks.
    ``s`` must be sorted ascending and non-empty; ``p`` in [0, 100]."""
    n = len(s)
    f = (p / 100.0) * (n - 1)
    if f <= 0.0:
        return s[0]
    lo = int(f)
    if lo >= n - 1:
        return s[n - 1]
    frac = f - lo
    return s[lo] + (s[lo + 1] - s[lo]) * frac


class LatencyWindow:
    """Fixed-size ring of the most recent latency samples (seconds).
    Appended by the single drainer — no locks, no atomics."""

    def __init__(self, capacity: int = 2048):
        self.capacity = int(capacity)
        self._buf: List[float] = []
        self._idx = 0
        self.count = 0  # total samples ever recorded
        # Sorted view of _buf, rebuilt lazily by percentile() and
        # invalidated on every record: stats() at tenant scale reads
        # several percentiles per class per interval, and re-sorting the
        # full ring for each read is the dominant telemetry cost.
        self._sorted: Optional[List[float]] = None

    def record(self, seconds: float) -> None:
        if len(self._buf) < self.capacity:
            self._buf.append(seconds)
        else:
            self._buf[self._idx] = seconds
            self._idx = (self._idx + 1) % self.capacity
        self.count += 1
        self._sorted = None

    def record_many(self, samples: List[float]) -> None:
        """Batched append with slice-assigned wraparound (the bulk-drain
        fast path): the reservoir is order-free — :meth:`percentile` sorts a
        snapshot — so overwriting the oldest run with one or two C-speed
        slice assignments keeps the same most-recent-N contents as N scalar
        :meth:`record` calls."""
        cap = self.capacity
        buf = self._buf
        self.count += len(samples)
        self._sorted = None
        if len(samples) >= cap:
            self._buf = list(samples[-cap:])
            self._idx = 0
            return
        room = cap - len(buf)
        if room:
            buf.extend(samples[:room])
            samples = samples[room:]
            if not samples:
                return
        i = self._idx
        end = i + len(samples)
        if end <= cap:
            buf[i:end] = samples
            self._idx = end % cap
        else:
            first = cap - i
            buf[i:] = samples[:first]
            rest = len(samples) - first
            buf[:rest] = samples[first:]
            self._idx = rest

    def percentile(self, p: float) -> Optional[float]:
        """p in [0, 100]; None when empty. Sorts a snapshot of the ring
        once and caches it until the next record — consecutive percentile
        reads (p50 then p99 per class, across many classes per stats
        interval) share one sort. Linear interpolation between closest
        ranks (numpy's default), not nearest-rank: at small sample counts
        nearest-rank rounding can move a p99 by a whole sample step,
        which is exactly the regime the SLO view reads."""
        if not self._buf:
            return None
        if self._sorted is None or len(self._sorted) != len(self._buf):
            self._sorted = sorted(self._buf)
        return _interp_percentile(self._sorted, p)

    def samples(self) -> List[float]:
        """Copy of the retained reservoir contents (unordered, seconds).
        Lets aggregators pool raw samples across replicas for exact merged
        percentiles instead of conservative picks."""
        return list(self._buf)


class ClassStats:
    """Counters + admission-latency reservoir for one :class:`QueueClass`.
    ``delivered``/``requeued``/``gap_waits`` are written by the single
    drainer only; the submit-side counts race across producers and go
    through :meth:`add_submitted`/:meth:`add_rejected` (fetch-add)."""

    def __init__(self, name: str, latency_capacity: int = 2048):
        self.name = name
        self._submitted = AtomicCell(0)
        self._rejected = AtomicCell(0)
        self.delivered = 0
        self.requeued = 0
        self.gap_waits = 0
        self.latency = LatencyWindow(latency_capacity)

    @property
    def submitted(self) -> int:
        return self._submitted.load()

    @property
    def rejected(self) -> int:
        return self._rejected.load()

    def add_submitted(self, n: int = 1) -> None:
        self._submitted.fetch_add(n)

    def add_rejected(self, n: int = 1) -> None:
        self._rejected.fetch_add(n)

    def record_delivery(self, env) -> None:
        self.latency.record(time.monotonic() - env.t_submit)

    def record_delivery_many(self, envs) -> None:
        """Batched delivery accounting: one clock read for the whole batch
        (the bulk-drain fast path, DESIGN.md §12)."""
        now = time.monotonic()
        self.latency.record_many([now - env.t_submit for env in envs])

    def snapshot(self, *, pending: int = 0,
                 shard_depths: Optional[List[int]] = None) -> dict:
        p50 = self.latency.percentile(50)
        p99 = self.latency.percentile(99)
        return {
            "class": self.name,
            "pending": pending,
            "shard_depths": list(shard_depths or []),
            "submitted": self.submitted,
            "rejected": self.rejected,
            "delivered": self.delivered,
            "requeued": self.requeued,
            "gap_waits": self.gap_waits,
            "admit_p50_ms": None if p50 is None else p50 * 1e3,
            "admit_p99_ms": None if p99 is None else p99 * 1e3,
            "latency_samples": self.latency.samples(),
        }


def aggregate_class_snapshots(per_replica: List[dict]) -> dict:
    """Fabric-wide roll-up of one class's per-replica ``ClassStats``
    snapshots: counters and shard depths add; latency percentiles merge
    *exactly* by pooling each replica's raw reservoir samples
    (``latency_samples``, seconds) and recomputing over the pool. Snapshots
    lacking raw samples (e.g. deserialized legacy aggregates) fall back to
    the conservative pick — worst replica's p99, best replica's p50 — for
    the whole merge, since a partial pool would under-weight them."""
    assert per_replica
    out = dict(per_replica[0])
    for snap in per_replica[1:]:
        for key in ("pending", "submitted", "rejected", "delivered",
                    "requeued", "gap_waits"):
            out[key] = out[key] + snap[key]
        out["shard_depths"] = out["shard_depths"] + snap["shard_depths"]

    pooled: List[float] = []
    exact = True
    for snap in per_replica:
        s = snap.get("latency_samples")
        if s is not None:
            pooled.extend(s)
        elif snap.get("admit_p50_ms") is not None:
            exact = False  # has latency but no raw samples to pool
    if pooled and exact:
        pooled.sort()
        out["admit_p50_ms"] = _interp_percentile(pooled, 50) * 1e3
        out["admit_p99_ms"] = _interp_percentile(pooled, 99) * 1e3
        if len(pooled) > _POOL_CAP:
            stride = -(-len(pooled) // _POOL_CAP)
            pooled = pooled[::stride]
        out["latency_samples"] = pooled
    else:
        for key, pick in (("admit_p50_ms", min), ("admit_p99_ms", max)):
            vals = [snap.get(key) for snap in per_replica]
            vals = [v for v in vals if v is not None]
            out[key] = pick(vals) if vals else None
        out["latency_samples"] = sorted(pooled) if pooled else None
    return out
