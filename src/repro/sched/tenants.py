"""Tenant-scale routing for the scheduler fabric (ISSUE 10).

The fabric's class grid is bounded (groups x tiers) no matter how many
tenants are declared: tenants hash onto class-groups, and every hot path
(drain, pending, stats, gauges) walks only the *active* subset of that
grid.  The pieces here are plain host Python — no jax imports — because
scheduler-only fabrics must stay importable without an accelerator
runtime (see fabric/session.py).

Components:

- ``tenant_hash`` / ``TenantMap``: deterministic FNV-1a tenant->group
  routing.  Python's builtin ``hash()`` is process-salted, so it would
  break snapshot-restore across processes; FNV-1a over ``str(tenant)``
  with a config salt survives resize/fail_host/restore because the group
  id is a pure function of (tenant, num_groups, salt) — none of which
  change over fabric lifetime.
- ``ActiveSet``: the active-class index.  Classes enter on enqueue
  (mark AFTER the item is visible in the queue) and leave when a drain
  sweep observes them empty.  A stale mark costs one wasted scan; a
  missed retire is corrected by the next sweep; an item can never be
  stranded because its mark happens after its enqueue.
- ``TenantStatsTable``: lazy per-tenant counters — allocated on first
  traffic, evicted (merged into an aggregate) when idle and over
  capacity.  Plain ints only: the per-envelope path adds zero atomics.
- ``TenantQuotaLedger``: per-tenant page quotas with per-host aggregate
  caps carved with the same host-first split the engine uses for lane
  and page budgets.
- ``TenantRouter``: the composition Fabric.submit talks to — routing,
  admission verdicts (ok / shed / reject), charge-at-admission with
  credit-at-delivery, and JSON state for snapshots.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "TIERS",
    "tenant_hash",
    "group_class_name",
    "split_class_name",
    "split_hosted",
    "TenantMap",
    "ActiveSet",
    "TenantStatsTable",
    "TenantQuotaLedger",
    "TenantRouter",
]

# Tier order is highest-priority first; the LAST tier is the sheddable
# one (429 rejects under pressure hit only this tier).
TIERS: Tuple[str, ...] = ("interactive", "batch", "background")

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def tenant_hash(tenant: Any, salt: int = 0) -> int:
    """64-bit FNV-1a over ``str(tenant)``, stable across processes.

    Deliberately NOT Python ``hash()``: that is salted per process, and
    tenant->group routing must survive snapshot-restore into a new
    interpreter.
    """
    h = (_FNV_OFFSET ^ (salt & _MASK64)) * _FNV_PRIME & _MASK64
    for b in str(tenant).encode("utf-8"):
        h = ((h ^ b) * _FNV_PRIME) & _MASK64
    return h


def group_class_name(gid: int, tier: str) -> str:
    """Class name for (group, tier) — ``g017:interactive``.

    The group id is encoded in the NAME so tenant classes ride every
    name-keyed path (snapshots, wire codec, seat maps, stats) with zero
    serialization changes.
    """
    return f"g{gid:03d}:{tier}"


def split_class_name(name: str) -> Tuple[str, str]:
    """Inverse of group_class_name: -> (group_key, tier)."""
    group, _, tier = name.partition(":")
    return group, tier


def split_hosted(total: int, num_hosts: int, min_per: int = 0) -> List[int]:
    """Host-first even split of ``total`` units across ``num_hosts``.

    Mirrors the engine's ``_split_budget_hosted`` discipline: every host
    gets ``min_per`` up front, the remainder spreads one unit at a time
    so no host is more than one unit ahead.
    """
    if num_hosts <= 0:
        return []
    caps = [min_per] * num_hosts
    rest = max(0, total - min_per * num_hosts)
    base, extra = divmod(rest, num_hosts)
    for h in range(num_hosts):
        caps[h] += base + (1 if h < extra else 0)
    return caps


class TenantMap:
    """Deterministic tenant -> (group, class) routing onto a bounded grid.

    ``num_groups * len(tiers)`` real QueueClass objects serve any number
    of declared tenants; per-tenant strict FIFO inside a group follows
    from CMP's dense per-class cycle stamps (items of one tenant land in
    one class in submit order, and class drain is stamp-ordered no
    matter which shard or thief holds an item).
    """

    # Submit-path memo bound: tenant -> group results cached up to this
    # many distinct tenants, then dropped wholesale (heavy-tail traffic
    # re-fills the hot entries within one wave). Keeps routing O(1) per
    # repeat submit without O(declared) resident memory.
    CACHE_CAP = 4096

    def __init__(self, num_tenants: int, num_groups: int, salt: int = 0,
                 tiers: Tuple[str, ...] = TIERS):
        if num_groups < 1:
            raise ValueError("num_groups must be >= 1")
        self.num_tenants = int(num_tenants)
        self.num_groups = int(num_groups)
        self.salt = int(salt)
        self.tiers = tuple(tiers)
        # (group, tier) -> name is the whole bounded grid, precomputed so
        # class_of is two dict hits on the hot path (no f-string formats)
        self._names = {(g, t): group_class_name(g, t)
                       for g in range(self.num_groups) for t in self.tiers}
        self._group_memo: Dict[str, int] = {}

    def group_of(self, tenant: Any) -> int:
        key = str(tenant)
        gid = self._group_memo.get(key)
        if gid is None:
            gid = tenant_hash(key, self.salt) % self.num_groups
            if len(self._group_memo) >= self.CACHE_CAP:
                self._group_memo.clear()
            self._group_memo[key] = gid
        return gid

    def class_of(self, tenant: Any, tier: str) -> str:
        name = self._names.get((self.group_of(tenant), tier))
        if name is None:
            raise KeyError(f"unknown tier {tier!r}; expected one of {self.tiers}")
        return name

    def class_names(self) -> List[str]:
        """The full grid, group-major (bounded, independent of tenants)."""
        return [group_class_name(g, t)
                for g in range(self.num_groups) for t in self.tiers]

    def host_of(self, tenant: Any, num_hosts: int) -> int:
        """Quota-accounting host for a tenant (group-affine)."""
        return self.group_of(tenant) % max(1, num_hosts)

    def state(self) -> Dict[str, Any]:
        return {"num_tenants": self.num_tenants, "num_groups": self.num_groups,
                "salt": self.salt, "tiers": list(self.tiers)}

    @classmethod
    def from_state(cls, st: Dict[str, Any]) -> "TenantMap":
        return cls(st["num_tenants"], st["num_groups"], st["salt"],
                   tuple(st["tiers"]))


class ActiveSet:
    """Insertion-ordered set of class names with queued work.

    GIL-atomic dict ops only — no locks, no added atomics on the submit
    path.  The invariant that makes mark/retire races benign: producers
    mark AFTER their item is visible in the queue, so any retire sweep
    that observes pending()==0 and drops the name either ran before the
    enqueue (the following mark re-adds it) or after the item was
    drained (nothing stranded).
    """

    __slots__ = ("_names",)

    def __init__(self) -> None:
        self._names: Dict[str, None] = {}

    def mark(self, name: str) -> None:
        if name not in self._names:
            self._names[name] = None

    def discard(self, name: str) -> None:
        self._names.pop(name, None)

    def __contains__(self, name: str) -> bool:
        return name in self._names

    def __len__(self) -> int:
        return len(self._names)

    def names(self) -> List[str]:
        return list(self._names)

    def state(self) -> List[str]:
        return list(self._names)

    def restore(self, names: Iterable[str]) -> None:
        for n in names:
            self._names[n] = None


class _TenantRecord:
    __slots__ = ("submitted", "delivered", "shed", "rejected", "backlog")

    def __init__(self) -> None:
        self.submitted = 0
        self.delivered = 0
        self.shed = 0
        self.rejected = 0
        self.backlog = 0  # charged-not-yet-delivered items


class TenantStatsTable:
    """Lazy per-tenant counters, bounded by eviction of idle records.

    Records are plain-int and allocated on first traffic.  When the
    table exceeds ``capacity``, idle records (backlog == 0) are merged
    into a single aggregate in insertion order — tenant cardinality
    never leaks into memory or into stats() output.  Backlogged tenants
    are never evicted (they are active by definition, so the table stays
    O(active + capacity)).
    """

    def __init__(self, capacity: int = 1024, top_k: int = 8):
        self.capacity = int(capacity)
        self.top_k = int(top_k)
        self._records: Dict[str, _TenantRecord] = {}
        # Aggregate of evicted records so fabric totals stay exact.
        self._evicted = {"tenants": 0, "submitted": 0, "delivered": 0,
                         "shed": 0, "rejected": 0}

    def _record(self, tenant: Any) -> _TenantRecord:
        key = str(tenant)
        rec = self._records.get(key)
        if rec is None:
            if len(self._records) >= self.capacity:
                self._evict_idle()
            rec = self._records[key] = _TenantRecord()
        return rec

    def _evict_idle(self) -> None:
        ev = self._evicted
        for key in list(self._records):
            rec = self._records[key]
            if rec.backlog == 0:
                ev["tenants"] += 1
                ev["submitted"] += rec.submitted
                ev["delivered"] += rec.delivered
                ev["shed"] += rec.shed
                ev["rejected"] += rec.rejected
                del self._records[key]
                if len(self._records) < self.capacity:
                    return

    def note_submit(self, tenant: Any, items: int = 1) -> None:
        rec = self._record(tenant)
        rec.submitted += items
        rec.backlog += items

    def note_deliver(self, tenant: Any, items: int = 1) -> None:
        rec = self._record(tenant)
        rec.delivered += items
        rec.backlog = max(0, rec.backlog - items)

    def note_shed(self, tenant: Any, items: int = 1) -> None:
        self._record(tenant).shed += items

    def note_reject(self, tenant: Any, items: int = 1) -> None:
        self._record(tenant).rejected += items

    def tracked(self) -> int:
        return len(self._records)

    def totals(self) -> Dict[str, int]:
        out = dict(self._evicted)
        out["tenants"] = self._evicted["tenants"] + len(self._records)
        for rec in self._records.values():
            out["submitted"] += rec.submitted
            out["delivered"] += rec.delivered
            out["shed"] += rec.shed
            out["rejected"] += rec.rejected
        return out

    def top_by_backlog(self, k: Optional[int] = None) -> List[Dict[str, int]]:
        k = self.top_k if k is None else k
        busy = [(key, rec) for key, rec in self._records.items()
                if rec.backlog > 0]
        busy.sort(key=lambda kv: -kv[1].backlog)
        return [{"tenant": key, "backlog": rec.backlog,
                 "submitted": rec.submitted, "delivered": rec.delivered,
                 "shed": rec.shed}
                for key, rec in busy[:k]]

    def snapshot(self) -> Dict[str, Any]:
        totals = self.totals()
        return {"tracked": len(self._records),
                "active_backlog": sum(1 for r in self._records.values()
                                      if r.backlog > 0),
                "totals": totals,
                "top": self.top_by_backlog()}

    def state(self) -> Dict[str, Any]:
        return {
            "evicted": dict(self._evicted),
            "records": {key: [r.submitted, r.delivered, r.shed, r.rejected,
                              r.backlog]
                        for key, r in self._records.items()},
        }

    def restore(self, st: Dict[str, Any]) -> None:
        self._evicted = dict(st["evicted"])
        self._records = {}
        for key, (sub, dlv, shd, rej, bkl) in st["records"].items():
            rec = _TenantRecord()
            rec.submitted, rec.delivered = int(sub), int(dlv)
            rec.shed, rec.rejected, rec.backlog = int(shd), int(rej), int(bkl)
            self._records[key] = rec


class TenantQuotaLedger:
    """Per-tenant page quotas with per-host aggregate caps.

    ``charge`` is called at admission with a page estimate, ``credit``
    at delivery/completion.  A tenant is denied when it would exceed its
    own quota OR its host's aggregate cap — the caps are carved from the
    fabric page budget with the same host-first split the engine uses
    for lanes and pages, so quota pressure lands on the same host that
    would run the work.
    """

    def __init__(self, per_tenant: int, total: int, num_hosts: int = 1):
        self.per_tenant = int(per_tenant)
        self.num_hosts = max(1, int(num_hosts))
        self.host_caps = split_hosted(int(total), self.num_hosts)
        self._tenant_used: Dict[str, int] = {}
        self._host_used: List[int] = [0] * self.num_hosts

    def used(self, tenant: Any) -> int:
        return self._tenant_used.get(str(tenant), 0)

    def host_used(self, host: int) -> int:
        return self._host_used[host]

    def charge(self, tenant: Any, host: int, pages: int) -> bool:
        key = str(tenant)
        host = host % self.num_hosts
        cur = self._tenant_used.get(key, 0)
        if cur + pages > self.per_tenant:
            return False
        if self._host_used[host] + pages > self.host_caps[host]:
            return False
        self._tenant_used[key] = cur + pages
        self._host_used[host] += pages
        return True

    def credit(self, tenant: Any, host: int, pages: int) -> None:
        key = str(tenant)
        host = host % self.num_hosts
        cur = self._tenant_used.get(key, 0)
        nxt = max(0, cur - pages)
        if nxt:
            self._tenant_used[key] = nxt
        else:
            self._tenant_used.pop(key, None)
        self._host_used[host] = max(0, self._host_used[host] - pages)

    def rehost(self, num_hosts: int) -> None:
        """Re-carve host caps after resize/fail_host.

        Outstanding charges are re-attributed by re-running the group-
        affine host mapping at credit time, so we simply re-split the
        aggregate: totals are conserved, per-tenant usage is untouched.
        """
        num_hosts = max(1, int(num_hosts))
        total = sum(self.host_caps)
        used = sum(self._host_used)
        self.num_hosts = num_hosts
        self.host_caps = split_hosted(total, num_hosts)
        self._host_used = split_hosted(used, num_hosts)

    def state(self) -> Dict[str, Any]:
        return {"per_tenant": self.per_tenant, "num_hosts": self.num_hosts,
                "host_caps": list(self.host_caps),
                "host_used": list(self._host_used),
                "tenant_used": dict(self._tenant_used)}

    @classmethod
    def from_state(cls, st: Dict[str, Any]) -> "TenantQuotaLedger":
        led = cls(st["per_tenant"], 0, st["num_hosts"])
        led.host_caps = [int(x) for x in st["host_caps"]]
        led._host_used = [int(x) for x in st["host_used"]]
        led._tenant_used = {k: int(v) for k, v in st["tenant_used"].items()}
        return led


class TenantRouter:
    """Routing + admission + charge/credit accounting for Fabric.submit.

    The router never walks the class grid: every operation is O(1) dict
    work keyed by the tenant or by the admission key handed back from
    ``note_admit``.  Shed-vs-reject semantics: only the LAST tier (the
    sheddable background class) records 429-style ``shed``; pressure or
    quota denials on higher tiers count as ordinary rejects.
    """

    def __init__(self, tmap: TenantMap, stats: TenantStatsTable,
                 ledger: Optional[TenantQuotaLedger] = None,
                 admit_pressure: float = 0.85):
        self.map = tmap
        self.stats = stats
        self.ledger = ledger
        self.admit_pressure = float(admit_pressure)
        self.shed_total = 0
        self.shed_by_class: Dict[str, int] = {}
        # Outstanding admission charges: key -> (tenant_str, host, pages).
        # Sched-only fabrics key by (class_name, seq); serving keys by uid.
        self._charges: Dict[Any, Tuple[str, int, int]] = {}

    # -- admission ---------------------------------------------------------

    def sheddable(self, tier: str) -> bool:
        return tier == self.map.tiers[-1]

    def route(self, tenant: Any, tier: str) -> Tuple[int, str]:
        gid = self.map.group_of(tenant)
        name = self.map._names.get((gid, tier))
        if name is None:
            raise KeyError(f"unknown tier {tier!r}; expected one of "
                           f"{self.map.tiers}")
        return gid, name

    def try_charge(self, tenant: Any, pages: int) -> bool:
        if self.ledger is None or pages <= 0:
            return True
        host = self.map.host_of(tenant, self.ledger.num_hosts)
        return self.ledger.charge(tenant, host, pages)

    def cancel_charge(self, tenant: Any, pages: int) -> None:
        """Undo a ``try_charge`` that never reached admission (the class
        window rejected after the ledger said yes)."""
        if self.ledger is not None and pages > 0:
            host = self.map.host_of(tenant, self.ledger.num_hosts)
            self.ledger.credit(tenant, host, pages)

    def note_admit(self, tenant: Any, key: Any, pages: int,
                   items: int = 1) -> None:
        """Record an admission: per-tenant stats plus the key -> (tenant,
        host, pages) entry ``on_done`` resolves at delivery. The entry is
        recorded even without a ledger (pages=0) — it is how deliveries
        find their tenant."""
        self.stats.note_submit(tenant, items)
        if self.ledger is not None and pages > 0:
            host = self.map.host_of(tenant, self.ledger.num_hosts)
            self._charges[key] = (str(tenant), host, pages)
        else:
            self._charges[key] = (str(tenant), 0, 0)

    def note_shed(self, tenant: Any, cls_name: str, items: int = 1) -> None:
        self.shed_total += items
        self.shed_by_class[cls_name] = (
            self.shed_by_class.get(cls_name, 0) + items)
        self.stats.note_shed(tenant, items)

    def note_reject(self, tenant: Any, items: int = 1) -> None:
        self.stats.note_reject(tenant, items)

    def on_done(self, key: Any, tenant: Any = None, items: int = 1) -> None:
        """Credit a delivery/completion by its admission key."""
        charge = self._charges.pop(key, None)
        if charge is not None:
            t, host, pages = charge
            if self.ledger is not None and pages > 0:
                self.ledger.credit(t, host, pages)
            self.stats.note_deliver(t, items)
        elif tenant is not None:
            self.stats.note_deliver(tenant, items)

    def outstanding(self) -> int:
        return len(self._charges)

    # -- snapshot ----------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        out = {"declared": self.map.num_tenants,
               "groups": self.map.num_groups,
               "shed_total": self.shed_total}
        out.update(self.stats.snapshot())
        if self.ledger is not None:
            out["quota"] = {"per_tenant": self.ledger.per_tenant,
                            "host_caps": list(self.ledger.host_caps),
                            "host_used": [self.ledger.host_used(h)
                                          for h in range(self.ledger.num_hosts)],
                            "outstanding": len(self._charges)}
        return out

    def state(self) -> Dict[str, Any]:
        # JSON-safe: charge keys may be tuples -> encode as tagged lists.
        charges = []
        for key, (t, host, pages) in self._charges.items():
            if isinstance(key, tuple):
                charges.append(["t", list(key), t, host, pages])
            else:
                charges.append(["s", key, t, host, pages])
        return {"map": self.map.state(),
                "stats": self.stats.state(),
                "ledger": None if self.ledger is None else self.ledger.state(),
                "admit_pressure": self.admit_pressure,
                "shed_total": self.shed_total,
                "shed_by_class": dict(self.shed_by_class),
                "charges": charges}

    @classmethod
    def from_state(cls, st: Dict[str, Any],
                   stats_capacity: int = 1024,
                   stats_top_k: int = 8) -> "TenantRouter":
        tmap = TenantMap.from_state(st["map"])
        stats = TenantStatsTable(stats_capacity, stats_top_k)
        stats.restore(st["stats"])
        ledger = (None if st["ledger"] is None
                  else TenantQuotaLedger.from_state(st["ledger"]))
        router = cls(tmap, stats, ledger, st["admit_pressure"])
        router.shed_total = int(st["shed_total"])
        router.shed_by_class = {k: int(v)
                                for k, v in st["shed_by_class"].items()}
        for tag, key, t, host, pages in st["charges"]:
            k = tuple(key) if tag == "t" else key
            router._charges[k] = (t, int(host), int(pages))
        return router
