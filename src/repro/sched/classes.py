"""Priority-class CMP queue fabric (DESIGN.md §8).

The paper's pitch is that CMP makes queues cheap enough to be the *fabric* of
a serving pipeline. This module composes many CMP queues under one scheduler:

  * :class:`ShardSet` — S independent :class:`CMPQueue` shards. Shard load is
    sampled straight from the domain counters (``cycle`` − ``deque_cycle``),
    zero added atomics.
  * :class:`QueueClass` — one tenant/priority class. Every submit linearizes
    at a dense per-class cycle stamp (one fetch-add); the item lands on shard
    ``seq % S``. The drain side re-merges shards through a *cycle frontier*:
    items are delivered in exactly class-cycle order, no matter which shard
    holds them — which is what makes work stealing (migration between shards)
    order-invisible. Admission is window-bounded via ``domain.window_admit``:
    the class rejects (backpressure) instead of growing without bound.
  * :class:`Scheduler` — the fabric: classes + a drain policy + one global
    arrival stamp (for FIFO-across-classes merges).

Ordering contract: *strict FIFO per class, policy-relaxed across classes.*
Within a class, delivery order is exactly the class-cycle order assigned at
submit — stronger than the base queue's per-producer FIFO, and preserved
under concurrent producers and stealers (tests/test_sched.py). Across
classes, the policy decides — that is the only ordering the fabric relaxes.

Concurrency contract: any number of producers (``submit``/``submit_many``)
and stealers (:mod:`repro.sched.steal`) run fully concurrently; the *drain*
of one class is single-caller (the scheduler loop), like the engine's
scheduler thread. A producer stalled between its stamp and its shard enqueue
stalls only its own class's frontier (head-of-line within the class is what
strict FIFO *means*); other classes are unaffected — that is the fabric's
whole point.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.atomics import AtomicCell, cpu_pause
from repro.core.cmp import CMPQueue
from repro.core.domain import window_admit
from repro.sched.stats import ClassStats

# Drain-side bounded spin while the frontier item is mid-enqueue (a producer
# between its stamp fetch-add and its shard splice). The gap window is a few
# instructions; a handful of pauses covers it without coordinating.
_GAP_PATIENCE = 64


@dataclasses.dataclass
class Envelope:
    """What actually travels through a class's shards.

    ``seq`` is the class cycle (dense, assigned at submit — the class-local
    FIFO order). ``stamp`` is the fabric-global arrival cycle (the merge key
    for FIFO-across-classes). ``t_submit`` feeds admission-latency telemetry.
    """
    __slots__ = ("seq", "stamp", "t_submit", "payload")
    seq: int
    stamp: int
    t_submit: float
    payload: Any

    def __lt__(self, other: "Envelope") -> bool:  # heapq (requeue order)
        return self.seq < other.seq


def shard_for(key: int, num_shards: int) -> int:
    """Stable multiplicative hash (Knuth) — producer-side shard pick."""
    return (int(key) * 2654435761 % (1 << 32)) % num_shards


def encode_envelopes(envs: Iterable[Envelope], encode=None) -> List[list]:
    """Checkpoint wire format for envelopes: ``[seq, stamp, payload]``
    records, seat-sorted. ``encode`` maps payloads to JSON-able values
    (default identity). Shared by the single-drain and replica codecs so
    the format cannot drift between them."""
    enc = encode or (lambda p: p)
    return [[e.seq, e.stamp, enc(e.payload)] for e in sorted(envs)]


def decode_envelope(rec: list, decode=None, *, now: float = None) -> Envelope:
    """Inverse of :func:`encode_envelopes` for one record. ``t_submit`` is
    reset to ``now`` — the old process's monotonic clock is meaningless
    here, and latency telemetry should count from the restore."""
    dec = decode or (lambda p: p)
    return Envelope(rec[0], rec[1],
                    time.monotonic() if now is None else now, dec(rec[2]))


def queue_depth(q: CMPQueue) -> int:
    """Unclaimed-depth estimate for one CMP queue, read from the domain
    counters alone (enqueue cycle − protection boundary): zero added
    atomics, approximate under in-flight claims, exact when quiesced."""
    return max(0, q.cycle.load() - q.deque_cycle.load())


class ShardSet:
    """S independent CMP queues with domain-state load sampling."""

    def __init__(self, num_shards: int = 1, **queue_kw):
        assert num_shards >= 1
        self.queues: List[CMPQueue] = [CMPQueue(**queue_kw)
                                       for _ in range(num_shards)]

    def __len__(self) -> int:
        return len(self.queues)

    def shard_for(self, key: int) -> int:
        return shard_for(key, len(self.queues))

    def depth(self, idx: int) -> int:
        """Unclaimed-depth estimate for one shard (see `queue_depth`)."""
        return queue_depth(self.queues[idx])

    def depths(self) -> List[int]:
        return [self.depth(i) for i in range(len(self.queues))]

    def live_nodes(self) -> int:
        return sum(q.live_nodes() for q in self.queues)


class QueueClass:
    """One tenant/priority class over a CMP shard set.

    Args:
      name: class identity (policy and telemetry key).
      priority: bigger = more urgent (strict-priority order, preemption rank).
      weight: share under weighted-fair draining.
      num_shards: CMP queue shards (stealing targets).
      admit_window: window-based admission bound — at most this many items
        in flight (submitted, not yet first-delivered); ``None`` = unbounded.
        This is ``domain.window_admit`` read as backpressure: the j-th
        outstanding submission is admitted iff j < W. Enforced with one
        fetch-add on an in-flight counter (claim-then-check, surplus rolled
        back before anything is enqueued), so the bound holds under any
        number of racing producers — overshoot is impossible; a transient
        spurious reject under a race is the conservative direction.
      queue_kw: forwarded to each shard's :class:`CMPQueue`.
    """

    def __init__(self, name: str, *, priority: int = 0, weight: float = 1.0,
                 num_shards: int = 1, admit_window: Optional[int] = None,
                 **queue_kw):
        self.name = name
        self.priority = int(priority)
        self.weight = float(weight)
        self.admit_window = admit_window
        self._queue_kw = dict(queue_kw)  # retained for checkpointing
        self.shards = ShardSet(num_shards, **queue_kw)
        self._seq = AtomicCell(0)      # class cycle: submit linearization point
        self._inflight = AtomicCell(0)  # admission-window occupancy (atomic)
        self._frontier = 0             # next seq to deliver (drain-side only)
        self._stage: Dict[int, Envelope] = {}   # claimed, awaiting their turn
        self._requeue: List[Envelope] = []      # preempted (seq < frontier)
        self.stats = ClassStats(name)

    # flight-recorder attachment (repro.obs): None until a MetricsHub
    # attaches — the un-observed hot path pays one `is None` check.
    # Head-sampling is a pure function of the class cycle (`rec.sampled`),
    # so every lifecycle emit site agrees on which envelopes are traced.
    _obs = None

    # active-set attachment (repro.sched.tenants): None until a tenant
    # fabric enables O(active) tracking via Scheduler.enable_active_
    # tracking() — same discipline as _obs, one `is None` check when off.
    # Producers mark AFTER their item is visible in a shard, so a retire
    # sweep can never strand an item (see ActiveSet).
    _active = None

    # ------------------------------------------------------------- producers
    def pending(self) -> int:
        """Items submitted but not yet first-delivered (+ requeued)."""
        return max(0, self._seq.load() - self._frontier) + len(self._requeue)

    def submit(self, payload: Any, *, stamp: int = 0) -> Optional[Envelope]:
        """Admit one item; returns its envelope, or None on window rejection.

        The fetch-add on the class cycle is the linearization point; placement
        is round-robin by cycle (``seq % S``) so the frontier drain knows the
        stamps are dense."""
        if self.admit_window is not None:
            # Claim a window seat first, roll back on overflow: racing
            # producers can never exceed the bound (j-th in flight iff j < W).
            pos = self._inflight.fetch_add(1)
            if not window_admit(pos, self.admit_window):
                self._inflight.fetch_add(-1)
                self.stats.add_rejected()
                return None
        seq = self._seq.fetch_add(1)
        env = Envelope(seq, stamp, time.monotonic(), payload)
        self.shards.queues[seq % len(self.shards)].enqueue(env)
        act = self._active
        if act is not None:
            act.mark(self.name)  # after the enqueue: never strands the item
        self.stats.add_submitted()
        rec = self._obs
        if rec is not None and rec.sampled(seq):
            self._trace_submit(rec, seq, env.t_submit)
        return env

    def _trace_submit(self, rec, seq: int, t0: float) -> None:
        """Off the fast path: the three producer-side lifecycle stages for
        one sampled envelope (stamp, window seat, shard splice)."""
        rec.emit("submit", self.name, seq, t=t0)
        rec.emit("window_admit", self.name, seq, t=t0)
        rec.emit("shard_enqueue", self.name, seq,
                 arg=seq % len(self.shards))

    def submit_many(self, payloads: Sequence[Any], *, stamp: int = 0
                    ) -> List[Optional[Envelope]]:
        """Batched admission: one cycle-range fetch-add for the accepted
        prefix, one ``enqueue_many`` splice per shard. Items beyond the
        admission window are rejected (None entries, suffix-aligned)."""
        payloads = list(payloads)
        n = len(payloads)
        if self.admit_window is not None:
            # Claim the whole range, return the surplus: bound never exceeded.
            old = self._inflight.fetch_add(n)
            room = max(0, min(n, self.admit_window - old))
            if room < n:
                self._inflight.fetch_add(room - n)
            n = room
        if n == 0:
            self.stats.add_rejected(len(payloads))
            return [None] * len(payloads)
        base = self._seq.fetch_add(n)
        now = time.monotonic()
        envs = [Envelope(base + i, stamp + i, now, p)
                for i, p in enumerate(payloads[:n])]
        S = len(self.shards)
        for s in range(S):
            group = envs[(s - base) % S::S] if S > 1 else envs
            if group:
                self.shards.queues[s].enqueue_many(group)
        act = self._active
        if act is not None:
            act.mark(self.name)
        self.stats.add_submitted(n)
        if len(payloads) > n:
            self.stats.add_rejected(len(payloads) - n)
        rec = self._obs
        if rec is not None and rec.every:
            # trace only the sampled seqs in [base, base+n): the batched
            # path stays O(batch/every), not O(batch)
            for seq in range(base + (-base) % rec.every, base + n, rec.every):
                self._trace_submit(rec, seq, now)
        return envs + [None] * (len(payloads) - n)

    # ---------------------------------------------------------------- drain
    def requeue(self, env: Envelope) -> None:
        """Return a previously-delivered envelope (preemption, admission
        park) to the class. It re-enters at its *original* cycle position:
        the requeue heap is served before the frontier, ordered by seq."""
        heapq.heappush(self._requeue, env)
        act = self._active
        if act is not None:
            act.mark(self.name)
        self.stats.requeued += 1
        rec = self._obs
        if rec is not None and rec.sampled(env.seq):
            rec.emit("requeue", self.name, env.seq)

    def _stage_from_shards(self, want: int) -> int:
        """Claim up to ``want`` envelopes from every shard into the staging
        map. A steal (migration) between shards is invisible here: staging
        keys by seq, delivery is by frontier, placement does not matter."""
        got = 0
        rec = self._obs
        for q in self.shards.queues:
            for env in q.dequeue_many(want):
                self._stage[env.seq] = env
                got += 1
                if rec is not None and rec.sampled(env.seq):
                    rec.emit("drain", self.name, env.seq)
        return got

    def drain(self, k: int) -> List[Envelope]:
        """Deliver up to ``k`` envelopes in exact class-cycle order.

        Single-caller (the scheduler loop). Requeued (preempted) items first
        — their cycles predate the frontier — then frontier items, claimed
        from the shards and re-merged by the dense seq stamps. Never delivers
        past a gap: a missing seq means a producer is mid-submit, so we spin
        briefly and otherwise return short (strict FIFO is preserved, the
        gap's class alone waits)."""
        out: List[Envelope] = []
        while self._requeue and len(out) < k:
            out.append(heapq.heappop(self._requeue))
        spins = 0
        rec = self._obs
        while len(out) < k:
            while len(out) < k and self._frontier in self._stage:
                env = self._stage.pop(self._frontier)
                self._frontier += 1
                if self.admit_window is not None:
                    self._inflight.fetch_add(-1)  # window seat freed
                self.stats.record_delivery(env)
                if rec is not None and rec.sampled(env.seq):
                    rec.emit("seat", self.name, env.seq)
                out.append(env)
                spins = 0
            if len(out) >= k:
                break
            if self._frontier >= self._seq.load():
                break  # nothing submitted beyond the frontier
            if self._stage_from_shards(k - len(out)) == 0:
                # Frontier item stamped but not yet spliced: bounded wait.
                spins += 1
                if spins > _GAP_PATIENCE:
                    self.stats.gap_waits += 1
                    break
                cpu_pause()
        self.stats.delivered += len(out)
        return out

    def drain_block(self, k: int) -> List[Envelope]:
        """Bulk drain with the same delivery contract as :meth:`drain`, used
        by the device-admission feeder (DESIGN.md §12). When the fast shape
        applies — single shard, no requeues, nothing staged, and the claimed
        run is seq-contiguous from the frontier — the per-item frontier and
        stage bookkeeping collapses to O(1) per batch: one vectorized shard
        claim, one frontier advance, one batched window-seat release, one
        clock read. Any other shape routes through the exact per-item
        :meth:`drain` (out-of-order runs are staged first, so nothing is
        lost or reordered)."""
        if self._requeue or self._stage or len(self.shards) != 1:
            return self.drain(k)
        envs = self.shards.queues[0].dequeue_many(k)
        if not envs:
            return []  # nothing claimable (or a producer mid-splice): retry next pull
        base = self._frontier
        n = len(envs)
        if [e.seq for e in envs] != list(range(base, base + n)):
            # Producers spliced out of seq order: merge the slow, exact way.
            for e in envs:
                self._stage[e.seq] = e
            return self.drain(k)
        self._frontier = base + n
        if self.admit_window is not None:
            self._inflight.fetch_add(-n)  # one batched seat release
        self.stats.record_delivery_many(envs)
        self.stats.delivered += n
        rec = self._obs
        if rec is not None and rec.every:
            now = time.monotonic()
            for seq in range(base + (-base) % rec.every, base + n, rec.every):
                rec.emit("drain", self.name, seq, t=now)
                rec.emit("seat", self.name, seq, t=now)
        return envs

    # ---------------------------------------------------------- checkpoint
    def _capture_pending(self) -> int:
        """Claim every spliced-but-undelivered envelope into the staging map
        (delivery order is unaffected: the drain already serves the stage).
        Returns the number of seats in [frontier, seq) that could *not* be
        captured — nonzero only when a producer is mid-submit, the same
        head-of-line contract as `drain`."""
        spins = 0
        while True:
            missing = (self._seq.load() - self._frontier) - len(self._stage)
            if missing <= 0:
                return 0
            if self._stage_from_shards(missing) == 0:
                spins += 1
                if spins > _GAP_PATIENCE:
                    return missing
                cpu_pause()
            else:
                spins = 0

    def _meta_state(self) -> dict:
        """The class-identity + cycle-counter half of a snapshot, shared by
        the single-drain and replica codecs. ``queue_kw`` (the shards'
        CMPQueue configuration — window, reclaim cadence, …) is captured so
        a restore rebuilds the *same* protection behavior, not a guessed
        one. ``deque_cycles`` (the shards' protection boundaries) rides
        along as *diagnostics only*: restore rebuilds fresh shards and
        re-enqueues the captured envelopes, so queue-internal counters
        restart at zero by design."""
        return {
            "name": self.name,
            "priority": self.priority,
            "weight": self.weight,
            "admit_window": self.admit_window,
            "num_shards": len(self.shards),
            "queue_kw": dict(self._queue_kw),
            "seq": self._seq.load(),
            "deque_cycles": [q.deque_cycle.load() for q in self.shards.queues],
        }

    @classmethod
    def _from_meta(cls, state: dict, **queue_kw) -> "QueueClass":
        """Rebuild the class identity; shard CMPQueue config comes from the
        snapshot, with caller kwargs as explicit overrides."""
        merged = {**state.get("queue_kw", {}), **queue_kw}
        qc = cls(state["name"], priority=state["priority"],
                 weight=state["weight"], num_shards=state["num_shards"],
                 admit_window=state["admit_window"], **merged)
        qc._seq.store(state["seq"])
        return qc

    def state(self, *, encode=None) -> dict:
        """Exact-seat frontier snapshot: ``(class seq, frontier, requeue
        heap, staged pending, per-shard deque_cycle)``. Every undelivered
        envelope is captured (claimed into the stage first), so a restored
        class resumes each tenant at its exact FIFO seat. The returned dict
        is plain data — safe to hand to an async checkpoint writer while
        this class keeps draining. Exact when producers are quiesced (a
        producer mid-submit is reported in ``gaps``, and its item — not yet
        spliced anywhere — cannot be captured by anyone).

        ``encode`` maps payloads to JSON-able values (default: identity).
        """
        gaps = self._capture_pending()
        return {
            **self._meta_state(),
            "frontier": self._frontier,
            "gaps": gaps,
            "requeue": encode_envelopes(self._requeue, encode),
            "stage": encode_envelopes(self._stage.values(), encode),
        }

    @classmethod
    def from_state(cls, state: dict, *, decode=None, **queue_kw) -> "QueueClass":
        """Rebuild a class at its checkpointed seats: the cycle counter,
        drain frontier and every undelivered envelope resume exactly where
        `state` captured them (staged items re-enter their home shard
        ``seq % S``; requeued seats are served first, as before)."""
        qc = cls._from_meta(state, **queue_kw)
        qc._frontier = state["frontier"]
        if qc.admit_window is not None:
            # window seats are freed at first delivery; everything in
            # [frontier, seq) is still occupying one
            qc._inflight.store(max(0, state["seq"] - state["frontier"]))
        now = time.monotonic()
        for rec in state["requeue"]:
            heapq.heappush(qc._requeue, decode_envelope(rec, decode, now=now))
        for rec in state["stage"]:
            env = decode_envelope(rec, decode, now=now)
            qc.shards.queues[env.seq % len(qc.shards)].enqueue(env)
        return qc

    # ------------------------------------------------------------ telemetry
    def snapshot(self) -> dict:
        return self.stats.snapshot(pending=self.pending(),
                                   shard_depths=self.shards.depths())


class Scheduler:
    """The class fabric: named classes + a drain policy + the global arrival
    stamp that FIFO-across-classes merges on."""

    def __init__(self, classes: Sequence[QueueClass], policy="strict"):
        from repro.sched.policy import make_policy
        assert classes, "scheduler needs at least one class"
        self.classes: List[QueueClass] = list(classes)
        self.by_name: Dict[str, QueueClass] = {c.name: c for c in self.classes}
        assert len(self.by_name) == len(self.classes), "duplicate class names"
        self.policy = make_policy(policy)
        self._stamp = AtomicCell(0)  # fabric-global arrival cycle
        # O(active) index (sched/tenants.py), None unless a tenant fabric
        # enables it: with it set, drain/pending/snapshot walk only the
        # classes that currently hold work instead of the whole grid.
        self.active = None

    @property
    def default_class(self) -> str:
        return self.classes[0].name

    def enable_active_tracking(self):
        """Switch drain/pending/snapshot to O(active classes).

        Attached post-construction (like the obs recorder) so none of the
        construction paths — direct, from_state, replica rebuild — need
        threading a flag. All classes start marked; the first drain sweep
        retires the idle ones, after which only classes with queued work
        are ever touched."""
        if self.active is None:
            from repro.sched.tenants import ActiveSet
            self.active = ActiveSet()
            for qc in self.classes:
                qc._active = self.active
                self.active.mark(qc.name)
        return self.active

    def submit(self, qclass: str, payload: Any) -> Optional[Envelope]:
        return self.by_name[qclass].submit(payload,
                                           stamp=self._stamp.fetch_add(1))

    def submit_many(self, qclass: str, payloads: Sequence[Any]
                    ) -> List[Optional[Envelope]]:
        qc = self.by_name[qclass]
        return qc.submit_many(payloads,
                              stamp=self._stamp.fetch_add(len(payloads)))

    def drain(self, k: int) -> List[Tuple[QueueClass, Envelope]]:
        """One admission batch: the policy composes per-class drains.
        With active tracking on, only classes holding work are offered to
        the policy, and classes observed empty afterwards leave the
        active set (a racing producer re-marks them post-enqueue)."""
        act = self.active
        if act is None:
            return self.policy.drain(self.classes, k)
        offered = [self.by_name[n] for n in act.names()]
        out = self.policy.drain(offered, k)
        for qc in offered:
            if qc.pending() == 0:
                act.discard(qc.name)
        return out

    def drain_bulk(self, k: int) -> List[Tuple[QueueClass, Envelope]]:
        """Bulk admission drain for the device-admission feeder (DESIGN.md
        §12): a single-class fabric has nothing to interleave, so the policy
        merge is skipped in favor of the class's vectorized block drain; any
        other shape (multi-class, policy-held heads) takes the normal
        policy-composed drain."""
        if len(self.classes) == 1 and self.policy.held() == 0:
            qc = self.classes[0]
            return [(qc, env) for env in qc.drain_block(k)]
        return self.drain(k)

    def pending(self) -> int:
        act = self.active
        if act is not None:
            # inactive => pending 0 by the mark-after-enqueue invariant
            return (sum(self.by_name[n].pending() for n in act.names())
                    + self.policy.held())
        return sum(c.pending() for c in self.classes) + self.policy.held()

    def snapshot(self, *, active_only: bool = False) -> dict:
        if active_only and self.active is not None:
            return {n: self.by_name[n].snapshot()
                    for n in self.active.names()}
        return {c.name: c.snapshot() for c in self.classes}
