"""Pluggable seat-protocol transport: host-addressed ownership over one
class fabric (DESIGN.md §11).

PR 3 made seat ownership a CAS-published cell and observed that the
exact-seat frontier snapshot *is* the whole cross-host protocol: a steal is
one ownership claim, a drain is a gather of staged envelopes, and the
checkpoint encoding (``[seq, stamp, payload]`` records + per-seat cursors)
is already the wire format. This module cashes that observation in. Seat
owners become **host-addressed** — :class:`HostAddr` ``(host, rid)`` instead
of a bare replica index — and every cross-owner operation of the replica
layer goes through a :class:`Transport`:

  * ``fetch``      — gather staged envelopes from a shard (the drain claim);
  * ``publish``    — republish envelopes into their home shard (the
    steal-victim / resize / recovery move);
  * ``claim_seat`` — the one ownership-claim RPC that a steal is.

Two transports ship:

  * :class:`LocalTransport` — one host, in-process, zero-copy. Exactly
    today's behavior: every call degenerates to the direct ``dequeue_many``
    / ``enqueue_many`` / owner-CAS it replaced, no serialization anywhere.
  * :class:`SimHostTransport` — N simulated hosts in one process. Replicas
    and shard queues are partitioned round-robin across hosts
    (``host_of(rid) = rid % H``, ``shard_home(s) = s % H``, so the default
    seat layout is *home-aligned*: cross-host messages are exactly the
    coordination-free operations — steals, republishes, recovery). Every
    cross-host envelope is serialized through the wire codec (a JSON round
    trip of the checkpoint record format) and the chaos knobs inject
    message **drop** (a lost request: fetch returns empty, a claim fails —
    both retried by the caller's next round, no state consumed), **delay**
    (claimed envelopes park in an in-flight buffer and arrive on a later
    fetch) and **reorder** (a fetched batch is shuffled — order-safe by
    construction, because the seat cursor, not arrival order, drives
    delivery). ``fail_host`` kills a host's drain loops mid-run.

Why drops can never lose an item: chaos is only ever applied *before* state
changes hands (a dropped fetch claims nothing; a dropped claim CASes
nothing) or to messages that are retried-until-acked (``publish`` counts a
retransmit instead of dropping — a republish carries claimed envelopes, so
at-least-once delivery with an idempotent apply is the only sound model).
Delayed envelopes live in the transport's in-flight buffer and are flushed
back into their home shards by :meth:`Transport.quiesce` (checkpoints) and
:meth:`Transport.fail_host` (recovery), so the exact-seat acceptance holds
under any chaos setting.
"""

from __future__ import annotations

import json
import random
import threading
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

from repro.sched.classes import (Envelope, decode_envelope, encode_envelopes)


class HostAddr(NamedTuple):
    """A host-addressed seat owner: which simulated host, which replica.

    ``rid`` stays globally unique (the index into ``ReplicaSet.replicas``);
    ``host`` is where that replica's drain loop runs. The pair is what the
    seat cell CAS-publishes — equality-comparable, JSON-encodable as
    ``[host, rid]``, and exactly the granularity a cross-host steal claims.
    """

    host: int
    rid: int

    def __repr__(self) -> str:  # telemetry-friendly
        return f"h{self.host}r{self.rid}"


def decode_owner(rec) -> Tuple[int, int]:
    """Wire/JSON -> ``(host, rid)``. Accepts the PR-3/4 legacy format (a
    bare replica index, implicitly single-host) so pre-transport frontier
    snapshots restore under any transport."""
    if isinstance(rec, int):
        return (0, rec)
    host, rid = rec
    return (int(host), int(rid))


def wire_encode(envs: List[Envelope], encode=None) -> str:
    """Envelope batch -> wire bytes: a JSON array of the checkpoint record
    format ``[seq, stamp, payload]`` (DESIGN.md §9 — the frontier snapshot
    encoding IS the wire format; sharing :func:`encode_envelopes` makes
    that a fact, not a convention)."""
    return json.dumps(encode_envelopes(envs, encode))


def wire_decode(blob: str, decode=None, *,
                t_submit: Optional[List[float]] = None) -> List[Envelope]:
    """Wire bytes -> envelopes. ``t_submit`` (optional, parallel to the
    records) preserves the originals' submit stamps so a same-process hop
    does not fake the admission-latency telemetry."""
    recs = json.loads(blob)
    out = []
    for i, rec in enumerate(recs):
        now = t_submit[i] if t_submit is not None else None
        out.append(decode_envelope(rec, decode, now=now))
    return out


class Transport:
    """The seat-protocol message layer (ABC).

    A transport is bound once to a fabric (``bind``) and then mediates the
    three cross-owner operations of the replica layer. Implementations
    decide what "cross-host" means; callers never branch on it — the
    replica/steal/fabric code is transport-agnostic.
    """

    kind = "abstract"
    num_hosts = 1
    _encode = None  # payload -> JSON-able (wire/codec hook)
    _decode = None  # JSON-able -> payload
    # metrics-plane attachment (repro.obs.MetricsHub): when set, remote
    # operations report their round-trip time via ``_obs.record_rtt``
    _obs = None

    def bind(self, scheduler, seats: Dict[str, List]) -> None:
        """Attach to the fabric state (class queues + seat cells)."""
        self._sched = scheduler
        self._seats = seats

    # ---- addressing -------------------------------------------------------
    def host_of(self, rid: int) -> int:
        raise NotImplementedError

    def addr_of(self, rid: int) -> HostAddr:
        return HostAddr(self.host_of(rid), int(rid))

    def alive(self, host: int) -> bool:
        return True

    def live_hosts(self) -> List[int]:
        return [h for h in range(self.num_hosts) if self.alive(h)]

    # ---- the three seat-protocol operations -------------------------------
    def fetch(self, cls_name: str, shard: int, k: int,
              addr: HostAddr) -> List[Envelope]:
        """Gather up to ``k`` staged envelopes from one shard (the drain
        claim). May return short or empty under chaos — the caller's drain
        loop already retries, so a lost request costs latency, never
        items."""
        raise NotImplementedError

    def publish(self, cls_name: str, shard: int, envs: List[Envelope],
                addr: HostAddr) -> int:
        """Republish envelopes into their home shard (steal-victim /
        resize / recovery move). Reliable: retried-until-acked, because a
        republish carries already-claimed envelopes."""
        raise NotImplementedError

    def claim_seat(self, cls_name: str, shard: int, addr: HostAddr) -> bool:
        """The ownership-claim RPC a steal is: one CAS on the seat cell.
        False when the CAS lost a race, the claimant already owns the seat,
        or chaos dropped the request — all retried next round."""
        raise NotImplementedError

    def reseat(self, assignments, *, expect_host: Optional[int] = None
               ) -> int:
        """Apply a batch of seat reassignments — the control-plane move
        that resize / recovery / restore make, distinct from a steal's
        single racing claim. ``assignments`` is an iterable of
        ``(cls_name, shard, HostAddr)``; with ``expect_host`` set, a seat
        is only moved while its current owner lives on that host (the
        conditional recovery sweep — a racing steal wins). Returns the
        number of seats actually moved.

        The default is the direct CAS loop over the bound seat cells that
        the in-process transports share; distributed transports override
        it to coalesce each destination host's slice into one batched
        claim frame."""
        moved = 0
        for cls_name, shard, target in assignments:
            seat = self._seats[cls_name][shard]
            cur = seat.owner.load()
            while True:
                if cur == target:
                    break
                if expect_host is not None and cur.host != expect_host:
                    break  # a concurrent steal already moved this seat
                if seat.owner.cas(cur, target):
                    moved += 1
                    break
                cur = seat.owner.load()
        return moved

    # ---- lifecycle --------------------------------------------------------
    def quiesce(self) -> int:
        """Flush any in-flight (delayed) envelopes back into their home
        shards so a step-boundary checkpoint captures every seat. Returns
        the number flushed."""
        return 0

    def fail_host(self, host: int) -> int:
        """Mark a host dead and flush its in-flight envelopes back into the
        fabric. Data-plane only — seat reassignment is the ReplicaSet's
        recovery move (:meth:`repro.sched.ReplicaSet.fail_host`)."""
        raise NotImplementedError(f"{self.kind} transport cannot fail hosts")

    def add_host(self) -> int:
        """Grow the host fleet by one; returns the new host count.
        Data-plane only — replicas spread onto the new host at the next
        reseat (``ReplicaSet.resize`` recomputes ``addr_of(rid)``)."""
        raise NotImplementedError(
            f"{self.kind} transport cannot add hosts (single-host by "
            f"definition — use transport='sim')")

    def stats(self) -> dict:
        raise NotImplementedError

    def spec(self) -> dict:
        """JSON-able description (rides frontier snapshots as metadata)."""
        return {"kind": self.kind, "hosts": self.num_hosts}


class LocalTransport(Transport):
    """One host, in-process, zero-copy — today's behavior, now behind the
    transport seam. Every operation is the direct call it replaced; the
    only bookkeeping is a pair of counters so ``stats()`` stays uniform."""

    kind = "local"
    num_hosts = 1

    def __init__(self):
        self._lock = threading.Lock()
        self.fetches = 0
        self.publishes = 0

    def host_of(self, rid: int) -> int:
        return 0

    def fetch(self, cls_name, shard, k, addr):
        # Hot-path counter: plain += on purpose — approximate under
        # concurrent drains, exact when quiesced (the repo's telemetry
        # contract, see sched/stats.py); a lock here would serialize every
        # frontier probe of every replica.
        self.fetches += 1
        return self._sched.by_name[cls_name].shards.queues[shard].dequeue_many(k)

    def publish(self, cls_name, shard, envs, addr):
        if envs:
            with self._lock:
                self.publishes += 1
            self._sched.by_name[cls_name].shards.queues[shard].enqueue_many(
                list(envs))
        return len(envs)

    def claim_seat(self, cls_name, shard, addr):
        from repro.sched.steal import claim_seat
        return claim_seat(self._seats[cls_name][shard], addr)

    def stats(self) -> dict:
        return {"kind": self.kind, "hosts": 1, "dead_hosts": [],
                "fetches": self.fetches, "publishes": self.publishes,
                "remote_msgs": 0, "remote_bytes": 0, "drops": 0,
                "delayed": 0, "reordered": 0, "retransmits": 0,
                "remote_claims": 0}


class SimHostTransport(Transport):
    """N simulated hosts over one in-process fabric, with a serialized wire
    and injectable chaos (see module docstring for the loss model).

    The CMP shard queues are the durable substrate: host loss kills drain
    loops and their staged claims, not enqueued items — in deployment the
    lost host's latest frontier snapshot (byte-identical to these wire
    records) is replayed by the recovering owners, which is exactly what
    :meth:`repro.sched.ReplicaSet.fail_host` does through this codec.
    """

    kind = "sim"

    def __init__(self, num_hosts: int, *, drop: float = 0.0,
                 reorder: bool = False, delay: float = 0.0, seed: int = 0,
                 rtt: float = 0.0, encode=None, decode=None):
        assert num_hosts >= 1
        assert 0.0 <= drop < 1.0, f"drop={drop} must be in [0, 1)"
        assert 0.0 <= delay < 1.0, f"delay={delay} must be in [0, 1)"
        assert rtt >= 0.0, f"rtt={rtt} must be >= 0"
        self.num_hosts = int(num_hosts)
        self.drop = float(drop)
        self.delay = float(delay)
        # Deterministic injected round-trip time (seconds) charged to every
        # seat-protocol op — fetch, publish, claim — modelling a driver
        # that is network-separated from the whole host fleet (the wire
        # transport's topology, where even a home-shard op crosses a
        # socket). rtt=0 (the default) is exactly the pre-knob behavior;
        # rtt>0 is the wire bench's sim-at-RTT baseline.
        self.rtt = float(rtt)
        self.reorder = bool(reorder)
        self._encode = encode
        self._decode = decode
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._dead: set = set()
        # claimed-but-delayed envelopes, keyed by (class, shard): they were
        # dequeued from the fabric and are in flight on the wire — flushed
        # by quiesce()/fail_host() so checkpoints and recovery see them
        self._inflight: Dict[Tuple[str, int], List[Envelope]] = {}
        self.remote_msgs = 0
        self.remote_bytes = 0
        self.local_fetches = 0
        self.publishes = 0
        self.drops = 0
        self.delayed = 0
        self.reordered = 0
        self.retransmits = 0
        self.remote_claims = 0

    # ---- addressing -------------------------------------------------------
    def host_of(self, rid: int) -> int:
        return int(rid) % self.num_hosts

    def shard_home(self, shard: int) -> int:
        return int(shard) % self.num_hosts

    def alive(self, host: int) -> bool:
        return host not in self._dead

    # ---- chaos + wire -----------------------------------------------------
    def _rtt(self, addr, t0: float) -> None:
        """Report one remote operation's round-trip time to the attached
        metrics hub (no-op until a MetricsHub attaches)."""
        if self._obs is not None:
            self._obs.record_rtt(addr.host, time.perf_counter() - t0)

    def _roll(self, p: float) -> bool:
        if p <= 0.0:
            return False
        with self._lock:
            return self._rng.random() < p

    def _pay_rtt(self) -> None:
        """Charge one injected round trip (no-op at the rtt=0 default)."""
        if self.rtt > 0.0:
            time.sleep(self.rtt)

    def _wire(self, envs: List[Envelope]) -> List[Envelope]:
        """One serialized hop: encode -> bytes -> decode. The originals'
        ``t_submit`` stamps ride along (same process, same monotonic clock)
        so admission-latency telemetry stays honest."""
        if not envs:
            return envs
        blob = wire_encode(envs, self._encode)
        with self._lock:
            self.remote_msgs += 1
            self.remote_bytes += len(blob)
        stamps = [e.t_submit for e in sorted(envs)]
        return wire_decode(blob, self._decode, t_submit=stamps)

    # ---- seat-protocol operations -----------------------------------------
    def fetch(self, cls_name, shard, k, addr):
        if addr.host in self._dead:
            return []  # a dead host's loops make no RPCs
        self._pay_rtt()
        q = self._sched.by_name[cls_name].shards.queues[shard]
        if self.shard_home(shard) == addr.host:
            # Home-host fetch: zero-copy, lock-free (the counter is the
            # approximate-when-racing hot-path kind) — except to reclaim
            # anything a previous remote owner left parked in flight for
            # this shard: a stolen-back seat must never strand delayed
            # envelopes. The unlocked peek is safe: entries are only added
            # under the lock, and a racy miss is reclaimed next fetch.
            self.local_fetches += 1
            parked: List[Envelope] = []
            if self._inflight:
                with self._lock:
                    parked = self._inflight.pop((cls_name, shard), [])
            return parked + q.dequeue_many(k)
        # remote: the request can be lost BEFORE anything is claimed
        t0 = time.perf_counter()
        if self._roll(self.drop):
            with self._lock:
                self.drops += 1
            self._rtt(addr, t0)
            return []
        with self._lock:
            parked = self._inflight.pop((cls_name, shard), [])
        fresh = q.dequeue_many(k)
        if fresh and self._roll(self.delay):
            # claimed but in flight: arrives on a later fetch (or a
            # quiesce/recovery flush) — never lost
            with self._lock:
                self.delayed += len(fresh)
                self._inflight.setdefault((cls_name, shard), []).extend(fresh)
            fresh = []
        out = self._wire(parked + fresh)
        if self.reorder and len(out) > 1:
            with self._lock:
                self._rng.shuffle(out)
                self.reordered += 1
        self._rtt(addr, t0)
        return out

    def publish(self, cls_name, shard, envs, addr):
        if not envs:
            return 0
        self._pay_rtt()
        envs = list(envs)
        remote = self.shard_home(shard) != addr.host
        t0 = time.perf_counter()
        if remote:
            if self._roll(self.drop):
                with self._lock:
                    self.retransmits += 1  # republish is retried-until-acked
            envs = self._wire(envs)
        with self._lock:
            self.publishes += 1
        self._sched.by_name[cls_name].shards.queues[shard].enqueue_many(envs)
        if remote:
            self._rtt(addr, t0)
        return len(envs)

    def claim_seat(self, cls_name, shard, addr):
        self._pay_rtt()
        seat = self._seats[cls_name][shard]
        remote = self.shard_home(shard) != addr.host
        t0 = time.perf_counter()
        if remote:
            with self._lock:
                self.remote_claims += 1
                self.remote_msgs += 1
                self.remote_bytes += 32  # fixed-size claim frame
            if self._roll(self.drop):
                with self._lock:
                    self.drops += 1
                self._rtt(addr, t0)
                return False
        from repro.sched.steal import claim_seat
        ok = claim_seat(seat, addr)
        if remote:
            self._rtt(addr, t0)
        return ok

    # ---- lifecycle --------------------------------------------------------
    def _flush_inflight(self, keys=None) -> int:
        with self._lock:
            if keys is None:
                keys = list(self._inflight)
            flushed = {k: self._inflight.pop(k) for k in keys
                       if k in self._inflight}
        n = 0
        for (cls_name, shard), envs in flushed.items():
            self._sched.by_name[cls_name].shards.queues[shard].enqueue_many(
                envs)
            n += len(envs)
        return n

    def quiesce(self) -> int:
        return self._flush_inflight()

    def fail_host(self, host: int) -> int:
        assert 0 <= host < self.num_hosts
        live = [h for h in self.live_hosts() if h != host]
        assert live, "cannot fail the last live host"
        self._dead.add(host)
        # everything in flight is flushed back into the fabric: in-flight
        # envelopes are addressed to shards, not hosts, so none are lost
        return self._flush_inflight()

    def add_host(self) -> int:
        # Flush first: ``host_of``/``shard_home`` are modular in num_hosts,
        # so parked envelopes keyed under the old modulus must land in
        # their shards before the mapping shifts.
        self._flush_inflight()
        self.num_hosts += 1
        return self.num_hosts

    def stats(self) -> dict:
        return {"kind": self.kind, "hosts": self.num_hosts,
                "dead_hosts": sorted(self._dead),
                "fetches": self.local_fetches, "publishes": self.publishes,
                "remote_msgs": self.remote_msgs,
                "remote_bytes": self.remote_bytes,
                "drops": self.drops, "delayed": self.delayed,
                "reordered": self.reordered,
                "retransmits": self.retransmits,
                "remote_claims": self.remote_claims}

    def spec(self) -> dict:
        return {"kind": self.kind, "hosts": self.num_hosts,
                "drop": self.drop, "delay": self.delay,
                "reorder": self.reorder, "rtt_ms": self.rtt * 1e3}


def make_transport(kind: str, hosts: int = 1, *, drop: float = 0.0,
                   reorder: bool = False, delay: float = 0.0, seed: int = 0,
                   rtt_ms: float = 0.0, credit: int = 4,
                   encode=None, decode=None) -> Transport:
    """``"local"`` | ``"sim"`` | ``"wire"`` -> a transport instance (the
    FabricConfig / serve.py entry point)."""
    if kind == "local":
        assert hosts == 1, "local transport is single-host; use kind='sim'"
        return LocalTransport()
    if kind == "sim":
        return SimHostTransport(hosts, drop=drop, reorder=reorder,
                                delay=delay, seed=seed, rtt=rtt_ms / 1e3,
                                encode=encode, decode=decode)
    if kind == "wire":
        assert not reorder, ("wire transport cannot reorder: TCP delivers "
                             "per-connection in order; use kind='sim'")
        from repro.net.wire import WireTransport  # lazy: avoids a cycle
        return WireTransport(hosts, drop=drop, delay=delay, rtt_ms=rtt_ms,
                             credit=credit, seed=seed, encode=encode,
                             decode=decode)
    raise ValueError(f"unknown transport kind {kind!r}; "
                     f"choose from ['local', 'sim', 'wire']")
