"""Sharded checkpointing with async write-behind on a CMP-windowed buffer pool.

* Leaves are written as .npy shards + a manifest (treedef, shapes, dtypes,
  sha256 per shard) — torn writes are detected, saves are atomic (tmp dir +
  rename), and ``latest`` moves only after a complete save.
* ``AsyncCheckpointer`` snapshots to host and hands off to a writer thread
  through a bounded cyclic pool: if the writer stalls (slow blob store — the
  'stalled thread' of the paper), at most W snapshots are retained and the
  *training loop is never blocked*; excess snapshots are dropped oldest-first
  (bounded reclamation instead of unbounded retention).
* Restore accepts target shardings -> elastic re-mesh: a checkpoint written
  on one mesh restores onto any other mesh shape.
"""

from __future__ import annotations

import hashlib
import json
import os
import queue as pyqueue
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _tree_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        out.append(("/".join(parts), leaf))
    return out


def save(ckpt_dir: str, step: int, state: Dict[str, Any],
         aux: Optional[Dict[str, Any]] = None) -> str:
    """state: arbitrary pytree dict (params, opt_state, data_state, ...).

    ``aux`` is an optional JSON-able side-channel saved atomically with the
    same step — scheduler frontier snapshots (``QueueClass.state()`` /
    ``ReplicaSet.state()``), data-pipeline cursors, uid counters: the
    exact-seat resume state that is *structure*, not arrays. It rides the
    same tmp-dir + rename, so a step either has both its leaves and its
    frontiers or neither."""
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    if aux is not None:
        with open(os.path.join(tmp, "aux.json"), "w") as f:
            json.dump(aux, f)

    manifest = {"step": step, "leaves": []}
    host_state = jax.device_get(state)
    for i, (path, leaf) in enumerate(_tree_paths(host_state)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        with open(os.path.join(tmp, fname), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"].append({
            "path": path, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "sha256": digest,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "latest.tmp"),
               os.path.join(ckpt_dir, "latest"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore_aux(ckpt_dir: str, step: Optional[int] = None
                ) -> Tuple[int, Optional[Dict[str, Any]]]:
    """Load the aux (frontier) side-channel of a checkpoint; None when the
    step was saved without one."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    p = os.path.join(ckpt_dir, f"step_{step}", "aux.json")
    if not os.path.exists(p):
        return step, None
    with open(p) as f:
        return step, json.load(f)


def restore(ckpt_dir: str, template: Dict[str, Any], step: Optional[int] = None,
            shardings: Any = None, verify: bool = True) -> Tuple[int, Dict[str, Any]]:
    """Restore into the structure of ``template``; optional pytree of
    shardings (prefix — params-only is fine) re-lays-out onto a new mesh."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = jax.tree_util.tree_flatten(template)
    leaves = manifest["leaves"]
    assert len(leaves) == len(flat_t), (
        f"checkpoint has {len(leaves)} leaves, template {len(flat_t)}")
    out = []
    for rec in leaves:
        fp = os.path.join(d, rec["file"])
        if verify:
            with open(fp, "rb") as f:
                if hashlib.sha256(f.read()).hexdigest() != rec["sha256"]:
                    raise IOError(f"integrity failure in {fp} ({rec['path']})")
        out.append(np.load(fp))
    state = treedef.unflatten(out)
    if shardings is not None:
        state = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            state, shardings,
            is_leaf=lambda x: isinstance(x, np.ndarray))
    return step, state


class AsyncCheckpointer:
    """Write-behind checkpointing with CMP-bounded snapshot retention."""

    def __init__(self, ckpt_dir: str, window: int = 2):
        os.makedirs(ckpt_dir, exist_ok=True)
        self.ckpt_dir = ckpt_dir
        self.window = window
        self._q: pyqueue.Queue = pyqueue.Queue()
        self._pending = 0
        self._lock = threading.Lock()
        self.dropped = 0
        self.written = []
        self._writer = threading.Thread(target=self._run, daemon=True)
        self._writer.start()

    def submit(self, step: int, state: Dict[str, Any],
               aux: Optional[Dict[str, Any]] = None) -> bool:
        """Never blocks. Returns False if dropped (writer lag > window).

        ``aux`` (frontier snapshots etc.) is deep-copied through JSON at
        submit time, so the caller's live scheduler state may keep mutating
        while the writer drains — the async part is only the file I/O."""
        if aux is not None:
            # Deep-copy (and fail on non-JSON-able aux) BEFORE reserving a
            # window slot — a raise here must not leak the reservation.
            aux = json.loads(json.dumps(aux))
        with self._lock:
            if self._pending >= self.window:
                self.dropped += 1
                return False
            self._pending += 1
        try:
            snapshot = jax.device_get(state)  # host copy: buffers reusable
            self._q.put((step, snapshot, aux))
        except BaseException:
            with self._lock:
                self._pending -= 1
            raise
        return True

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, snapshot, aux = item
            try:
                save(self.ckpt_dir, step, snapshot, aux=aux)
                self.written.append(step)
            finally:
                with self._lock:
                    self._pending -= 1

    def drain(self, timeout: float = 60.0) -> None:
        import time
        t0 = time.time()
        while True:
            with self._lock:
                if self._pending == 0:
                    return
            if time.time() - t0 > timeout:
                raise TimeoutError("checkpoint writer did not drain")
            time.sleep(0.01)

    def close(self) -> None:
        self.drain()
        self._q.put(None)
