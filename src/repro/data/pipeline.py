"""Host data pipeline: producer threads -> CMP queue -> training batches.

This is the paper's queue in its natural production habitat (DESIGN.md §2):
multiple tokenizer/packer threads enqueue ready batches; the train loop
dequeues. The protection window bounds pipeline memory at W x batch_bytes and
a stalled producer can never block the consumer (nor vice versa) — the
coordination-free property the paper proves, applied to input pipelines.

Batch *content* is a pure function of (seed, batch_id): any batch can be
regenerated, so checkpointing the consumed-id frontier gives exact resume.

With ``num_shards > 1`` the single queue becomes a :class:`ShardSet` from the
scheduler fabric (DESIGN.md §8): producers shard by ``batch_id`` hash and the
consumer is a :class:`ShardConsumer` — home shard first, stealing from the
deepest sibling when the home runs dry (a steal is just a claim, so the
window-safety and no-loss properties are inherited unchanged).
"""

from __future__ import annotations

import threading
import time
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core.cmp import CMPQueue
from repro.sched.classes import ShardSet
from repro.sched.steal import ShardConsumer


def synth_batch(seed: int, batch_id: int, batch: int, seq: int, vocab: int) -> Dict:
    """Deterministic synthetic packed token batch (zipf-ish unigram docs with
    BOS-separated documents, mimicking packed pretraining sequences)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, batch_id]))
    # zipf-like unigram distribution over the vocab
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    tokens = rng.choice(vocab, size=(batch, seq + 1), p=probs).astype(np.int32)
    # sprinkle document boundaries (token 0 as BOS)
    doc_mask = rng.random((batch, seq + 1)) < (1.0 / 512)
    tokens[doc_mask] = 0
    return {"tokens": tokens, "batch_id": batch_id}


class DataPipeline:
    """num_producers threads generating batches into a CMPQueue.

    Producer p generates ids p, p+P, p+2P, ... starting from its cursor.
    ``state()``/restore give exact-resume cursors. A ``stall_producer`` hook
    simulates a straggler host (used by tests/benchmarks to demonstrate the
    window-bounded tolerance).
    """

    def __init__(self, batch: int, seq: int, vocab: int, *, seed: int = 0,
                 num_producers: int = 2, window: int = 64,
                 start_cursors: Optional[List[int]] = None,
                 max_queue_batches: int = 32, enqueue_batch: int = 4,
                 num_shards: int = 1):
        self.batch, self.seq, self.vocab, self.seed = batch, seq, vocab, seed
        self.num_producers = num_producers
        self.enqueue_batch = max(1, int(enqueue_batch))
        self.shards = ShardSet(num_shards, window=window, reclaim_period=16,
                               min_batch=2)
        self._consumer = ShardConsumer(self.shards, home=0)
        self._cursors = list(start_cursors) if start_cursors else list(range(num_producers))
        # Exact-resume frontier: per producer, the last id up to which
        # consumption is *contiguous*. Sharded delivery (stealing) can hand
        # the consumer ids out of order; ids ahead of the frontier wait in
        # _ooo until the gap closes, so resume can skip nothing (it may
        # regenerate a few already-consumed batches — the safe direction).
        self._frontier = dict((p, c - num_producers)
                              for p, c in enumerate(self._cursors))
        self._ooo: Dict[int, set] = {p: set() for p in range(num_producers)}
        self._stop = threading.Event()
        self._stalls: Dict[int, float] = {}
        self._max_q = max_queue_batches
        # _produced/_dequeued/_stalls/_cursors/_consumed are all guarded by
        # _lock: the backpressure check must not misread torn counter state
        # under free-threaded builds.
        self._produced = 0
        self._dequeued = 0
        self._lock = threading.Lock()
        self._threads = [
            threading.Thread(target=self._produce, args=(p,), daemon=True)
            for p in range(num_producers)
        ]
        self._started = False

    # -------------------------------------------------------------- producers
    def _produce(self, pid: int) -> None:
        while not self._stop.is_set():
            with self._lock:
                stall = self._stalls.pop(pid, None)
            if stall:
                time.sleep(stall)
            # Backpressure on *unconsumed depth* (produced - consumed), NOT
            # on live_nodes(): the CMP window retains ~W already-claimed
            # nodes, which must not count against producer throttle.
            with self._lock:
                depth = self._produced - self._dequeued
            if depth > self._max_q:
                time.sleep(0.0005)
                continue
            # Batched generation + one enqueue_many splice (DESIGN.md §3):
            # the cycle-range fetch-add and tail CAS amortize over the batch.
            n = min(self.enqueue_batch, max(1, self._max_q - depth + 1))
            with self._lock:
                bids = [self._cursors[pid] + j * self.num_producers
                        for j in range(n)]
                self._cursors[pid] = bids[-1] + self.num_producers
            # Shard by batch_id hash; one enqueue_many splice per shard hit.
            by_shard: Dict[int, List[Dict]] = {}
            for bid in bids:
                by_shard.setdefault(self.shards.shard_for(bid), []).append(
                    synth_batch(self.seed, bid, self.batch, self.seq,
                                self.vocab))
            for s, items in by_shard.items():
                self.shards.queues[s].enqueue_many(items)
            with self._lock:
                self._produced += n

    def stall_producer(self, pid: int, seconds: float) -> None:
        with self._lock:
            self._stalls[pid] = seconds

    # -------------------------------------------------------------- consumer
    def start(self) -> "DataPipeline":
        if not self._started:
            for t in self._threads:
                t.start()
            self._started = True
        return self

    @property
    def queue(self) -> CMPQueue:
        """Shard 0 (the whole queue when unsharded) — kept for diagnostics
        and backward compatibility."""
        return self.shards.queues[0]

    def __iter__(self) -> Iterator[Dict]:
        self.start()
        while not self._stop.is_set():
            got = self._consumer.take(1)  # home shard first, then steal
            if not got:
                time.sleep(0.0002)
                continue
            item = got[0]
            with self._lock:
                self._dequeued += 1
                bid = item["batch_id"]
                p = bid % self.num_producers
                self._ooo[p].add(bid)
                while self._frontier[p] + self.num_producers in self._ooo[p]:
                    self._frontier[p] += self.num_producers
                    self._ooo[p].discard(self._frontier[p])
            yield item

    def next_batch(self) -> Dict:
        return next(iter(self))

    # -------------------------------------------------------------- state
    def state(self) -> Dict:
        """Exact-resume frontier: next id each producer should generate is
        the last *contiguously* consumed id + P (regenerating any dropped or
        out-of-order in-flight batches, never skipping one)."""
        with self._lock:
            return {
                "cursors": [self._frontier[p] + self.num_producers
                            for p in range(self.num_producers)],
                "seed": self.seed,
            }

    @classmethod
    def from_state(cls, state: Dict, **kw) -> "DataPipeline":
        """Resume from `state()`. The producer count is implied by the
        cursor vector; a `num_producers` kwarg is deduped against it (an
        explicit mismatch is a config error, not a silent reshard — resharding
        producers would re-map every batch_id to a different producer)."""
        num_producers = kw.pop("num_producers", None)
        if num_producers is not None and num_producers != len(state["cursors"]):
            raise ValueError(
                f"from_state got num_producers={num_producers} but the "
                f"checkpoint has {len(state['cursors'])} producer cursors")
        pipe = cls(seed=state["seed"], start_cursors=state["cursors"],
                   num_producers=len(state["cursors"]), **kw)
        # Round-trip invariant: a freshly resumed pipeline checkpoints to
        # exactly the state it was built from.
        assert pipe.state() == {"cursors": list(state["cursors"]),
                                "seed": state["seed"]}, "resume round-trip"
        return pipe

    def steal_stats(self) -> Dict:
        """Consumer-side steal telemetry (zero added atomics)."""
        c = self._consumer
        return {"steals": c.steals, "stolen_items": c.stolen_items,
                "idle_polls": c.idle_polls,
                "shard_depths": self.shards.depths()}

    def close(self) -> None:
        self._stop.set()
