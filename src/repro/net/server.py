"""Per-host shard server for the wire transport (DESIGN.md §15).

One :class:`HostWorker` is one host of the fleet, run as a real OS process
(spawned by :class:`~repro.net.wire.WireTransport`). It owns the
**authoritative half** of the fabric for the shards homed on it
(``shard_home(s) = s % H``, same modular layout as ``SimHostTransport``):

  * the real :class:`~repro.core.cmp.CMPQueue` instances — the durable
    substrate; driver-side shard objects become mirrors (ShardProxy);
  * the **seat-owner table** for those shards — a claim is one serialized
    compare-and-swap here, exactly :func:`repro.sched.steal.claim_seat`'s
    semantics; the driver's seat cells become response-fed mirrors.

The failure model mirrors the sim transport's exactness argument
(module docstring of ``sched/transport.py``): chaos **drop** discards a
request *before* it is processed (a dropped fetch claims nothing, a dropped
claim CASes nothing; the client times the request out and its retry — a
later fetch round, a publish retransmit with the same request id — is the
recovery). Chaos **delay** parks freshly-claimed fetch batches in a
server-side in-flight buffer (claimed-but-on-the-wire); they surface on a
later fetch of the same shard or on a ``quiesce`` flush, so no setting of
the knobs can lose an item. Mutating retried ops (``publish``,
``shard_enq``, ``reseat``) are **deduplicated by request id**: a
retransmitted request whose original was applied returns the cached ack
without re-applying, which is what makes at-least-once delivery exact.

Injected RTT (``rtt_ms``) delays data-plane *responses* through a sender
queue, so pipelined requests overlap their round trips — the mechanism the
prefetch-credit client exploits.
"""

from __future__ import annotations

import json
import os
import random
import socket
import sys
import threading
import time
from collections import OrderedDict, deque
from typing import Dict, List, Tuple

from repro.core.cmp import CMPQueue
from repro.net.framing import KIND_RESP, FrameDecoder, pack_frame
from repro.sched.transport import wire_decode, wire_encode

# ops whose responses model a network round trip (and whose requests are
# subject to chaos): the three seat-protocol operations. Control-plane ops
# (reseat/quiesce/stats/...) and proxy ops are chaos-free, matching the
# sim transport's chaos-free quiesce/resize/checkpoint paths.
_DATA_OPS = ("fetch", "publish", "claim")
# mutating ops that clients retry with the same request id -> id-deduped
_RETRIED_OPS = ("publish", "shard_enq", "reseat")
_DEDUPE_CAP = 4096


class HostWorker:
    """Authoritative shard state + request handlers for one host."""

    def __init__(self, spec: dict):
        self.host = int(spec["host"])
        self.num_hosts = int(spec["num_hosts"])
        self.queues: Dict[Tuple[str, int], CMPQueue] = {}
        for c in spec["classes"]:
            kw = dict(c.get("queue_kw") or {})
            for s in range(int(c["num_shards"])):
                if s % self.num_hosts == self.host:
                    self.queues[(c["name"], s)] = CMPQueue(**kw)
        # seat-owner table for homed shards: (cls, shard) -> (host, rid)
        self.owners: Dict[Tuple[str, int], Tuple[int, int]] = {}
        for name, s, owner in spec.get("owners", []):
            self.owners[(name, int(s))] = (int(owner[0]), int(owner[1]))
        chaos = spec.get("chaos") or {}
        self.drop = float(chaos.get("drop", 0.0))
        self.delay = float(chaos.get("delay", 0.0))
        self.rtt_s = float(chaos.get("rtt_ms", 0.0)) / 1e3
        self._rng = random.Random(int(chaos.get("seed", 0)))
        self._lock = threading.RLock()
        # claimed-but-delayed fetch batches (the sim's _inflight, host-local)
        self._inflight: Dict[Tuple[str, int], List] = {}
        # request-id dedupe cache for retried mutations: id -> cached resp
        self._done: "OrderedDict[int, dict]" = OrderedDict()
        self.counters = {"drops": 0, "delayed": 0, "deduped": 0,
                         "fetches": 0, "publishes": 0, "claims": 0}

    # ------------------------------------------------------------ helpers
    def _roll(self, p: float) -> bool:
        if p <= 0.0:
            return False
        with self._lock:
            return self._rng.random() < p

    def _depths(self) -> List[List]:
        """Gauge piggyback: ``[cls, shard, cycle, deque_cycle]`` for every
        shard homed here — rides every data-plane response so the driver's
        steal ranking and depth gauges never read a stale mirror for long."""
        return [[name, s, q.cycle.load(), q.deque_cycle.load()]
                for (name, s), q in self.queues.items()]

    def _envs_out(self, envs) -> Tuple[str, List[float]]:
        envs = sorted(envs)
        return (wire_encode(envs),
                [e.t_submit for e in envs])

    # ----------------------------------------------------------- handlers
    def handle(self, body: dict) -> dict:
        """One request -> one response body (the connection layer frames it
        and applies the RTT sender delay). Never raises on bad input — a
        malformed op gets an ``{"err": ...}`` response so the driver fails
        loudly instead of hanging on a silent connection death."""
        op = body.get("op")
        rid = body.get("id")
        if op in _RETRIED_OPS and rid is not None:
            with self._lock:
                cached = self._done.get(rid)
                if cached is not None:
                    self.counters["deduped"] += 1
                    return cached
        try:
            fn = getattr(self, "_op_" + str(op), None)
            if fn is None:
                resp = {"err": f"unknown op {op!r}"}
            else:
                resp = fn(body)
        except Exception as exc:  # surface, don't kill the connection
            resp = {"err": f"{type(exc).__name__}: {exc}"}
        resp["id"] = rid
        if op in _RETRIED_OPS and rid is not None and "err" not in resp:
            with self._lock:
                self._done[rid] = resp
                while len(self._done) > _DEDUPE_CAP:
                    self._done.popitem(last=False)
        return resp

    def _op_ping(self, body):
        return {"host": self.host}

    def _op_fetch(self, body):
        key = (body["cls"], int(body["shard"]))
        addr = tuple(body["addr"])
        resp = {"op": "fetch", "cls": key[0], "shard": key[1]}
        with self._lock:
            self.counters["fetches"] += 1
            own = self.owners.get(key)
            if own is not None and own != (int(addr[0]), int(addr[1])):
                # stale mirror: the seat moved (a steal landed here first).
                # Claim nothing; return the authoritative owner so the
                # driver's seat mirror catches up immediately.
                resp.update(envs="[]", t=[], owner=list(own),
                            d=self._depths())
                return resp
            parked = self._inflight.pop(key, [])
        q = self.queues[key]
        fresh = q.dequeue_many(int(body["k"]))
        if fresh and self._roll(self.delay):
            # claimed but in flight on the (simulated) wire: parks until a
            # later fetch of this shard or a quiesce flush — never lost
            with self._lock:
                self.counters["delayed"] += len(fresh)
                self._inflight.setdefault(key, []).extend(fresh)
            fresh = []
        blob, t = self._envs_out(parked + fresh)
        resp.update(envs=blob, t=t, d=self._depths())
        if (own := self.owners.get(key)) is not None:
            resp["owner"] = list(own)
        return resp

    def _op_publish(self, body):
        key = (body["cls"], int(body["shard"]))
        envs = wire_decode(body["envs"], t_submit=body.get("t"))
        self.queues[key].enqueue_many(envs)
        with self._lock:
            self.counters["publishes"] += 1
        return {"n": len(envs), "d": self._depths()}

    def _op_claim(self, body):
        key = (body["cls"], int(body["shard"]))
        thief = (int(body["thief"][0]), int(body["thief"][1]))
        with self._lock:
            self.counters["claims"] += 1
            cur = self.owners.get(key)
            won = cur is not None and cur != thief
            if won:
                self.owners[key] = thief  # the serialized seat CAS
            owner = self.owners.get(key)
        return {"won": won, "owner": list(owner) if owner else None,
                "d": self._depths()}

    def _op_reseat(self, body):
        expect = body.get("expect_host")
        moved = 0
        keys = []
        with self._lock:
            for name, s, target in body["assignments"]:
                key = (name, int(s))
                keys.append(key)
                cur = self.owners.get(key)
                tgt = (int(target[0]), int(target[1]))
                if cur == tgt:
                    continue
                if expect is not None and (cur is None
                                           or cur[0] != int(expect)):
                    continue
                self.owners[key] = tgt
                moved += 1
            owners = [[k[0], k[1], list(self.owners[k])] for k in keys]
        return {"moved": moved, "owners": owners}

    def _op_shard_enq(self, body):
        key = (body["cls"], int(body["shard"]))
        envs = wire_decode(body["envs"], t_submit=body.get("t"))
        q = self.queues[key]
        q.enqueue_many(envs)
        return {"n": len(envs),
                "cycle": q.cycle.load(), "dcycle": q.deque_cycle.load()}

    def _op_shard_deq(self, body):
        key = (body["cls"], int(body["shard"]))
        q = self.queues[key]
        blob, t = self._envs_out(q.dequeue_many(int(body["k"])))
        return {"envs": blob, "t": t,
                "cycle": q.cycle.load(), "dcycle": q.deque_cycle.load()}

    def _op_depths(self, body):
        return {"d": self._depths()}

    def _op_quiesce(self, body):
        """Flush claimed-but-delayed batches back into their home shards
        (the sim's ``_flush_inflight``) so a checkpoint or recovery pass
        sees every envelope in a queue."""
        with self._lock:
            flushed = self._inflight
            self._inflight = {}
        n = 0
        for key, envs in flushed.items():
            self.queues[key].enqueue_many(envs)
            n += len(envs)
        return {"flushed": n, "d": self._depths()}

    def _op_stats(self, body):
        shards = []
        for (name, s), q in self.queues.items():
            shards.append([name, s, q.cycle.load(), q.deque_cycle.load(),
                           q.pool.allocated, dict(q.stats)])
        with self._lock:
            counters = dict(self.counters)
            counters["inflight"] = sum(len(v)
                                       for v in self._inflight.values())
        return {"shards": shards, "counters": counters}


class HostServer:
    """Threaded TCP front end for one :class:`HostWorker`.

    One accept loop; per connection, one reader thread that decodes frames,
    dispatches to the worker and sends responses. With injected RTT, data-
    plane responses are handed to a per-connection **sender queue** that
    releases each at ``receive time + rtt``: per-connection FIFO (TCP
    ordering) is preserved while pipelined requests overlap their delays —
    which is exactly what prefetch credit buys the client.
    """

    def __init__(self, worker: HostWorker, port: int = 0):
        self.worker = worker
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind(("127.0.0.1", port))
        self._sock.listen(8)
        self.port = self._sock.getsockname()[1]
        self._stop = threading.Event()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"host{worker.host}-accept",
            daemon=True)

    def start(self) -> None:
        self._accept_thread.start()

    def serve_forever(self) -> None:
        self.start()
        self._stop.wait()

    def shutdown(self) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass

    # ------------------------------------------------------------ plumbing
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return  # listener closed
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name=f"host{self.worker.host}-conn",
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        worker = self.worker
        send_lock = threading.Lock()
        sender = _DelayedSender(conn, send_lock) if worker.rtt_s > 0 else None
        dec = FrameDecoder()
        try:
            while not self._stop.is_set():
                try:
                    data = conn.recv(65536)
                except OSError:
                    break
                if not data:
                    break
                for _, body in dec.feed(data):
                    op = body.get("op")
                    if op == "shutdown":
                        frame = pack_frame(KIND_RESP,
                                           {"id": body.get("id"), "ok": 1})
                        with send_lock:
                            conn.sendall(frame)
                        self.shutdown()
                        return
                    is_data = op in _DATA_OPS
                    remote = True
                    if is_data:
                        src = body.get("addr") or body.get("thief")
                        remote = src is None or int(src[0]) != worker.host
                    if is_data and remote and worker._roll(worker.drop):
                        # lost request: nothing processed, nothing sent —
                        # the client's timeout/retry is the recovery
                        with worker._lock:
                            worker.counters["drops"] += 1
                        continue
                    resp = worker.handle(body)
                    frame = pack_frame(KIND_RESP, resp)
                    if sender is not None and is_data:
                        sender.put(frame, worker.rtt_s)
                    else:
                        with send_lock:
                            conn.sendall(frame)
        finally:
            if sender is not None:
                sender.close()
            try:
                conn.close()
            except OSError:
                pass


class _DelayedSender:
    """Per-connection FIFO of (due-time, frame): releases each frame once
    its injected RTT has elapsed. FIFO + constant delay keeps responses in
    request order, like a real pipe with latency."""

    def __init__(self, conn: socket.socket, send_lock: threading.Lock):
        self._conn = conn
        self._send_lock = send_lock
        self._q: deque = deque()
        self._cond = threading.Condition()
        self._closed = False
        threading.Thread(target=self._run, daemon=True,
                         name="wire-delayed-sender").start()

    def put(self, frame: bytes, delay_s: float) -> None:
        with self._cond:
            self._q.append((time.monotonic() + delay_s, frame))
            self._cond.notify()

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify()

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._q and not self._closed:
                    self._cond.wait()
                if not self._q and self._closed:
                    return
                due, frame = self._q[0]
                wait = due - time.monotonic()
                if wait > 0:
                    self._cond.wait(wait)
                    continue
                self._q.popleft()
            try:
                with self._send_lock:
                    self._conn.sendall(frame)
            except OSError:
                return


def worker_main(spec_json: str) -> None:
    """Entry point for one host worker process (``python -m
    repro.net.server``): build the shard state from the spec line on
    stdin, bind an ephemeral localhost port, report it as ``PORT <n>`` on
    stdout, then serve until a ``shutdown`` frame arrives — or until
    stdin hits EOF, which means the driver died; exiting then (rather
    than serving an orphaned fleet) is the crash-cleanup path. Import
    cost is deliberately tiny (core CMP + stdlib, no accelerator stack)
    so a 2-host fleet spawns in well under a second."""
    spec = json.loads(spec_json)
    server = HostServer(HostWorker(spec), port=0)
    sys.stdout.write(f"PORT {server.port}\n")
    sys.stdout.flush()

    def _watch_stdin() -> None:
        while sys.stdin.read(64):
            pass
        server.shutdown()
        os._exit(0)

    threading.Thread(target=_watch_stdin, daemon=True,
                     name="wire-stdin-watch").start()
    server.serve_forever()


def main() -> None:
    worker_main(sys.stdin.readline())


if __name__ == "__main__":
    main()
