"""Real multi-process wire transport for the seat protocol (DESIGN.md §15).

``framing`` — length-prefixed binary frames wrapping the ``wire_encode``
JSON codec; ``server`` — the per-host worker process (authoritative shard
queues + seat table); ``wire`` — the driver-side :class:`WireTransport`
with batched claim frames, fetch pipelining and prefetch credit.
"""

from repro.net.framing import (FrameDecoder, FrameError, KIND_REQ,
                               KIND_RESP, MAX_FRAME, pack_frame,
                               unpack_frames)
from repro.net.server import HostServer, HostWorker, worker_main
from repro.net.wire import PeerClient, ShardProxy, WireError, WireTransport

__all__ = [
    "FrameDecoder", "FrameError", "KIND_REQ", "KIND_RESP", "MAX_FRAME",
    "pack_frame", "unpack_frames", "HostServer", "HostWorker",
    "worker_main", "PeerClient", "ShardProxy", "WireError", "WireTransport",
]
