"""Driver-side wire transport: the seat protocol over real sockets
(DESIGN.md §15).

:class:`WireTransport` implements the :class:`~repro.sched.transport.Transport`
ABC over a fleet of **real OS processes**: ``bind`` spawns one
:mod:`repro.net.server` worker per host, each owning the authoritative
CMP shard queues and seat table for the shards homed on it, and keeps one
persistent TCP connection (:class:`PeerClient`) per peer. The driver's
shard queues become :class:`ShardProxy` mirrors and its seat cells become
response-fed mirrors; every byte between them is a
:mod:`repro.net.framing` frame whose body carries the existing
``wire_encode`` JSON codec — the frontier checkpoint format stays the wire
format.

What makes it fast (the RTT-amortization trio, per the paper's thesis that
coordination cost, not queue cost, dominates):

  * **fetch pipelining with prefetch credit** — each consumer keeps up to
    ``credit`` fetches in flight per home shard (mirroring
    ``DeviceAdmissionRing``'s claim look-ahead), so a hot drain loop pops
    locally-buffered envelopes while the next batches are already on the
    wire; ``credit=1`` degenerates to a synchronous fetch per round (the
    bench's comparison baseline). The buffer is keyed by shard, not owner,
    so a steal inherits the victim's prefetched batches exactly like the
    sim's in-flight reclaim.
  * **batched claim frames** — ``reseat`` coalesces a whole cycle-run of
    seat CASes (a resize or recovery's reassignment sweep) into one frame
    per destination host.
  * **piggybacked gauges** — every data-plane response carries the serving
    host's shard depths, so steal ranking reads fresh mirrors without
    dedicated polling.

Failure model (chaos-invariant exactness, same argument as the sim): a
dropped request is discarded by the server *before* any state changes, so
the client's timeout is exact — fetch expires to an empty round, claim
expires to ``False``, and ``publish`` (which carries claimed envelopes)
retransmits the **same request id** with exponential backoff until acked,
with server-side id dedupe making at-least-once delivery idempotent.
"""

from __future__ import annotations

import itertools
import json
import os
import select
import socket
import subprocess
import sys
import threading
import time
from collections import deque
from typing import Deque, Dict, Tuple

from repro.core.atomics import AtomicCell
from repro.net.framing import KIND_REQ, FrameDecoder, FrameError, pack_frame
from repro.sched.transport import (HostAddr, Transport, wire_decode,
                                   wire_encode)


class WireError(RuntimeError):
    """A wire-transport failure the protocol cannot absorb: an unacked
    reliable op past its total deadline, a dead peer connection, or a
    server-side handler error."""


class PeerClient:
    """One persistent connection to one host server.

    A single reader thread demultiplexes responses by request id: sync
    requests park on an event, async fetches are handed to the transport's
    prefetch buffer. Reliable requests retransmit the *same* id on timeout
    (the server dedupes applied mutations), with exponential backoff.
    """

    def __init__(self, host: int, port: int, transport: "WireTransport"):
        self.host = int(host)
        self._transport = transport
        self.sock = socket.create_connection(("127.0.0.1", port), timeout=10)
        self.sock.settimeout(None)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._send_lock = threading.Lock()
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._sync: Dict[int, list] = {}    # id -> [event, response]
        self._fetch: Dict[int, tuple] = {}  # id -> (key, deadline, t0)
        self._dec = FrameDecoder()
        self.alive = True
        threading.Thread(target=self._read_loop, daemon=True,
                         name=f"wire-peer{host}-reader").start()

    # ------------------------------------------------------------- sending
    def _send(self, frame: bytes) -> None:
        if not self.alive:
            raise WireError(f"connection to host {self.host} is closed")
        try:
            with self._send_lock:
                self.sock.sendall(frame)
        except OSError as exc:
            self.alive = False
            raise WireError(
                f"send to host {self.host} failed: {exc}") from exc

    def request(self, body: dict, *, timeout: float, retry: bool = False,
                max_total: float = 30.0) -> Tuple[dict, int]:
        """Send one request and wait for its response. ``retry=True`` is
        the reliable (ack-before-done) mode: retransmit the same id with
        doubling timeouts until acked or ``max_total`` elapses. Returns
        ``(response_or_None, attempts)``."""
        rid = next(self._ids)
        body = dict(body)
        body["id"] = rid
        frame = pack_frame(KIND_REQ, body)
        ev = threading.Event()
        slot = [ev, None]
        with self._lock:
            self._sync[rid] = slot
        deadline = time.monotonic() + max_total
        wait = timeout
        attempts = 0
        try:
            while True:
                attempts += 1
                self._send(frame)
                if ev.wait(wait):
                    return slot[1], attempts
                if not retry or time.monotonic() >= deadline:
                    return None, attempts
                wait = min(wait * 2.0, 2.0)  # exponential backoff
        finally:
            with self._lock:
                self._sync.pop(rid, None)

    def fetch_async(self, body: dict, key: tuple, deadline: float) -> None:
        """Fire one pipelined fetch; its response (or expiry) is handled by
        the transport's prefetch state."""
        rid = next(self._ids)
        body["id"] = rid
        frame = pack_frame(KIND_REQ, body)
        with self._lock:
            self._fetch[rid] = (key, deadline, time.perf_counter())
        try:
            self._send(frame)
        except WireError:
            with self._lock:
                self._fetch.pop(rid, None)
            raise

    def expire_fetches(self, key: tuple, now: float) -> int:
        """Drop timed-out in-flight fetch entries for ``key`` (a dropped
        request claimed nothing server-side, so expiry is exact)."""
        with self._lock:
            dead = [r for r, (k, dl, _) in self._fetch.items()
                    if k == key and dl <= now]
            for r in dead:
                del self._fetch[r]
        return len(dead)

    def close(self) -> None:
        self.alive = False
        try:
            self.sock.close()
        except OSError:
            pass

    # ------------------------------------------------------------ receiving
    def _read_loop(self) -> None:
        try:
            while True:
                data = self.sock.recv(65536)
                if not data:
                    break
                for _, body in self._dec.feed(data):
                    self._dispatch(body)
        except (OSError, FrameError):
            pass
        finally:
            self.alive = False
            with self._lock:
                slots = list(self._sync.values())
                self._sync.clear()
                fetches = list(self._fetch.values())
                self._fetch.clear()
            for slot in slots:
                slot[0].set()  # response stays None -> callers see a timeout
            if fetches:
                self._transport._abandon_fetches(
                    [ent[0] for ent in fetches])

    def _dispatch(self, body: dict) -> None:
        rid = body.get("id")
        ent = slot = None
        with self._lock:
            if rid is not None:
                ent = self._fetch.pop(rid, None)
                if ent is None:
                    slot = self._sync.pop(rid, None)
        if ent is not None:
            self._transport._on_fetch_response(self, ent, body,
                                               counted=True)
        elif slot is not None:
            slot[1] = body
            slot[0].set()
        elif body.get("op") == "fetch":
            # late response to an expired fetch: its envelopes were claimed
            # server-side, so park them — claimed-but-in-flight, never lost
            self._transport._on_fetch_response(self, None, body,
                                               counted=False)


class _PoolMirror:
    """Stand-in for ``CMPQueue.pool`` on a proxy: gauge mirror only."""

    __slots__ = ("allocated",)

    def __init__(self) -> None:
        self.allocated = 0


class ShardProxy:
    """Driver-side mirror of one host-resident CMP shard.

    Presents exactly the surface the driver-side fabric reads —
    ``cycle``/``deque_cycle`` cells (depth gauges + steal ranking),
    ``window``, ``pool.allocated``, ``stats`` and the enqueue/dequeue entry
    points — while the authoritative queue lives in the shard's home host
    process. Counter mirrors advance monotonically from response
    piggybacks; enqueue/dequeue are synchronous RPCs (the drain hot path
    does NOT come through here — it uses the transport's pipelined
    ``fetch``)."""

    # flight-recorder attachment points (MetricsHub.attach sets these)
    _obs = None
    _obs_cls = "?"

    def __init__(self, transport: "WireTransport", cls_name: str,
                 shard: int, window: int):
        self._transport = transport
        self.cls_name = cls_name
        self.shard = int(shard)
        self.window = window
        self.cycle = AtomicCell(0)
        self.deque_cycle = AtomicCell(0)
        self.pool = _PoolMirror()
        self.stats = {"enq_retries": 0, "deq_scans": 0, "reclaimed": 0,
                      "reclaim_passes": 0, "reclaim_contended": 0,
                      "rescued": 0}

    def enqueue(self, env) -> bool:
        return self.enqueue_many([env]) == 1

    def enqueue_many(self, envs) -> int:
        envs = list(envs)
        if not envs:
            return 0
        return self._transport._shard_enqueue(self.cls_name, self.shard,
                                              envs)

    def dequeue(self):
        got = self.dequeue_many(1)
        return got[0] if got else None

    def dequeue_many(self, k: int) -> list:
        return self._transport._shard_dequeue(self.cls_name, self.shard,
                                              int(k))


class WireTransport(Transport):
    """The seat protocol over TCP to per-host worker processes."""

    kind = "wire"

    def __init__(self, num_hosts: int, *, drop: float = 0.0,
                 delay: float = 0.0, rtt_ms: float = 0.0, credit: int = 4,
                 seed: int = 0, encode=None, decode=None,
                 fetch_timeout: float = 0.0):
        assert num_hosts >= 1
        assert 0.0 <= drop < 1.0, f"drop={drop} must be in [0, 1)"
        assert 0.0 <= delay < 1.0, f"delay={delay} must be in [0, 1)"
        assert credit >= 1, f"credit={credit} must be >= 1"
        self.num_hosts = int(num_hosts)
        self.drop = float(drop)
        self.delay = float(delay)
        self.rtt_ms = float(rtt_ms)
        self.credit = int(credit)
        self.seed = int(seed)
        self._encode = encode
        self._decode = decode
        rtt_s = self.rtt_ms / 1e3
        # Timeout calibration IS the failure model: injected RTT bounds the
        # response delay, so a client-side expiry implies the request was
        # dropped before processing (nothing claimed) — except for
        # publish/reseat, which retransmit the same id until acked.
        self.fetch_timeout = float(fetch_timeout) or max(
            0.25, 10.0 * rtt_s + 0.1)
        self.pub_timeout = max(0.1, 4.0 * rtt_s + 0.05)
        self.claim_timeout = max(0.15, 4.0 * rtt_s + 0.05)
        self.ctl_timeout = 10.0
        self.max_op_s = 30.0
        self._dead: set = set()
        self._closed = False
        self._procs: list = []
        self._peers: Dict[int, PeerClient] = {}
        # prefetch-credit state: per-(cls, shard) buffered envelopes +
        # in-flight fetch count + a hot/cold hint from the last response
        self._fcond = threading.Condition()
        self._buf: Dict[tuple, Deque] = {}
        self._outstanding: Dict[tuple, int] = {}
        self._hot: Dict[tuple, bool] = {}
        self._empty_tick: Dict[tuple, int] = {}
        self._depth_refresh_t = 0.0
        self._stats_cache: dict = {}
        self._stats_cache_t = 0.0
        # client-side counters (plain +=: the repo's approximate-when-racing
        # telemetry contract)
        self.fetches = 0
        self.publishes = 0
        self.remote_msgs = 0
        self.remote_bytes = 0
        self.retransmits = 0
        self.remote_claims = 0
        self.fetch_timeouts = 0

    # ---- addressing -------------------------------------------------------
    def host_of(self, rid: int) -> int:
        return int(rid) % self.num_hosts

    def shard_home(self, shard: int) -> int:
        return int(shard) % self.num_hosts

    def alive(self, host: int) -> bool:
        return host not in self._dead

    # ---- lifecycle: spawn + bind ------------------------------------------
    def bind(self, scheduler, seats) -> None:
        if self._procs:
            raise WireError("wire transport is already bound to a fleet")
        super().bind(scheduler, seats)
        self._spawn(scheduler, seats)
        # Swap every driver-side shard queue for a mirror proxy. Anything
        # already enqueued (producers cannot start before bind, but belt
        # and braces) is forwarded to its authoritative home.
        for qc in scheduler.classes:
            for s, q in enumerate(qc.shards.queues):
                proxy = ShardProxy(self, qc.name, s, window=q.window)
                leftovers: list = []
                while True:
                    got = q.dequeue_many(256)
                    if not got:
                        break
                    leftovers.extend(got)
                qc.shards.queues[s] = proxy
                if leftovers:
                    proxy.enqueue_many(leftovers)

    def _spawn(self, scheduler, seats) -> None:
        # Plain subprocesses running `python -m repro.net.server` (spec on
        # stdin, `PORT <n>` on stdout) rather than multiprocessing spawn:
        # no re-import of the driver's __main__, no pickling — the spec
        # line IS the worker's whole world, which is also what keeps the
        # worker import graph accelerator-free.
        src_root = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        env = dict(os.environ)
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        for h in range(self.num_hosts):
            spec = {
                "host": h,
                "num_hosts": self.num_hosts,
                "classes": [{"name": qc.name,
                             "num_shards": len(qc.shards),
                             "queue_kw": dict(qc._queue_kw)}
                            for qc in scheduler.classes],
                "owners": [[name, s, [seat.owner.load().host,
                                      seat.owner.load().rid]]
                           for name, cls_seats in seats.items()
                           for s, seat in enumerate(cls_seats)
                           if s % self.num_hosts == h],
                "chaos": {"drop": self.drop, "delay": self.delay,
                          "rtt_ms": self.rtt_ms,
                          "seed": self.seed + 1000 * h},
            }
            proc = subprocess.Popen(
                [sys.executable, "-m", "repro.net"],
                stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                env=env, text=True)
            proc.stdin.write(json.dumps(spec) + "\n")
            proc.stdin.flush()
            self._procs.append(proc)
        for h, proc in enumerate(self._procs):
            ready, _, _ = select.select([proc.stdout], [], [], 30.0)
            line = proc.stdout.readline() if ready else ""
            if not line.startswith("PORT "):
                self.close()
                raise WireError(
                    f"host worker {h} did not report a port within 30s "
                    f"(got {line!r}; exit={proc.poll()})")
            self._peers[h] = PeerClient(h, int(line.split()[1]), self)

    def close(self) -> None:
        """Shut the fleet down: one shutdown frame per worker, then wait
        (terminate/kill as a last resort — closing the worker's stdin is
        itself an exit signal). Idempotent."""
        if self._closed:
            return
        self._closed = True
        for peer in self._peers.values():
            try:
                peer.request({"op": "shutdown"}, timeout=2.0)
            except Exception:
                pass
            peer.close()
        for proc in self._procs:
            for stream in (proc.stdin, proc.stdout):
                try:
                    if stream:
                        stream.close()
                except OSError:
                    pass
            try:
                proc.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                proc.terminate()
                try:
                    proc.wait(timeout=2.0)
                except subprocess.TimeoutExpired:
                    proc.kill()
                    proc.wait(timeout=2.0)

    # ---- mirror maintenance ----------------------------------------------
    def _rtt(self, host: int, dt: float) -> None:
        if self._obs is not None:
            self._obs.record_rtt(host, dt)

    def _bump(self, cls_name: str, shard: int, cycle=None,
              dcycle=None) -> None:
        """Advance a proxy's depth mirror monotonically (responses can
        overtake each other across the control/data planes; the counters
        themselves never regress)."""
        qc = self._sched.by_name.get(cls_name)
        if qc is None or shard >= len(qc.shards.queues):
            return
        q = qc.shards.queues[shard]
        if not isinstance(q, ShardProxy):
            return
        if cycle is not None and cycle > q.cycle.load():
            q.cycle.store(cycle)
        if dcycle is not None and dcycle > q.deque_cycle.load():
            q.deque_cycle.store(dcycle)

    def _apply_depths(self, body: dict) -> None:
        for rec in body.get("d") or ():
            name, s, cyc, dcyc = rec
            self._bump(name, int(s), cycle=cyc, dcycle=dcyc)

    def _store_owner(self, cls_name: str, shard: int, owner) -> None:
        if owner is None:
            return
        seats = self._seats.get(cls_name)
        if seats is None or shard >= len(seats):
            return
        seats[shard].owner.store(HostAddr(int(owner[0]), int(owner[1])))

    # ---- prefetch-credit fetch pipeline -----------------------------------
    def _on_fetch_response(self, peer: PeerClient, ent, body: dict,
                           counted: bool) -> None:
        """Reader-thread handler for one fetch response (pipelined or
        late). ``counted`` distinguishes a tracked in-flight entry (whose
        outstanding slot this response releases) from a late response whose
        entry already expired — the latter only parks envelopes."""
        if counted:
            key, _deadline, t0 = ent
            self._rtt(peer.host, time.perf_counter() - t0)
        else:
            key = (body.get("cls"), body.get("shard"))
        envs: list = []
        blob = body.get("envs")
        if blob:
            try:
                envs = wire_decode(blob, self._decode,
                                   t_submit=body.get("t"))
            except (ValueError, KeyError, TypeError):
                envs = []
            if envs:
                self.remote_bytes += len(blob)
        self._store_owner(key[0], key[1], body.get("owner"))
        self._apply_depths(body)
        with self._fcond:
            if counted:
                self._outstanding[key] = max(
                    0, self._outstanding.get(key, 0) - 1)
            if envs:
                self._buf.setdefault(key, deque()).extend(envs)
                self._hot[key] = True
            else:
                self._hot[key] = False
                self._empty_tick[key] = self._empty_tick.get(key, 0) + 1
            self._fcond.notify_all()

    def _abandon_fetches(self, keys) -> None:
        """A peer connection died with fetches in flight: release their
        outstanding slots so waiters stop blocking."""
        with self._fcond:
            for key in keys:
                self._outstanding[key] = max(
                    0, self._outstanding.get(key, 0) - 1)
            self._fcond.notify_all()

    def _issue(self, peer: PeerClient, key: tuple, k: int,
               addr: HostAddr) -> None:
        body = {"op": "fetch", "cls": key[0], "shard": key[1], "k": int(k),
                "addr": [int(addr.host), int(addr.rid)]}
        self.remote_msgs += 1
        try:
            peer.fetch_async(body, key,
                             time.monotonic() + self.fetch_timeout)
        except WireError:
            with self._fcond:
                self._outstanding[key] = max(
                    0, self._outstanding.get(key, 0) - 1)

    def fetch(self, cls_name, shard, k, addr):
        if self._closed or addr.host in self._dead:
            return []
        key = (cls_name, int(shard))
        peer = self._peers[self.shard_home(shard)]
        self.fetches += 1
        deadline = time.monotonic() + self.fetch_timeout
        to_issue = 0
        out: list = []
        with self._fcond:
            expired = peer.expire_fetches(key, time.monotonic())
            if expired:
                self._outstanding[key] = max(
                    0, self._outstanding.get(key, 0) - expired)
                self.fetch_timeouts += expired
            buf = self._buf.setdefault(key, deque())
            while buf and len(out) < k:
                out.append(buf.popleft())
            outst = self._outstanding.get(key, 0)
            if self.credit > 1:
                # pipeline: keep `credit` fetches in flight while the shard
                # is producing; idle back to 1 probe once it runs dry
                target = self.credit if self._hot.get(key, True) else 1
                to_issue = max(0, target - outst)
                if not out and outst == 0 and to_issue == 0:
                    to_issue = 1
            elif not out and outst == 0:
                # credit=1: one synchronous fetch, issued only on a dry
                # buffer — no look-ahead (the bench's baseline)
                to_issue = 1
            self._outstanding[key] = outst + to_issue
            tick0 = self._empty_tick.get(key, 0)
        for _ in range(to_issue):
            self._issue(peer, key, k, addr)
        if out:
            return out
        # dry buffer: wait for the pipeline's next response (an empty
        # response while dry means the shard has nothing — return and let
        # the drain loop pace its own retry)
        with self._fcond:
            while True:
                buf = self._buf.get(key)
                if buf:
                    while buf and len(out) < k:
                        out.append(buf.popleft())
                    return out
                if self._empty_tick.get(key, 0) != tick0:
                    break
                if self._outstanding.get(key, 0) <= 0:
                    break
                now = time.monotonic()
                if now >= deadline:
                    break
                self._fcond.wait(min(0.05, deadline - now))
                expired = peer.expire_fetches(key, time.monotonic())
                if expired:
                    self._outstanding[key] = max(
                        0, self._outstanding.get(key, 0) - expired)
                    self.fetch_timeouts += expired
        self._maybe_refresh_depths()
        return out

    def _maybe_refresh_depths(self) -> None:
        """Starved-consumer path: refresh every live host's depth mirrors
        (rate-limited) so steal ranking sees remote backlogs even when no
        data-plane response has piggybacked them recently."""
        now = time.monotonic()
        if now - self._depth_refresh_t < 0.05 or self._closed:
            return
        self._depth_refresh_t = now
        for h, peer in self._peers.items():
            if not peer.alive:
                continue
            try:
                resp, _ = peer.request({"op": "depths"}, timeout=0.25)
            except WireError:
                continue
            if resp:
                self._apply_depths(resp)

    # ---- publish / claim --------------------------------------------------
    def publish(self, cls_name, shard, envs, addr):
        if not envs:
            return 0
        envs = sorted(envs)
        blob = wire_encode(envs, self._encode)
        stamps = [e.t_submit for e in envs]
        peer = self._peers[self.shard_home(shard)]
        body = {"op": "publish", "cls": cls_name, "shard": int(shard),
                "envs": blob, "t": stamps,
                "addr": [int(addr.host), int(addr.rid)]}
        self.publishes += 1
        self.remote_msgs += 1
        self.remote_bytes += len(blob)
        t0 = time.perf_counter()
        resp, attempts = peer.request(body, timeout=self.pub_timeout,
                                      retry=True, max_total=self.max_op_s)
        self.retransmits += attempts - 1
        if resp is None:
            raise WireError(
                f"publish of {len(envs)} envelopes to host {peer.host} "
                f"unacked after {attempts} attempts")
        if "err" in resp:
            raise WireError(f"publish rejected by host {peer.host}: "
                            f"{resp['err']}")
        self._rtt(peer.host, time.perf_counter() - t0)
        self._apply_depths(resp)
        return len(envs)

    def claim_seat(self, cls_name, shard, addr):
        peer = self._peers[self.shard_home(shard)]
        body = {"op": "claim", "cls": cls_name, "shard": int(shard),
                "thief": [int(addr.host), int(addr.rid)]}
        self.remote_claims += 1
        self.remote_msgs += 1
        self.remote_bytes += 32  # fixed-size claim frame (sim parity)
        t0 = time.perf_counter()
        try:
            resp, _ = peer.request(body, timeout=self.claim_timeout)
        except WireError:
            return False
        if resp is None or "err" in resp:
            # dropped before processing: the CAS never happened — the
            # caller's next steal round is the retry, exactly as in sim
            return False
        self._rtt(peer.host, time.perf_counter() - t0)
        self._store_owner(cls_name, int(shard), resp.get("owner"))
        self._apply_depths(resp)
        return bool(resp.get("won"))

    def reseat(self, assignments, *, expect_host=None) -> int:
        """The batched claim frame: one reseat request per destination
        host carries that host's whole slice of a reassignment sweep
        (resize / recovery / restore), applied serially against the
        authoritative seat table; the response feeds the driver mirrors."""
        by_host: Dict[int, list] = {}
        for cls_name, shard, target in assignments:
            by_host.setdefault(self.shard_home(shard), []).append(
                [cls_name, int(shard),
                 [int(target.host), int(target.rid)]])
        moved = 0
        for h in sorted(by_host):
            peer = self._peers[h]
            body = {"op": "reseat", "assignments": by_host[h],
                    "expect_host": expect_host}
            self.remote_msgs += 1
            resp, _ = peer.request(body, timeout=self.ctl_timeout,
                                   retry=True, max_total=self.max_op_s)
            if resp is None or "err" in resp:
                raise WireError(
                    f"reseat on host {h} failed: "
                    f"{'timeout' if resp is None else resp['err']}")
            for name, s, owner in resp["owners"]:
                self._store_owner(name, int(s), owner)
            moved += int(resp["moved"])
        return moved

    # ---- proxy ops (driver-side shard mirror RPCs) ------------------------
    def _shard_enqueue(self, cls_name: str, shard: int, envs: list) -> int:
        envs = sorted(envs)
        blob = wire_encode(envs, self._encode)
        stamps = [e.t_submit for e in envs]
        peer = self._peers[self.shard_home(shard)]
        body = {"op": "shard_enq", "cls": cls_name, "shard": int(shard),
                "envs": blob, "t": stamps}
        resp, _ = peer.request(body, timeout=self.pub_timeout, retry=True,
                               max_total=self.max_op_s)
        if resp is None or "err" in resp:
            raise WireError(
                f"shard enqueue on host {peer.host} failed: "
                f"{'timeout' if resp is None else resp['err']}")
        self._bump(cls_name, shard, cycle=resp.get("cycle"),
                   dcycle=resp.get("dcycle"))
        return int(resp["n"])

    def _shard_dequeue(self, cls_name: str, shard: int, k: int) -> list:
        peer = self._peers[self.shard_home(shard)]
        body = {"op": "shard_deq", "cls": cls_name, "shard": int(shard),
                "k": int(k)}
        resp, _ = peer.request(body, timeout=self.ctl_timeout)
        if resp is None or "err" in resp:
            raise WireError(
                f"shard dequeue on host {peer.host} failed: "
                f"{'timeout' if resp is None else resp['err']}")
        self._bump(cls_name, shard, cycle=resp.get("cycle"),
                   dcycle=resp.get("dcycle"))
        return wire_decode(resp["envs"], self._decode,
                           t_submit=resp.get("t"))

    # ---- quiesce / failure ------------------------------------------------
    def quiesce(self) -> int:
        """Settle the pipeline for a checkpoint: wait out every in-flight
        fetch, republish the client-side prefetch buffers to their home
        shards (chaos-free — a quiesce republish is control-plane), and
        flush the servers' delayed batches. After this, every envelope is
        in an authoritative queue."""
        if self._closed:
            return 0
        deadline = time.monotonic() + self.fetch_timeout + 0.5
        with self._fcond:
            while time.monotonic() < deadline:
                now = time.monotonic()
                for key in list(self._outstanding):
                    peer = self._peers[self.shard_home(key[1])]
                    n = peer.expire_fetches(key, now)
                    if n:
                        self._outstanding[key] = max(
                            0, self._outstanding[key] - n)
                        self.fetch_timeouts += n
                if not any(self._outstanding.values()):
                    break
                self._fcond.wait(0.01)
            drained = []
            for key, buf in self._buf.items():
                if buf:
                    drained.append((key, list(buf)))
                    buf.clear()
        n = 0
        for (cls_name, shard), envs in drained:
            home = self.shard_home(shard)
            # home-addressed publish: control-plane, exempt from chaos
            self.publish(cls_name, shard, envs, HostAddr(home, -1))
            n += len(envs)
        for peer in self._peers.values():
            if not peer.alive:
                continue
            resp, _ = peer.request({"op": "quiesce"},
                                   timeout=self.ctl_timeout)
            if resp and "err" not in resp:
                n += int(resp.get("flushed", 0))
                self._apply_depths(resp)
        return n

    def fail_host(self, host: int) -> int:
        """Mark a host's replicas dead (their drain loops stop being
        served) and settle everything in flight. The worker *process*
        stays up: its shard queues are the durable substrate, exactly like
        the sim's host-loss model — recovery republishes staged claims and
        reseats onto survivors."""
        assert 0 <= host < self.num_hosts
        live = [h for h in self.live_hosts() if h != host]
        assert live, "cannot fail the last live host"
        self._dead.add(host)
        return self.quiesce()

    def add_host(self) -> int:
        raise NotImplementedError(
            "wire transport cannot add hosts live: shard homes are modular "
            "in the spawned fleet size — open a new fabric at the larger "
            "size (or use transport='sim' for elasticity experiments)")

    # ---- telemetry --------------------------------------------------------
    def _server_sweep(self) -> dict:
        """Aggregate server-side counters + refresh every proxy's full
        gauge mirror. Cached briefly: stats() sits on gauge-sampling paths
        that tick far faster than counters matter."""
        now = time.monotonic()
        if self._stats_cache and (self._closed or
                                  now - self._stats_cache_t < 0.05):
            return self._stats_cache
        agg = {"drops": 0, "delayed": 0, "deduped": 0, "server_inflight": 0}
        for peer in self._peers.values():
            if not peer.alive:
                continue
            try:
                resp, _ = peer.request({"op": "stats"}, timeout=1.0)
            except WireError:
                continue
            if not resp or "err" in resp:
                continue
            for name, s, cyc, dcyc, alloc, qstats in resp["shards"]:
                self._bump(name, int(s), cycle=cyc, dcycle=dcyc)
                qc = self._sched.by_name.get(name)
                if qc is not None:
                    q = qc.shards.queues[int(s)]
                    if isinstance(q, ShardProxy):
                        q.pool.allocated = alloc
                        q.stats.update(qstats)
            c = resp.get("counters", {})
            agg["drops"] += int(c.get("drops", 0))
            agg["delayed"] += int(c.get("delayed", 0))
            agg["deduped"] += int(c.get("deduped", 0))
            agg["server_inflight"] += int(c.get("inflight", 0))
        self._stats_cache = agg
        self._stats_cache_t = now
        return agg

    def stats(self) -> dict:
        agg = self._server_sweep() if getattr(self, "_sched", None) \
            else {"drops": 0, "delayed": 0, "deduped": 0,
                  "server_inflight": 0}
        return {"kind": self.kind, "hosts": self.num_hosts,
                "dead_hosts": sorted(self._dead),
                "fetches": self.fetches, "publishes": self.publishes,
                "remote_msgs": self.remote_msgs,
                "remote_bytes": self.remote_bytes,
                "drops": agg["drops"], "delayed": agg["delayed"],
                "reordered": 0, "retransmits": self.retransmits,
                "remote_claims": self.remote_claims,
                "deduped": agg["deduped"],
                "server_inflight": agg["server_inflight"],
                "fetch_timeouts": self.fetch_timeouts,
                "prefetch_buffered": sum(len(b)
                                         for b in self._buf.values()),
                "credit": self.credit}

    def spec(self) -> dict:
        return {"kind": self.kind, "hosts": self.num_hosts,
                "drop": self.drop, "delay": self.delay,
                "rtt_ms": self.rtt_ms, "credit": self.credit}
