"""``python -m repro.net`` — host worker entry point for the wire
transport (spec line on stdin, ``PORT <n>`` on stdout; see
:func:`repro.net.server.worker_main`)."""

from repro.net.server import main

main()
