"""Length-prefixed binary framing for the wire transport (DESIGN.md §15).

The frontier checkpoint format IS the wire format (DESIGN.md §9/§11):
``wire_encode`` produces a JSON array of ``[seq, stamp, payload]`` records
and that string rides *inside* the frame body — framing wraps the codec, it
never replaces it. A frame is::

    [4-byte big-endian body length] [1-byte kind] [body: UTF-8 JSON object]

The kind byte separates requests from responses so a frame is
self-describing on capture (tcpdump of the smoke lane reads back with a
5-byte header decode). Bodies are one JSON object per frame — request
bodies carry ``{"id", "op", ...}``, response bodies echo the ``id`` (and,
for fetch, the ``op``/``cls``/``shard`` context so a late response can
still be parked safely).

:class:`FrameDecoder` is incremental: feed it arbitrary byte chunks
(truncated frames, many concatenated frames, single bytes) and it yields
exactly the complete frames, in order, holding partial tails until the
rest arrives. ``MAX_FRAME`` bounds a single body so a corrupt length
prefix fails loudly instead of buffering gigabytes.
"""

from __future__ import annotations

import json
import struct
from typing import Iterator, List, Tuple

# frame kinds (the 1-byte tag after the length prefix)
KIND_REQ = 0x01
KIND_RESP = 0x02
_KINDS = (KIND_REQ, KIND_RESP)

_HEADER = struct.Struct(">IB")  # body length, kind
HEADER_SIZE = _HEADER.size

# One frame carries at most one drain batch (k envelopes of JSON-able
# payloads) or one claim/reseat batch; 64 MiB is orders of magnitude above
# any legitimate body and small enough to fail fast on a corrupt prefix.
MAX_FRAME = 64 * 1024 * 1024


class FrameError(ValueError):
    """A malformed frame: bad kind byte, oversized or negative length, or
    a body that is not valid UTF-8 JSON."""


def pack_frame(kind: int, body: dict) -> bytes:
    """One JSON body -> one wire frame (header + UTF-8 JSON bytes)."""
    if kind not in _KINDS:
        raise FrameError(f"unknown frame kind {kind!r}")
    raw = json.dumps(body, separators=(",", ":")).encode("utf-8")
    if len(raw) > MAX_FRAME:
        raise FrameError(f"frame body {len(raw)}B exceeds MAX_FRAME")
    return _HEADER.pack(len(raw), kind) + raw


def unpack_frames(data: bytes) -> List[Tuple[int, dict]]:
    """Decode a byte string that holds exactly N complete frames (test /
    capture helper; the streaming path uses :class:`FrameDecoder`)."""
    dec = FrameDecoder()
    out = list(dec.feed(data))
    if dec.pending:
        raise FrameError(f"{dec.pending}B of trailing partial frame")
    return out


class FrameDecoder:
    """Incremental frame reassembly over an arbitrary chunk stream.

    TCP is a byte stream: one ``recv`` may hold half a frame or fifty.
    ``feed`` buffers the tail across calls and yields each ``(kind, body)``
    as soon as its last byte arrives — byte-chunking is invisible above
    this layer (property-fuzzed in tests/test_net.py / test_wire_props.py).
    """

    def __init__(self) -> None:
        self._buf = bytearray()

    @property
    def pending(self) -> int:
        """Bytes buffered awaiting the rest of a frame."""
        return len(self._buf)

    def feed(self, chunk: bytes) -> Iterator[Tuple[int, dict]]:
        self._buf.extend(chunk)
        while True:
            if len(self._buf) < HEADER_SIZE:
                return
            length, kind = _HEADER.unpack_from(self._buf)
            if kind not in _KINDS:
                raise FrameError(f"unknown frame kind {kind!r}")
            if length > MAX_FRAME:
                raise FrameError(
                    f"frame length {length}B exceeds MAX_FRAME "
                    f"(corrupt prefix?)")
            end = HEADER_SIZE + length
            if len(self._buf) < end:
                return
            raw = bytes(self._buf[HEADER_SIZE:end])
            del self._buf[:end]
            try:
                body = json.loads(raw.decode("utf-8"))
            except (UnicodeDecodeError, json.JSONDecodeError) as exc:
                raise FrameError(f"undecodable frame body: {exc}") from exc
            yield kind, body
