"""Property-based tests (hypothesis) for the device-side CMP slot pool:
the paper's invariants hold for every operation sequence."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import slotpool as sp
from repro.kernels import ops as kops
from repro.kernels.ref import ref_claim

OPS = st.lists(
    st.one_of(
        st.tuples(st.just("produce"), st.integers(1, 6)),
        st.tuples(st.just("claim"), st.integers(1, 6)),
        st.tuples(st.just("reclaim"), st.integers(0, 8)),   # window size
        st.tuples(st.just("advance"), st.integers(0, 5)),   # cycle delta
    ),
    min_size=1, max_size=40,
)


@pytest.mark.slow
@settings(max_examples=60, deadline=None)
@given(ops=OPS, n=st.integers(4, 24))
def test_slotpool_invariants_hold_for_any_sequence(ops, n):
    pool = sp.make(n)
    produced_cycles = []
    claimed_order = []
    for op, arg in ops:
        if op == "produce":
            pool, ids, valid = sp.produce(pool, arg)
            for i, v in zip(np.asarray(ids), np.asarray(valid)):
                if v:
                    produced_cycles.append(int(pool.cycle[i]))
        elif op == "claim":
            pool, ids, valid = sp.claim(pool, arg)
            for i, v in zip(np.asarray(ids), np.asarray(valid)):
                if v:
                    claimed_order.append(int(pool.cycle[i]))
        elif op == "reclaim":
            before = sp.counts(pool)
            pool, nrec = sp.reclaim(pool, arg)
            # reclamation never touches AVAILABLE slots
            assert sp.counts(pool)["available"] == before["available"]
            # everything still CLAIMED is inside the protection window
            safe = max(0, int(pool.deque_cycle) - arg)
            state = np.asarray(pool.state)
            cyc = np.asarray(pool.cycle)
            assert np.all(cyc[state == sp.CLAIMED] >= safe) or safe == 0
        else:
            # paper-faithful clock: deque_cycle never exceeds issued cycles
            # (the serving engine uses an external step clock instead, where
            # this bound intentionally does not apply)
            pool = sp.advance(pool, jnp.minimum(pool.deque_cycle + arg,
                                                pool.enq_cycle))
        sp.check_invariants(pool, 8)
    # strict FIFO: claims happen in produced-cycle order
    assert claimed_order == sorted(claimed_order)
    # conservation: monotone counters
    assert int(pool.deque_cycle) <= int(pool.enq_cycle)


@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(n=st.integers(4, 32), k=st.integers(1, 8), window=st.integers(0, 10))
def test_window_blocks_reuse(n, k, window):
    """A slot claimed at cycle c is not reusable until deque_cycle - c > W."""
    pool = sp.make(n)
    pool, ids, valid = sp.produce(pool, min(k, n))
    pool, cids, cvalid = sp.claim(pool, min(k, n))
    dc = int(pool.deque_cycle)
    pool2, nrec = sp.reclaim(pool, window)
    cyc = np.asarray(pool.cycle)
    for i, v in zip(np.asarray(cids), np.asarray(cvalid)):
        if not v:
            continue
        inside = cyc[i] >= max(0, dc - window)
        reused = int(pool2.state[i]) == sp.FREE
        assert not (inside and reused), "slot inside window was reclaimed"


@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), k=st.integers(1, 6))
def test_claim_kernel_matches_slotpool(seed, k):
    """The fused Pallas claim kernel == slotpool.claim == ref oracle."""
    rng = np.random.default_rng(seed)
    n = 32
    state = jnp.asarray(rng.choice([0, 1, 2], size=n).astype(np.int32))
    cycle = jnp.asarray(rng.permutation(n).astype(np.int32) + 1)
    ns_k, ids_k = kops.claim(state, cycle, k=k)
    ns_r, ids_r, valid_r = ref_claim(state, cycle, k)
    assert np.array_equal(np.asarray(ns_k), np.asarray(ns_r))
    assert np.array_equal(np.asarray(ids_k), np.asarray(ids_r))
    # and the pool-level claim picks the same earliest cycles
    pool = sp.SlotPool(state=state, cycle=cycle,
                       retire_cycle=jnp.zeros_like(cycle),
                       enq_cycle=jnp.int32(n), deque_cycle=jnp.int32(0))
    pool2, ids_p, valid_p = sp.claim(pool, k)
    got_k = sorted(int(i) for i in np.asarray(ids_k) if i < n)
    got_p = sorted(int(i) for i in np.asarray(ids_p) if i < n)
    assert got_k == got_p


def test_produce_with_reclaim_relieves_pressure():
    pool = sp.make(4)
    pool, ids, valid = sp.produce(pool, 4)
    assert bool(valid.all())
    pool, cids, _ = sp.claim(pool, 4)
    pool = sp.advance(pool, pool.deque_cycle + 100)  # window expires
    pool, ids2, valid2 = sp.produce_with_reclaim(pool, 2, window=8)
    assert bool(valid2.all()), "allocation failure should trigger reclamation"
