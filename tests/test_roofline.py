"""Roofline extraction: collective parsing on known HLO, wire-byte math, and
the while-loop cost-extrapolation calibration (in a subprocess so the main
test process keeps its single-device jax)."""

import subprocess
import sys
import textwrap

from repro.launch import roofline as R


def test_collective_parse_brace_groups():
    hlo = """
  %ar = f32[1024,64]{1,0} all-reduce(f32[1024,64] %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[2048,128]{1,0} all-gather(bf16[512,128] %y), replica_groups={{0,1,2,3}}, dimensions={0}
  %rs = f32[256]{0} reduce-scatter(f32[1024] %z), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[64,64]{1,0} collective-permute(f32[64,64] %w), source_target_pairs={{0,1}}
"""
    wire = R.collective_wire_bytes(hlo)
    ar = 2 * 1024 * 64 * 4 * 3 / 4
    ag = 2048 * 128 * 2 * 3 / 4
    rs = 256 * 4 * 3
    cp = 64 * 64 * 4
    assert abs(wire["all-reduce"] - ar) < 1
    assert abs(wire["all-gather"] - ag) < 1
    assert abs(wire["reduce-scatter"] - rs) < 1
    assert abs(wire["collective-permute"] - cp) < 1
    assert wire["ops"] == 4


def test_collective_parse_iota_groups_and_async():
    hlo = """
  %ars = f32[100]{0} all-reduce-start(f32[100] %x), replica_groups=[16,32]<=[512], to_apply=%add
  %ard = f32[100]{0} all-reduce-done(f32[100] %ars)
"""
    wire = R.collective_wire_bytes(hlo)
    # counted once (start only), n=32 participants
    assert abs(wire["all-reduce"] - 2 * 100 * 4 * 31 / 32) < 1
    assert wire["ops"] == 1


def test_roofline_terms_dominance():
    cost = {"flops": 197e12 * 2.0, "bytes accessed": 819e9 * 0.5}
    terms = R.roofline_terms(cost, "")
    assert terms["dominant"] == "compute"
    assert abs(terms["compute_s"] - 2.0) < 1e-9
    assert abs(terms["memory_s"] - 0.5) < 1e-9


def test_model_flops():
    assert R.model_flops(1000, 10, "train") == 6e4
    assert R.model_flops(1000, 10, "decode") == 2e4


_CALIB = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import functools, jax, jax.numpy as jnp
    M, R = 128, 8
    def loss(x, ws, unroll):
        def body(x, w):
            return jnp.tanh(x @ w), ()
        x, _ = jax.lax.scan(body, x, ws, unroll=unroll)
        return jnp.sum(x)
    g = jax.grad(loss, argnums=1)
    xs = jax.ShapeDtypeStruct((M, M), jnp.float32)
    ws = jax.ShapeDtypeStruct((R, M, M), jnp.float32)
    c = {}
    for u in (1, 2):
        comp = jax.jit(functools.partial(g, unroll=u)).lower(xs, ws).compile()
        ca = comp.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        c[u] = ca["flops"]
    slope = c[2] - c[1]
    total = c[1] - slope + R * slope
    exact = 6 * M**3 * R  # fwd 2M^3 + bwd 4M^3 per layer
    ratio = total / exact
    assert 0.95 < ratio < 1.10, ratio
    print("CALIB_OK", ratio)
""")


def test_unroll_extrapolation_calibration():
    """XLA counts while bodies once; the 2-point unroll extrapolation
    reconstructs true flops to within 10% (the dry-run's cost model)."""
    r = subprocess.run([sys.executable, "-c", _CALIB], capture_output=True,
                       text=True, timeout=300)
    assert "CALIB_OK" in r.stdout, r.stdout + r.stderr
