"""Observability plane (DESIGN.md §13): flight recorder, gauges, exporters,
and the MetricsHub wired through the Fabric session."""

import json

import pytest

from repro.obs import (CONTROL_EVENTS, LIFECYCLE_STAGES, PRODUCER_RID,
                       FlightRecorder, MetricsHub, ObsConfig,
                       format_class_lines, perfetto_trace, prometheus_text,
                       sample_stride, stage_breakdown, strip_samples)
from repro.sched import QueueClass


# ---------------------------------------------------------------------------
# recorder
# ---------------------------------------------------------------------------


def test_sample_stride_maps_rate_to_every_n():
    assert sample_stride(1.0) == 1
    assert sample_stride(0.5) == 2
    assert sample_stride(0.01) == 100
    assert sample_stride(0.0) == 0  # lifecycle tracing off


def test_recorder_sampling_is_deterministic_in_seq():
    rec = FlightRecorder(ObsConfig(ring_capacity=16, trace_rate=0.25))
    picked = [seq for seq in range(40) if rec.sampled(seq)]
    assert picked == [0, 4, 8, 12, 16, 20, 24, 28, 32, 36]
    off = FlightRecorder(ObsConfig(ring_capacity=16, trace_rate=0.0))
    assert not any(off.sampled(seq) for seq in range(40))


def test_recorder_ring_wraps_and_counts():
    rec = FlightRecorder(ObsConfig(ring_capacity=4, trace_rate=1.0),
                         host=1, rid=3)
    for seq in range(10):
        rec.emit("submit", "cls", seq)
    evs = rec.events()
    assert len(evs) == 4  # bounded ring: only the newest survive
    assert [e[3] for e in evs] == [6, 7, 8, 9]  # append order preserved
    snap = rec.snapshot()
    assert snap["dropped"] == 6
    assert snap["counts"]["submit"] == 10  # counts are totals, not retained
    assert snap["rid"] == 3 and snap["host"] == 1


def test_obs_config_validation():
    ObsConfig().validate()
    with pytest.raises(ValueError):
        ObsConfig(trace_rate=1.5).validate()
    with pytest.raises(ValueError):
        ObsConfig(ring_capacity=0).validate()
    with pytest.raises(ValueError):
        ObsConfig(sample_every_n_steps=0).validate()


# ---------------------------------------------------------------------------
# class-level emit sites
# ---------------------------------------------------------------------------


def _traced_class(**kw):
    qc = QueueClass("t", num_shards=2, **kw)
    qc._obs = FlightRecorder(ObsConfig(ring_capacity=1024, trace_rate=1.0))
    return qc


def test_queue_class_emits_producer_and_drain_stages():
    qc = _traced_class()
    qc.submit_many(list(range(8)))
    qc.submit(99)
    qc.drain(9)
    stages = {e[1] for e in qc._obs.events()}
    assert {"submit", "window_admit", "shard_enqueue",
            "drain", "seat"} <= stages
    # one submit event per envelope at trace_rate=1.0
    assert qc._obs.snapshot()["counts"]["submit"] == 9


def test_queue_class_emits_requeue_event():
    qc = _traced_class()
    qc.submit(0)
    [env] = qc.drain(1)
    qc.requeue(env)
    assert any(e[1] == "requeue" and e[3] == env.seq
               for e in qc._obs.events())


def test_partial_sampling_traces_the_stride_subset():
    qc = _traced_class()
    qc._obs = FlightRecorder(ObsConfig(ring_capacity=1024, trace_rate=0.25))
    qc.submit_many(list(range(20)))
    qc.drain(20)
    submit_seqs = sorted(e[3] for e in qc._obs.events()
                         if e[1] == "submit")
    assert submit_seqs == [0, 4, 8, 12, 16]
    drain_seqs = sorted(e[3] for e in qc._obs.events() if e[1] == "drain")
    assert drain_seqs == [0, 4, 8, 12, 16]


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def _lifecycle_events():
    qc = _traced_class()
    qc.submit_many(list(range(6)))
    qc.drain(6)
    return qc._obs.events()


def test_perfetto_trace_structure(tmp_path):
    path = str(tmp_path / "trace.json")
    trace = perfetto_trace(_lifecycle_events(), path=path)
    reloaded = json.load(open(path))
    assert reloaded == trace
    assert trace["displayTimeUnit"] == "ms"
    slices = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert slices, "no complete slices emitted"
    for ev in slices:
        assert ev["dur"] >= 0.0 and ev["ts"] >= 0.0
        assert set(ev) >= {"name", "cat", "pid", "tid", "args"}
        assert ev["name"] in LIFECYCLE_STAGES


def test_perfetto_control_events_are_instants():
    rec = FlightRecorder(ObsConfig(ring_capacity=16, trace_rate=1.0))
    rec.emit("steal", "t", -1, arg={"shard": 1})
    trace = perfetto_trace(rec.events())
    [inst] = trace["traceEvents"]
    assert inst["ph"] == "i" and inst["name"] == "steal"
    assert inst["name"] in CONTROL_EVENTS


def test_stage_breakdown_covers_adjacent_pairs():
    bd = stage_breakdown(_lifecycle_events())
    assert set(bd) == {"submit->window_admit",
                       "window_admit->shard_enqueue",
                       "shard_enqueue->drain", "drain->seat"}
    for row in bd.values():
        assert row["n"] == 6
        assert row["p99_ms"] >= row["p50_ms"] >= 0.0


def _parse_prometheus(text):
    """Minimal exposition-format parser: returns {metric: type} and sample
    count, raising on format violations (non-contiguous families,
    duplicate samples, malformed lines)."""
    types, samples, seen = {}, 0, set()
    current = None
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, typ = line.split(" ")
            assert name not in types, f"family {name} split into two groups"
            types[name] = typ
            current = name
        elif line.startswith("#"):
            continue
        else:
            ident, value = line.rsplit(" ", 1)
            float(value)
            assert ident.split("{")[0] == current, f"stray sample {ident}"
            assert ident not in seen, f"duplicate sample {ident}"
            seen.add(ident)
            samples += 1
    return types, samples


def test_prometheus_text_is_well_formed():
    from repro.fabric import Fabric, FabricConfig
    fab = Fabric.open(FabricConfig(replicas=2, obs=ObsConfig(trace_rate=1.0)))
    fab.submit_many(list(range(30)))
    fab.drain()
    hub = fab.obs
    hub.sample(fab.replica_set, fab.engines)
    gauges = hub.window()[-1][1]
    text = prometheus_text(fab.stats_view(), gauges=gauges)
    types, samples = _parse_prometheus(text)
    assert samples > 20
    assert types["repro_class_submitted"] == "counter"
    assert types["repro_class_pending"] == "gauge"
    assert types["repro_obs_events_total"] == "counter"
    assert "repro_obs_events_dropped" in types


def test_strip_samples_removes_reservoirs_deeply():
    obj = {"a": {"latency_samples": [1, 2], "keep": 1},
           "b": [{"latency_samples": []}, 3]}
    assert strip_samples(obj) == {"a": {"keep": 1}, "b": [{}, 3]}


def test_format_class_lines_handles_missing_latency():
    from repro.fabric import Fabric, FabricConfig
    fab = Fabric.open(FabricConfig())
    lines = format_class_lines(fab.stats_view())
    assert len(lines) == 1 and "p50_ms=-" in lines[0]
    fab.submit_many(list(range(4)))
    fab.drain()
    [line] = format_class_lines(fab.stats_view())
    assert "submitted=4" in line and "delivered=4" in line


# ---------------------------------------------------------------------------
# hub + fabric wiring
# ---------------------------------------------------------------------------


def test_hub_attach_traces_scheduler_fabric_end_to_end():
    from repro.fabric import Fabric, FabricConfig
    cfg = FabricConfig(replicas=2,
                       obs=ObsConfig(trace_rate=1.0, sample_every_n_steps=1))
    fab = Fabric.open(cfg)
    fab.submit_many(list(range(40)))
    deliveries = fab.drain()
    assert len(deliveries) == 40
    hub = fab.obs
    evs = hub.events()
    assert {"submit", "window_admit", "shard_enqueue",
            "drain", "seat"} <= {e[1] for e in evs}
    # merged stream is time-sorted across all rings
    assert all(a[0] <= b[0] for a, b in zip(evs, evs[1:]))
    snap = fab.stats_view().obs
    assert snap["trace_rate"] == 1.0
    assert sum(snap["events_total"].values()) >= 5 * 40
    assert snap["window"]["samples"] >= 1  # cadenced gauge sweeps ran
    gauges = snap["gauges"]
    assert "default" in gauges["classes"]
    occ = gauges["classes"]["default"]
    assert occ["occupancy_frac_max"] >= 0.0
    assert gauges["pending"] == 0


def test_hub_survives_resize_reattach():
    from repro.fabric import Fabric, FabricConfig
    cfg = FabricConfig(replicas=1, max_replicas=3,
                       obs=ObsConfig(trace_rate=1.0))
    fab = Fabric.open(cfg)
    fab.submit_many(list(range(10)))
    fab.drain()
    before = len(fab.obs.events())
    fab.resize(3)
    fab.submit_many(list(range(10, 30)))
    fab.drain()
    evs = fab.obs.events()
    assert len(evs) > before  # new replicas' views re-attached and emitting
    seat_seqs = sorted(e[3] for e in evs if e[1] == "seat")
    assert seat_seqs == list(range(30))  # no envelope lost to the resize


def test_hub_rolling_window_evicts_by_age():
    hub = MetricsHub(ObsConfig(metrics_window_s=1e-7))
    from repro.fabric import Fabric, FabricConfig
    fab = Fabric.open(FabricConfig())
    for _ in range(5):
        hub.sample(fab.replica_set, [])
    # span 0s: every sweep but the newest is already outside the window
    assert len(hub.window()) == 1
    assert hub.snapshot()["window"]["taken"] == 5


def test_hub_rtt_histograms():
    hub = MetricsHub(ObsConfig())
    for ms in (1.0, 2.0, 3.0, 4.0):
        hub.record_rtt(1, ms / 1e3)
    snap = hub.snapshot()["rtt_ms"]
    assert snap[1]["count"] == 4
    assert snap[1]["p50"] == pytest.approx(2.5)


def test_transport_rtt_reaches_hub():
    """Remote publishes (the steal-victim move) report RTT through the
    attached hub; home-aligned local ops do not."""
    from repro.sched import (HostAddr, QueueClass, ReplicaSet, Scheduler,
                             SimHostTransport)
    qc = QueueClass("t", num_shards=2)
    transport = SimHostTransport(2)
    rs = ReplicaSet(Scheduler([qc]), 2, transport=transport)
    hub = MetricsHub(ObsConfig())
    hub.attach(rs)
    qc.submit_many(list(range(4)))
    envs = [env for _, env in rs.replicas[0].drain(4)]
    # shard 1's home is host 1; publishing from host 0 is a remote op
    transport.publish("t", 1, envs[:1], HostAddr(0, 0))
    assert hub.snapshot()["rtt_ms"].get(0, {}).get("count", 0) >= 1


def test_device_admission_ring_control_events():
    from repro.serving.admission import DeviceAdmissionRing
    ring = DeviceAdmissionRing(k=2, claim_block=4)
    ring._obs = FlightRecorder(ObsConfig(ring_capacity=64, trace_rate=1.0))
    claimed, rejected = ring.step(["a", "b", "c"], want=2)
    assert claimed == ["a", "b"] and rejected == []
    leftover = ring.flush()
    assert leftover == ["c"]
    stages = [e[1] for e in ring._obs.events()]
    assert "claim_block" in stages and "flush" in stages


def test_fabric_config_obs_json_round_trip():
    from repro.fabric import FabricConfig, FabricConfigError
    cfg = FabricConfig(obs=ObsConfig(trace_rate=0.5, ring_capacity=128))
    again = FabricConfig.from_json(cfg.to_json())
    assert again == cfg
    assert isinstance(again.obs, ObsConfig)
    with pytest.raises(FabricConfigError):
        FabricConfig(obs=ObsConfig(trace_rate=7.0))


def test_jsonl_snapshot_cadence(tmp_path):
    from repro.fabric import Fabric, FabricConfig
    path = str(tmp_path / "obs" / "snapshots.jsonl")
    cfg = FabricConfig(obs=ObsConfig(sample_every_n_steps=2,
                                     snapshot_path=path))
    fab = Fabric.open(cfg)
    fab.submit_many(list(range(64)))
    fab.drain()
    lines = [json.loads(l) for l in open(path)]
    assert len(lines) >= 2  # one line per cadence hit
    for rec in lines:
        assert "t" in rec and "obs" in rec and "step" in rec
        assert "latency_samples" not in json.dumps(rec)
