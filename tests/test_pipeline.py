"""Pipeline parallelism on CMP-windowed buffers: schedule validity, window
enforcement, and numerical equivalence with non-pipelined training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.pipeline import PipelineRunner, max_in_flight, one_f_one_b

KEY = jax.random.PRNGKey(0)


def test_1f1b_schedule_is_complete_and_ordered():
    for num_stages, num_micro in [(2, 4), (4, 8), (3, 3), (4, 2)]:
        ticks = one_f_one_b(num_stages, num_micro)
        fwd_seen = {s: [] for s in range(num_stages)}
        bwd_seen = {s: [] for s in range(num_stages)}
        for t in ticks:
            (fwd_seen if t.kind == "fwd" else bwd_seen)[t.stage].append(t.microbatch)
        for s in range(num_stages):
            assert fwd_seen[s] == list(range(num_micro)), (num_stages, num_micro, s)
            assert bwd_seen[s] == list(range(num_micro))
        # dataflow order: stage s fwd of micro m appears after stage s-1's
        pos = {(t.kind, t.stage, t.microbatch): i for i, t in enumerate(ticks)}
        for s in range(1, num_stages):
            for m in range(num_micro):
                assert pos[("fwd", s, m)] > pos[("fwd", s - 1, m)]
                assert pos[("bwd", s - 1, m)] > pos[("bwd", s, m)]
        # the window is bounded by pipeline depth
        assert max_in_flight(ticks, num_stages) <= min(num_stages, num_micro) + 1


def _mk_stages(num_stages, d, key):
    ws = [jax.random.normal(jax.random.fold_in(key, i), (d, d)) * 0.3
          for i in range(num_stages)]

    def stage(i):
        def f(x, p=None):
            w = ws[i] if p is None else p
            return jnp.tanh(x @ w)
        return f

    return ws, [stage(i) for i in range(num_stages)]


def test_forward_pipeline_matches_sequential():
    d, num_stages, num_micro = 8, 3, 5
    ws, fns = _mk_stages(num_stages, d, KEY)
    mb = [jax.random.normal(jax.random.fold_in(KEY, 100 + m), (2, d))
          for m in range(num_micro)]
    runner = PipelineRunner([lambda x, f=f: f(x) for f in fns], num_micro)
    outs = runner.forward(mb)
    for m in range(num_micro):
        ref = mb[m]
        for f in fns:
            ref = f(ref)
        np.testing.assert_allclose(np.asarray(outs[m]), np.asarray(ref),
                                   atol=1e-6)
    assert runner.stats["fwd"] == num_stages * num_micro
    assert runner.stats["reclaimed"] > 0  # buffers actually recycled
    # peak live buffers bounded by window + slack, not by num_micro
    assert runner.stats["peak_slots"] <= runner.window + 2


def test_train_grads_match_non_pipelined():
    d, num_stages, num_micro = 6, 3, 4
    ws, _ = _mk_stages(num_stages, d, KEY)

    def stage_fn(i):
        return lambda x, p: jnp.tanh(x @ p)

    def loss_fn(y):
        return jnp.mean(y ** 2)

    mb = [jax.random.normal(jax.random.fold_in(KEY, 200 + m), (2, d))
          for m in range(num_micro)]
    runner = PipelineRunner([stage_fn(i) for i in range(num_stages)], num_micro)
    grads, loss = runner.train_grads(ws, mb, loss_fn)

    def full_loss(params):
        tot = 0.0
        for x in mb:
            for p in params:
                x = jnp.tanh(x @ p)
            tot = tot + loss_fn(x)
        return tot  # sum over microbatches (grads accumulate by sum)

    ref_grads = jax.grad(full_loss)(ws)
    for g, r in zip(grads, ref_grads):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=1e-5, rtol=1e-5)
    assert runner.stats["bwd"] == num_stages * num_micro


def test_window_violation_is_caught():
    """Consuming a buffer after the window slid past it raises (the UAF the
    CMP window prevents is *detected*, not silently read)."""
    d = 4
    fns = [lambda x: x + 1, lambda x: x * 2]
    runner = PipelineRunner([lambda x, f=f: f(x) for f in fns], num_micro=2)
    runner._produce(0, 0, jnp.zeros((1, d)))
    runner._produce(0, 1, jnp.ones((1, d)))
    runner._consume(0, 0)
    # force the window far forward: everything claimed becomes reclaimable
    import repro.core.slotpool as sp
    runner.pools[0] = sp.advance(runner.pools[0], runner.pools[0].enq_cycle + 100)
    runner.pools[0], _ = sp.reclaim_retired(runner.pools[0], 0)
    # slot of micro 0 was recycled; re-reading it must be caught
    with pytest.raises(AssertionError, match="UAF"):
        runner.slot_of[0][0] = runner.slot_of[0][0]  # same slot
        runner._consume(0, 0)
