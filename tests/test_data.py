"""Data pipeline: determinism, exact resume, straggler tolerance, bounded
queue memory (the CMP window at the input layer)."""

import time

import numpy as np

from repro.data.pipeline import DataPipeline, synth_batch


def test_batch_content_is_pure_function_of_id():
    a = synth_batch(7, 42, 4, 32, 1000)
    b = synth_batch(7, 42, 4, 32, 1000)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = synth_batch(7, 43, 4, 32, 1000)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_delivers_and_resumes():
    pipe = DataPipeline(batch=2, seq=16, vocab=500, num_producers=2, window=16)
    it = iter(pipe)
    seen = [next(it)["batch_id"] for _ in range(10)]
    state = pipe.state()
    pipe.close()
    assert len(set(seen)) == 10
    # resume: new pipeline starts at the saved frontier; regenerated ids do
    # not regress below the consumed frontier per producer
    pipe2 = DataPipeline.from_state(state, batch=2, seq=16, vocab=500, window=16)
    it2 = iter(pipe2)
    seen2 = [next(it2)["batch_id"] for _ in range(6)]
    pipe2.close()
    per_prod_max = {}
    for bid in seen:
        p = bid % 2
        per_prod_max[p] = max(per_prod_max.get(p, -1), bid)
    for bid in seen2:
        assert bid > per_prod_max.get(bid % 2, -1) - 2 * 2, (
            "resumed pipeline re-delivered far-past batches")


def test_from_state_dedupes_num_producers_kwarg():
    """Callers that also pass num_producers explicitly must not collide with
    the checkpoint's cursor vector: matching values dedupe, a mismatch is a
    loud config error (resharding would remap every batch_id)."""
    state = {"cursors": [4, 5], "seed": 7}
    pipe = DataPipeline.from_state(state, batch=1, seq=8, vocab=50,
                                   num_producers=2, window=8)
    assert pipe.num_producers == 2
    assert pipe.state() == state  # round-trip invariant
    pipe.close()
    try:
        DataPipeline.from_state(state, batch=1, seq=8, vocab=50,
                                num_producers=3, window=8)
        assert False, "mismatched num_producers must raise"
    except ValueError as e:
        assert "cursors" in str(e)


def test_stalled_producer_does_not_block_consumer():
    pipe = DataPipeline(batch=2, seq=8, vocab=100, num_producers=2, window=8)
    pipe.start()
    time.sleep(0.05)
    pipe.stall_producer(0, seconds=0.5)  # producer 0 stalls
    it = iter(pipe)
    t0 = time.time()
    got = [next(it)["batch_id"] for _ in range(8)]
    elapsed = time.time() - t0
    pipe.close()
    assert elapsed < 0.5, "consumer was blocked by the stalled producer"
    assert len(got) == 8


def test_queue_memory_is_bounded():
    pipe = DataPipeline(batch=1, seq=8, vocab=100, num_producers=2,
                        window=8, max_queue_batches=12)
    pipe.start()
    time.sleep(0.3)  # producers run, consumer absent
    live = pipe.queue.live_nodes()
    pipe.close()
    # bounded by backpressure + window, not by elapsed time
    assert live < 12 + 8 + 16, f"unbounded queue growth: {live} nodes"
