"""Property tests for tenant routing (CI slow lane; hypothesis is not a
runtime dep, so the whole module skips where it is missing).

The invariants that carry the tenant fabric's exactness argument:

* routing is a pure function of (tenant, num_groups, salt) — identical
  across processes and across a state()/from_state() round trip, for any
  hashable tenant spelling;
* every routed class name is on the declared grid and parses back to the
  (group, tier) that produced it;
* the quota ledger never goes negative and conserves host totals across
  any charge/credit/rehost interleaving.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.sched import (TIERS, TenantMap, TenantQuotaLedger,  # noqa: E402
                         group_class_name, split_class_name)

pytestmark = pytest.mark.slow

_tenants = (st.text(max_size=24) | st.integers(-2**40, 2**40)
            | st.tuples(st.text(max_size=6), st.integers(0, 99)))


@given(_tenants, st.integers(1, 512), st.integers(0, 2**32))
@settings(max_examples=300, deadline=None)
def test_routing_survives_state_roundtrip(tenant, groups, salt):
    m = TenantMap(num_tenants=10**6, num_groups=groups, salt=salt)
    m2 = TenantMap.from_state(m.state())
    gid = m.group_of(tenant)
    assert 0 <= gid < groups
    assert m2.group_of(tenant) == gid
    for tier in TIERS:
        name = m.class_of(tenant, tier)
        assert name == m2.class_of(tenant, tier) == group_class_name(gid, tier)
        assert split_class_name(name)[1] == tier


@given(st.lists(_tenants, min_size=1, max_size=64), st.integers(1, 64))
@settings(max_examples=200, deadline=None)
def test_grid_is_bounded_by_groups_not_tenants(tenants, groups):
    m = TenantMap(num_tenants=10**9, num_groups=groups)
    names = {m.class_of(t, TIERS[0]) for t in tenants}
    assert names <= set(m.class_names())
    assert len(m.class_names()) == groups * len(TIERS)


@given(st.integers(1, 8),
       st.lists(st.tuples(st.integers(0, 15), st.integers(0, 7),
                          st.integers(0, 20), st.booleans()),
                max_size=64))
@settings(max_examples=200, deadline=None)
def test_ledger_conserves_and_never_goes_negative(hosts, ops):
    led = TenantQuotaLedger(per_tenant=30, total=64, num_hosts=hosts)
    outstanding = {}
    for tid, host, pages, is_credit in ops:
        key, h = f"t{tid}", host % led.num_hosts
        if is_credit:
            take = min(pages, outstanding.get((key, h), 0))
            led.credit(key, h, take)
            outstanding[(key, h)] = outstanding.get((key, h), 0) - take
        elif led.charge(key, h, pages):
            outstanding[(key, h)] = outstanding.get((key, h), 0) + pages
        assert led.used(key) >= 0
        assert all(0 <= led.host_used(i) <= led.host_caps[i]
                   for i in range(led.num_hosts))
    assert sum(led.host_used(i) for i in range(led.num_hosts)) == \
        sum(outstanding.values())
    led.rehost(max(1, hosts // 2))
    assert sum(led.host_caps) == 64
    assert sum(led.host_used(i) for i in range(led.num_hosts)) == \
        sum(outstanding.values())
