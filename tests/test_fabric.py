"""The fabric session API (DESIGN.md §10): FabricConfig validation + JSON
round-trip, scheduler-only and serving sessions, live resize FIFO
preservation (incl. under concurrent producers), snapshot/restore through
Fabric, the in-loop checkpoint cadence, the versioned StatsView, and the
fail-loud removal of the pre-Fabric compat shims."""

import argparse
import json
import threading

import pytest

from repro.fabric import (ClassSpec, Fabric, FabricConfig, FabricConfigError,
                          StatsView, tiered_classes)

# ---------------------------------------------------------------------------
# FabricConfig: validation + JSON round trip
# ---------------------------------------------------------------------------


def test_config_rejects_cross_class_policy_with_single_class():
    with pytest.raises(FabricConfigError, match="single class"):
        FabricConfig(policy="wfq")
    with pytest.raises(FabricConfigError, match="single class"):
        FabricConfig(policy="fifo", classes=(ClassSpec("only"),))


def test_config_rejects_checkpoint_cadence_without_dir():
    with pytest.raises(FabricConfigError, match="nowhere to write"):
        FabricConfig(checkpoint_every_n_steps=5)


def test_config_rejects_frontier_dir_shadowing_params_dir():
    with pytest.raises(FabricConfigError, match="must differ"):
        FabricConfig(arch="glm4-9b", params_dir="/tmp/x",
                     checkpoint_dir="/tmp/x")


def test_config_rejects_bad_replica_and_seat_counts():
    with pytest.raises(FabricConfigError, match="seat per class"):
        FabricConfig(shards_per_class=2, replicas=4)
    with pytest.raises(FabricConfigError, match="max_replicas"):
        FabricConfig(replicas=4, max_replicas=2)
    with pytest.raises(FabricConfigError, match="replicas must be >= 1"):
        FabricConfig(replicas=0)


def test_config_rejects_bad_classes_and_budgets():
    with pytest.raises(FabricConfigError, match="unique name"):
        FabricConfig(classes=(ClassSpec("a"), ClassSpec("a", priority=1)))
    with pytest.raises(FabricConfigError, match="weight"):
        FabricConfig(classes=(ClassSpec("a", weight=0.0),))
    with pytest.raises(FabricConfigError, match="at least one class"):
        FabricConfig(classes=())
    with pytest.raises(FabricConfigError, match="unknown policy"):
        FabricConfig(policy="round-robin")
    with pytest.raises(FabricConfigError, match="lane budget"):
        FabricConfig(arch="glm4-9b", replicas=4, max_batch=2, num_pages=64)
    with pytest.raises(FabricConfigError, match="params_dir without arch"):
        FabricConfig(params_dir="/tmp/params")


def test_config_json_roundtrip_exact():
    cfg = FabricConfig(
        classes=tiered_classes(background_window=6),
        replicas=2, max_replicas=4, shards_per_class=4, policy="wfq",
        queue_window=512, drain_k=6, arch="yi_6b", max_batch=8,
        page_size=8, num_pages=64, max_seq=64, kv_window=3,
        checkpoint_dir="/tmp/ck", checkpoint_every_n_steps=4)
    wire = json.loads(json.dumps(cfg.to_json()))
    assert FabricConfig.from_json(wire) == cfg
    with pytest.raises(FabricConfigError, match="unknown keys"):
        FabricConfig.from_json({**wire, "warp_factor": 9})


def test_serve_flag_combinations_fail_actionably():
    """ISSUE satellite: flag combos the old driver accepted silently now
    raise from FabricConfig with the fix named."""
    from repro.launch.serve import config_from_args

    def ns(**kw):
        base = dict(arch="glm4-9b", smoke=True, max_batch=4, page_size=16,
                    num_pages=128, window=4, ckpt_dir=None, multitenant=False,
                    policy="strict", replicas=1, checkpoint_dir=None,
                    checkpoint_every=None)
        base.update(kw)
        return argparse.Namespace(**base)

    with pytest.raises(FabricConfigError, match="--multitenant"):
        config_from_args(ns(policy="wfq"))  # policy without classes
    with pytest.raises(FabricConfigError, match="must differ"):
        config_from_args(ns(checkpoint_dir="/tmp/d", ckpt_dir="/tmp/d"))
    with pytest.raises(FabricConfigError, match="nowhere to write"):
        config_from_args(ns(checkpoint_every=8))
    # --checkpoint-dir without --replicas used to be silently ignored; under
    # the fabric it is simply valid (a 1-replica group checkpoints too)
    cfg = config_from_args(ns(checkpoint_dir="/tmp/d"))
    assert cfg.checkpoint_dir == "/tmp/d" and cfg.replicas == 1


# ---------------------------------------------------------------------------
# scheduler-only sessions: delivery, resize, snapshot/restore
# ---------------------------------------------------------------------------


def _two_class_config(**kw):
    base = dict(classes=(ClassSpec("hi", priority=2, weight=4.0),
                         ClassSpec("lo", priority=0, weight=1.0)),
                shards_per_class=4, replicas=1, max_replicas=4,
                queue_window=4096, drain_k=6)
    base.update(kw)
    return FabricConfig(**base)


def test_schedonly_fabric_exact_class_fifo():
    fab = Fabric.open(_two_class_config())
    fab.submit_many([("hi", i) for i in range(100)], qclass="hi")
    fab.submit_many([("lo", i) for i in range(100)], qclass="lo")
    streams = {"hi": [], "lo": []}
    for v, env in fab.drain():
        streams[v.name].append(env.seq)
    # single replica: per-class delivery is globally the dense cycle order
    assert streams["hi"] == list(range(100))
    assert streams["lo"] == list(range(100))
    assert fab.pending() == 0


def _run_resized_wave(resize_plan, *, per_class=240, shards=4,
                      concurrent=True):
    """Run a 2-class wave (concurrent producer threads) through a fabric,
    resizing at the planned steps; returns per-class delivered seq
    streams in wall order."""
    fab = Fabric.open(_two_class_config(shards_per_class=shards))
    names = ("hi", "lo")

    def produce(name):
        for i in range(per_class):
            fab.submit((name, i), qclass=name)

    ts = [threading.Thread(target=produce, args=(n,)) for n in names]
    if concurrent:
        for t in ts:
            t.start()
    else:
        for t in ts:
            t.run()
    streams = {n: [] for n in names}
    got_total, step = 0, 0
    while got_total < per_class * len(names):
        step += 1
        assert step < 100000, "fabric did not drain"
        if step in resize_plan:
            fab.resize(resize_plan[step])
        for v, env in fab.step():
            streams[v.name].append(env.seq)
            got_total += 1
    if concurrent:
        for t in ts:
            t.join()
    fab.close()
    return streams


def test_resize_1_4_2_preserves_exact_fifo_under_concurrent_producers():
    """ISSUE acceptance: Fabric.resize(1->4->2) under concurrent producers
    never inverts per-class FIFO order — per class every shard cycle-run is
    delivered in exactly the order a no-resize run delivers it, and the
    merge is exactly 0..n-1 (nothing lost, duplicated, or reordered)."""
    per_class, shards = 240, 4
    base = _run_resized_wave({}, per_class=per_class, shards=shards,
                             concurrent=False)
    chaos = _run_resized_wave({4: 4, 9: 2}, per_class=per_class,
                              shards=shards)
    for name in ("hi", "lo"):
        assert sorted(chaos[name]) == list(range(per_class)), \
            f"{name}: lost/duplicated seats across resizes"
        for s in range(shards):
            run_resized = [q for q in chaos[name] if q % shards == s]
            run_base = [q for q in base[name] if q % shards == s]
            assert run_resized == run_base, \
                f"{name} run {s}: delivery diverged from the no-resize run"


def test_resize_bounds_enforced():
    fab = Fabric.open(_two_class_config(max_replicas=2, shards_per_class=2))
    with pytest.raises(FabricConfigError, match="max_replicas"):
        fab.resize(3)
    with pytest.raises(FabricConfigError, match="max_replicas"):
        fab.resize(0)
    fab.resize(2)
    assert fab.num_replicas == 2


def test_resize_carries_policy_held_heads():
    """A fifo-merge policy buffers one head per class between drains; a
    resize must carry those to the new seat owners (as requeued seats) or
    the tenants would vanish."""
    cfg = FabricConfig(classes=(ClassSpec("a"), ClassSpec("b")),
                       shards_per_class=2, replicas=2, max_replicas=2,
                       policy="fifo", queue_window=256, drain_k=1)
    fab = Fabric.open(cfg)
    for i in range(10):
        fab.submit(("a", i), qclass="a")
        fab.submit(("b", i), qclass="b")
    delivered = [(v.name, e.seq) for v, e in fab.step()]  # k=1: heads held
    assert sum(r.policy.held() for r in fab.replicas) > 0
    fab.resize(1)
    rounds = 0
    while fab.pending() > 0 and rounds < 1000:
        rounds += 1
        delivered += [(v.name, e.seq) for v, e in fab.step()]
    for name in ("a", "b"):
        seqs = sorted(s for n, s in delivered if n == name)
        assert seqs == list(range(10)), \
            f"{name}: policy-held head lost across resize"
        # a carried head is a relocation, not a preemption: the requeued
        # telemetry must not be inflated by the resize
        assert fab.stats_view().classes[name].requeued == 0


def test_snapshot_restore_through_fabric_is_equivalent():
    """ISSUE satellite: restoring a Fabric from its JSON snapshot delivers
    exactly what the uninterrupted session would have delivered."""
    def build():
        fab = Fabric.open(_two_class_config(replicas=2, shards_per_class=2,
                                            max_replicas=2))
        for name in ("hi", "lo"):
            fab.submit_many([(name, i) for i in range(60)], qclass=name)
        prefix = [(v.name, e.seq) for _ in range(3)
                  for v, e in fab.step()]
        return fab, prefix

    fab_a, prefix_a = build()
    expected = prefix_a + [(v.name, e.seq) for v, e in fab_a.drain()]

    fab_b, prefix_b = build()
    assert prefix_b == prefix_a  # deterministic single-thread prefix
    snap = json.loads(json.dumps(fab_b.snapshot()))
    fab_c = Fabric.from_snapshot(snap)
    assert fab_c.num_replicas == 2
    continued = prefix_b + [(v.name, e.seq) for v, e in fab_c.drain()]
    assert continued == expected, "restored delivery diverged"


def test_restore_accepts_safe_overrides_and_rejects_structural():
    fab = Fabric.open(_two_class_config())
    fab.submit_many([("hi", i) for i in range(20)], qclass="hi")
    fab.step()
    snap = json.loads(json.dumps(fab.snapshot()))
    # safe knobs (rebuilt fresh on restore) may follow the caller's flags
    fab2 = Fabric.from_snapshot(snap, overrides={"drain_k": 3,
                                                 "min_steal": 2})
    assert fab2.config.drain_k == 3 and fab2.config.min_steal == 2
    assert sorted(e.seq for _, e in fab2.drain()) == sorted(
        e.seq for _, e in fab.drain())
    # the seat structure IS the resume state: overriding it must refuse
    with pytest.raises(FabricConfigError, match="seat structure"):
        Fabric.from_snapshot(snap, overrides={"replicas": 4})
    # an invalid override combination fails validation, not silently
    with pytest.raises(FabricConfigError, match="unknown policy"):
        Fabric.from_snapshot(snap, overrides={"policy": "nope"})


def test_restore_from_dir_structural_vs_policy_overrides(tmp_path):
    """ISSUE satellite: Fabric.restore refuses structural overrides
    (shards_per_class, the class set, replicas) but accepts policy/cadence
    — and a snapshot written under LocalTransport restores under
    SimHostTransport (the transport/host layout is a safe override)."""
    ck = str(tmp_path / "frontier")
    fab = Fabric.open(_two_class_config(replicas=2, checkpoint_dir=ck))
    for name in ("hi", "lo"):
        fab.submit_many([(name, i) for i in range(50)], qclass=name)
    prefix = [(v.name, e.seq) for v, e in fab.step()]
    fab.checkpoint()
    del fab  # crash: the checkpoint is the recovery truth

    for bad in ({"shards_per_class": 8}, {"replicas": 4},
                {"classes": (ClassSpec("other"),)}):
        with pytest.raises(FabricConfigError, match="seat structure"):
            Fabric.restore(ck, overrides=bad)
    with pytest.raises(FabricConfigError, match="single-host"):
        Fabric.restore(ck, overrides={"hosts": 2})  # needs the sim transport

    fab2 = Fabric.restore(ck, overrides={
        "transport": "sim", "hosts": 2, "policy": "wfq", "drain_k": 4,
        "transport_seed": 3, "checkpoint_every_n_steps": 7})
    assert fab2.transport.kind == "sim" and fab2.transport.num_hosts == 2
    assert fab2.config.policy == "wfq"
    assert fab2.config.checkpoint_every_n_steps == 7
    assert fab2.config.shards_per_class == 4  # structure from the snapshot
    streams = {"hi": [s for n, s in prefix if n == "hi"],
               "lo": [s for n, s in prefix if n == "lo"]}
    for v, e in fab2.drain():
        streams[v.name].append(e.seq)
    for name in ("hi", "lo"):
        assert sorted(streams[name]) == list(range(50)), \
            f"{name}: seats lost restoring local->sim"
    fab2.close()


def test_stats_slo_view():
    cfg = FabricConfig(
        classes=(ClassSpec("fast", priority=1, slo_ms=1e7),
                 ClassSpec("slow", priority=0, slo_ms=1e-9),
                 ClassSpec("untargeted", priority=0, weight=2.0)),
        shards_per_class=1)
    fab = Fabric.open(cfg)
    for name in ("fast", "slow", "untargeted"):
        fab.submit_many([(name, i) for i in range(10)], qclass=name)
    fab.drain()
    slo = fab.stats_view().slo
    assert slo["fast"].target_ms == 1e7 and slo["fast"].ok is True
    assert slo["fast"].headroom_ms > 0
    assert slo["slow"].ok is False and slo["slow"].headroom_ms < 0
    assert slo["untargeted"].target_ms is None
    assert slo["untargeted"].ok is None
    assert slo["untargeted"].admit_p99_ms is not None


def test_stats_survive_resize():
    fab = Fabric.open(_two_class_config())
    fab.submit_many([("hi", i) for i in range(40)], qclass="hi")
    for _ in range(3):
        fab.step()
    before = fab.stats_view().classes["hi"].delivered
    assert before > 0
    fab.resize(4)
    after = fab.stats_view().classes["hi"]
    assert after.delivered >= before, "delivered counter reset by resize"
    assert after.admit_p99_ms is not None, "latency reservoir lost"
    fab.drain()
    assert fab.stats_view().classes["hi"].delivered == 40


def test_closed_fabric_refuses_work():
    fab = Fabric.open(_two_class_config())
    fab.close()
    with pytest.raises(FabricConfigError, match="closed"):
        fab.submit(("hi", 0), qclass="hi")
    with pytest.raises(FabricConfigError, match="closed"):
        fab.step()


def test_schedonly_cadence_checkpoint_restores_exact(tmp_path):
    """Cadence snapshots land through the async writer; a fabric killed
    mid-run restores from the latest one with every seat exact."""
    ck = str(tmp_path / "frontier")
    cfg = _two_class_config(checkpoint_dir=ck, checkpoint_every_n_steps=2)
    fab = Fabric.open(cfg)
    for name in ("hi", "lo"):
        fab.submit_many([(name, i) for i in range(80)], qclass=name)
    streams = {"hi": [], "lo": []}
    for _ in range(4):  # cadence fires at steps 2 and 4
        for v, env in fab.step():
            streams[v.name].append(env.seq)
    fab.flush_checkpoints()
    assert fab.stats_view().checkpoint["written"] == [2, 4]
    del fab  # killed: no close(), the cadence snapshot is the recovery truth

    fab2 = Fabric.restore(ck)
    assert fab2.step_count == 4
    # replay what the killed fabric delivered after its last checkpoint:
    # those seats were consumed pre-kill, so the restored run re-delivers
    # nothing before the step-4 frontier and everything after it exactly
    for v, env in fab2.drain():
        streams[v.name].append(env.seq)
    for name in ("hi", "lo"):
        assert sorted(streams[name]) == list(range(80))
        assert streams[name] == sorted(streams[name])  # 1 replica: dense
    fab2.close()


# ---------------------------------------------------------------------------
# serving sessions (smoke model): cadence restore, resize, compat shims
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def model():
    import jax
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("yi_6b", smoke=True)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _serving_config(**kw):
    base = dict(classes=(ClassSpec("hi", priority=1, weight=4.0),
                         ClassSpec("lo", priority=0, weight=1.0)),
                shards_per_class=2, replicas=1, max_replicas=2,
                arch="yi_6b", max_batch=4, page_size=8, num_pages=32,
                kv_window=2, max_seq=64, queue_window=64)
    base.update(kw)
    return FabricConfig(**base)


def test_serving_fabric_killed_midrun_restores_from_cadence(model, tmp_path):
    """ISSUE acceptance: a serving fabric killed mid-run restores from its
    cadence checkpoint with every tenant at its exact seat — nothing lost,
    nothing served twice, uids never reused."""
    mcfg, params = model
    ck = str(tmp_path / "frontier")
    cfg = _serving_config(replicas=2, checkpoint_dir=ck,
                          checkpoint_every_n_steps=2)
    fab = Fabric.open(cfg, params=params, model_cfg=mcfg)
    uids = [fab.submit([i + 1, 2, 3], max_new_tokens=3, qclass="hi")
            for i in range(4)]
    uids += fab.submit_many([[9, 9 + i] for i in range(4)],
                            max_new_tokens=3, qclass="lo")
    fab.step()
    fab.step()  # cadence fires
    fab.flush_checkpoints()
    done_before = dict(fab.completed)
    del fab  # crash: laned requests and staged claims die with the group

    fab2 = Fabric.restore(ck, params=params, model_cfg=mcfg)
    assert fab2.step_count == 2 and fab2.num_replicas == 2
    done_after = fab2.drain(max_steps=300)
    assert not (set(done_before) & set(done_after)), "served twice"
    missing = [u for u in uids
               if u not in done_before and u not in done_after]
    assert not missing, f"lost across kill+restore: {missing}"
    # uid continuity across the restore
    assert fab2.submit([3, 3], max_new_tokens=2, qclass="hi") not in uids
    fab2.drain(max_steps=100)
    fab2.close()


def test_serving_fabric_resize_under_load(model):
    """Live elasticity through the engine layer: resize 1->2 mid-wave
    re-partitions lanes and pages, preempted lanes keep their exact seats,
    and every request is served exactly once."""
    mcfg, params = model
    fab = Fabric.open(_serving_config(), params=params, model_cfg=mcfg)
    uids = fab.submit_many([[i + 1, 2] for i in range(6)],
                           max_new_tokens=3, qclass="hi")
    fab.step()
    assert len(fab.engines) == 1
    fab.resize(2)
    assert fab.num_replicas == 2 and len(fab.engines) == 2
    assert [e.max_batch for e in fab.engines] == [2, 2]
    assert sum(e.pool.num_pages for e in fab.engines) == 32
    done = fab.drain(max_steps=300)
    assert set(done) >= set(uids), "request lost across resize"
    assert len(done) == len(set(done)), "request served twice"
    fab.close()


def test_serving_fabric_multihost_host_loss(model):
    """Serving over 2 simulated hosts: kill one mid-wave — its lanes
    preempt to exact seats, its engines stop, survivors steal the seats —
    and every request is still served exactly once."""
    mcfg, params = model
    fab = Fabric.open(
        _serving_config(replicas=2, transport="sim", hosts=2),
        params=params, model_cfg=mcfg)
    uids = fab.submit_many([[i + 1, 2] for i in range(6)],
                           max_new_tokens=3, qclass="hi")
    fab.step()
    moved = fab.fail_host(1)
    assert moved > 0
    assert not fab.replicas[1].alive
    done = fab.drain(max_steps=300)
    assert set(done) >= set(uids), "request lost across host failure"
    assert len(done) == len(set(done)), "request served twice"
    assert fab.stats_view().transport["dead_hosts"] == [1]
    fab.close()


def test_compat_shims_removed_fail_loudly():
    """ISSUE satellite: the PR-5 deprecation shims are gone — touching any
    of them raises with the replacement named, instead of a warning."""
    import repro.fabric as fabric_pkg
    for gone in ("compat", "open_replica_set", "open_engine",
                 "open_replica_group"):
        with pytest.raises(AttributeError, match="Fabric.open"):
            getattr(fabric_pkg, gone)
    # the module file itself is gone, not just unexported
    with pytest.raises(ImportError):
        import repro.fabric.compat  # noqa: F401


# ---------------------------------------------------------------------------
# versioned StatsView (ISSUE satellite): exact round trip, one-time warning
# ---------------------------------------------------------------------------


def _busy_fabric():
    fab = Fabric.open(_two_class_config(replicas=2, max_replicas=4,
                                        transport="sim", hosts=2))
    for name in ("hi", "lo"):
        fab.submit_many([(name, i) for i in range(30)], qclass=name)
    fab.step()
    fab.resize(3)
    fab.step()
    return fab


def test_stats_view_json_roundtrip_exact():
    fab = _busy_fabric()
    view = fab.stats_view()
    assert isinstance(view, StatsView) and view.schema_version == 1
    assert view.num_replicas == 3 and view.resizes == 1
    assert view.classes["hi"].delivered > 0
    # exact round trip, including through a JSON wire encode/decode
    assert StatsView.from_json(view.to_json()) == view
    wire = json.loads(json.dumps(view.to_json()))
    assert StatsView.from_json(wire) == view
    with pytest.raises(ValueError, match="schema_version"):
        StatsView.from_json({**view.to_json(), "schema_version": 99})
    fab.close()


def test_stats_dict_alias_warns_exactly_once():
    """The raw-dict ``stats()`` is a deprecated alias for
    ``stats_view().to_json()`` and warns once per process, not per call."""
    import warnings

    import repro.fabric.session as session
    fab = _busy_fabric()
    session._STATS_DICT_WARNED = False
    try:
        with pytest.warns(DeprecationWarning, match="stats_view"):
            first = fab.stats()
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # a second warning would raise
            second = fab.stats()
    finally:
        session._STATS_DICT_WARNED = True  # leave quiet for other tests
    assert first == fab.stats_view().to_json() == second


# ---------------------------------------------------------------------------
# device-resident admission through the fabric (ISSUE 6, DESIGN.md §12)
# ---------------------------------------------------------------------------


def test_config_validates_device_admission():
    with pytest.raises(ValueError, match="device_admission"):
        _serving_config(device_admission="yes")
    with pytest.raises(ValueError, match="device_admission"):
        FabricConfig(classes=(ClassSpec("a"),), device_admission=True)
    for ok in (True, False, "auto"):
        cfg = _serving_config(device_admission=ok)
        assert cfg.device_admission == ok
    # round-trips through JSON like every other field
    cfg = _serving_config(device_admission=True)
    assert FabricConfig.from_json(cfg.to_json()).device_admission is True


def test_serving_fabric_resize_under_load_device_admission(model):
    """ISSUE 6 acceptance: live resize with admission routed through the
    device ring — ring-resident entries flush back to their exact seats
    before lanes move, so exactly-once + no-loss hold unchanged."""
    mcfg, params = model
    fab = Fabric.open(_serving_config(device_admission=True),
                      params=params, model_cfg=mcfg)
    uids = fab.submit_many([[i + 1, 2] for i in range(8)],
                           max_new_tokens=3, qclass="hi")
    fab.step()
    fab.resize(2)
    assert fab.num_replicas == 2
    done = fab.drain(max_steps=300)
    assert set(done) >= set(uids), "request lost across resize"
    assert len(done) == len(set(done)), "request served twice"
    fab.close()


def test_serving_fabric_multihost_host_loss_device_admission(model):
    """ISSUE 6 acceptance: kill a host mid-wave with the device ring on —
    the dead host's ring entries requeue at exact seats and survivors
    serve everything exactly once."""
    mcfg, params = model
    fab = Fabric.open(
        _serving_config(replicas=2, transport="sim", hosts=2,
                        device_admission=True),
        params=params, model_cfg=mcfg)
    uids = fab.submit_many([[i + 1, 2] for i in range(8)],
                           max_new_tokens=3, qclass="hi")
    fab.step()
    moved = fab.fail_host(1)
    assert moved > 0
    done = fab.drain(max_steps=300)
    assert set(done) >= set(uids), "request lost across host failure"
    assert len(done) == len(set(done)), "request served twice"
    fab.close()


def test_snapshot_restore_with_device_admission(model):
    """sched_state() flushes the ring first, so a snapshot taken mid-wave
    with device admission on restores to the exact same seats."""
    mcfg, params = model
    fab = Fabric.open(_serving_config(device_admission=True),
                      params=params, model_cfg=mcfg)
    uids = fab.submit_many([[i + 1, 3] for i in range(6)],
                           max_new_tokens=3, qclass="lo")
    fab.step()
    snap = fab.snapshot()
    done_a = fab.drain(max_steps=300)
    fab.close()

    fab2 = Fabric.from_snapshot(snap, params=params, model_cfg=mcfg)
    done_b = fab2.drain(max_steps=300)
    fab2.close()
    # both futures serve every outstanding request exactly once
    for done in (done_a, done_b):
        assert set(done) | set(fab.completed if done is done_a
                               else fab2.completed) >= set(uids)
        assert len(done) == len(set(done))
