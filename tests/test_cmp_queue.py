"""CMP queue semantics: FIFO, MPMC safety, bounded reclamation, stall
recovery, atomic-op counts (paper §3.3/§3.5/§3.6/§3.7)."""

import random
import threading
import time

import pytest

from repro.core import CMPQueue
from repro.core.atomics import op_counts, reset_op_counts, set_chaos_hook
from repro.core.baselines import MSQueue, MutexQueue, SegmentedQueue
from repro.core.window import compute_window, max_reclaim_delay_cycles


def test_fifo_single_thread():
    q = CMPQueue(window=32, reclaim_period=8, min_batch=2)
    for i in range(500):
        q.enqueue(i)
    assert [q.dequeue() for _ in range(500)] == list(range(500))
    assert q.dequeue() is None


def test_fifo_interleaved_enq_deq():
    q = CMPQueue(window=16, reclaim_period=4, min_batch=1)
    out = []
    n = 0
    for round_ in range(50):
        for _ in range(random.Random(round_).randint(1, 10)):
            q.enqueue(n)
            n += 1
        for _ in range(random.Random(round_ + 999).randint(0, 8)):
            d = q.dequeue()
            if d is not None:
                out.append(d)
    while (d := q.dequeue()) is not None:
        out.append(d)
    assert out == list(range(n))


def test_mpmc_no_loss_no_duplication():
    q = CMPQueue(window=128, reclaim_period=16, min_batch=4)
    per, P, C = 1500, 4, 4
    consumed, lock = [], threading.Lock()
    done = threading.Event()

    def prod(pid):
        for i in range(per):
            q.enqueue((pid, i))

    def cons():
        while not done.is_set():
            d = q.dequeue()
            if d is None:
                time.sleep(0)
                continue
            with lock:
                consumed.append(d)
                if len(consumed) == per * P:
                    done.set()

    ts = [threading.Thread(target=prod, args=(p,)) for p in range(P)]
    ts += [threading.Thread(target=cons) for _ in range(C)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert len(consumed) == per * P
    assert len(set(consumed)) == per * P  # no duplicates
    # NOTE: with C>1 the post-claim append order is not the claim
    # linearization order, so FIFO is asserted in the 1-consumer test below.


def test_mpmc_single_consumer_fifo():
    """Multi-producer, ONE consumer: observed order == claim order, so the
    per-producer FIFO (earliest-claim) invariant is directly checkable."""
    q = CMPQueue(window=128, reclaim_period=16, min_batch=4)
    per, P = 2000, 4
    consumed = []

    def prod(pid):
        for i in range(per):
            q.enqueue((pid, i))

    ts = [threading.Thread(target=prod, args=(p,)) for p in range(P)]
    for t in ts:
        t.start()
    while len(consumed) < per * P:
        d = q.dequeue()
        if d is not None:
            consumed.append(d)
    for t in ts:
        t.join()
    for p in range(P):
        seq = [i for (pid, i) in consumed if pid == p]
        assert seq == sorted(seq), f"producer {p} order violated"


def test_reclamation_is_bounded():
    """Nodes recycle within W + N cycles; memory stays bounded under churn."""
    w, n = 64, 16
    q = CMPQueue(window=w, reclaim_period=n, min_batch=1)
    for i in range(5000):
        q.enqueue(i)
        assert q.dequeue() == i
    # live list length must be O(W + N), not O(operations)
    assert q.live_nodes() < w + 4 * n + 16
    assert q.stats["reclaimed"] > 4000


def test_stalled_consumer_does_not_block_reclamation():
    """A thread that claimed a node then died delays nothing (paper §3.6)."""
    q = CMPQueue(window=8, reclaim_period=4, min_batch=1)
    q.enqueue("poison")
    # simulate a consumer that claims and stalls forever: claim manually
    node = q.head.load().next.load()
    assert node.state.cas(1, 2)  # AVAILABLE -> CLAIMED, then "crash"
    for i in range(200):
        q.enqueue(i)
        q.dequeue()
    # the stalled node's slot was reclaimed once outside the window
    assert q.live_nodes() < 64


def test_window_protects_recent_nodes():
    q = CMPQueue(window=1000, reclaim_period=1, min_batch=1)
    for i in range(50):
        q.enqueue(i)
        q.dequeue()
    # all 50 cycles are within the window: nothing may be reclaimed
    assert q.stats["reclaimed"] == 0


def test_atomic_op_counts_match_paper():
    """Paper: enqueue 3-5 atomics, dequeue 4-9 in the common case."""
    q = CMPQueue(window=64, reclaim_period=10**9)  # no reclaim noise
    q.enqueue(0)  # warm the structure
    q.dequeue()
    reset_op_counts()
    for i in range(100):
        q.enqueue(i)
    enq_ops = sum(op_counts().values()) / 100
    reset_op_counts()
    for _ in range(100):
        q.dequeue()
    deq_ops = sum(op_counts().values()) / 100
    # pool get/put adds ~4 atomics; allow the paper range + pool overhead
    assert enq_ops <= 5 + 4.5, enq_ops
    assert deq_ops <= 9 + 4.5, deq_ops


@pytest.mark.slow
def test_chaos_interleaving_preserves_safety():
    """Random delays at atomic boundaries: still no loss/duplication."""
    rng = random.Random(0)

    def hook(kind):
        if rng.random() < 0.01:
            time.sleep(0.0001)

    set_chaos_hook(hook)
    try:
        q = CMPQueue(window=32, reclaim_period=8, min_batch=2)
        consumed, lock = [], threading.Lock()
        per, P = 300, 3
        done = threading.Event()

        def prod(pid):
            for i in range(per):
                q.enqueue((pid, i))

        def cons():
            while not done.is_set():
                d = q.dequeue()
                if d is None:
                    continue
                with lock:
                    consumed.append(d)
                    if len(consumed) == per * P:
                        done.set()

        ts = [threading.Thread(target=prod, args=(p,)) for p in range(P)]
        ts += [threading.Thread(target=cons) for _ in range(3)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=120)
        assert len(consumed) == per * P and len(set(consumed)) == per * P
    finally:
        set_chaos_hook(None)


def test_window_sizing_formula():
    assert compute_window(1e6, 0.001) == 1000
    assert compute_window(100, 0.001) == 64  # MIN_WINDOW floor
    assert max_reclaim_delay_cycles(1000, 64) == 1064


@pytest.mark.parametrize("cls", [MSQueue, SegmentedQueue, MutexQueue])
def test_baselines_basic(cls):
    q = cls()
    for i in range(200):
        q.enqueue(i)
    out = [q.dequeue() for _ in range(200)]
    assert sorted(x for x in out if x is not None) == list(range(200))
    assert q.dequeue() is None


def test_ms_queue_strict_fifo():
    q = MSQueue()
    for i in range(100):
        q.enqueue(i)
    assert [q.dequeue() for _ in range(100)] == list(range(100))


def test_hazard_pointer_scan_cost_scales_with_threads():
    """The O(P x K) coordination CMP eliminates: HP scan comparisons grow
    linearly with registered threads."""
    q = MSQueue(scan_threshold=8)
    costs = {}
    for nthreads in (2, 8):
        qq = MSQueue(scan_threshold=8)

        def work():
            for i in range(200):
                qq.enqueue(i)
                qq.dequeue()

        ts = [threading.Thread(target=work) for _ in range(nthreads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        costs[nthreads] = qq.hp.stats["scan_comparisons"] / max(1, qq.hp.stats["scans"])
    assert costs[8] > costs[2] * 2.5  # ~4x slots -> ~4x comparisons per scan


# ---------------------------------------------------------------------------
# batched ops (DESIGN.md §3): enqueue_many / dequeue_many
# ---------------------------------------------------------------------------


def test_batched_fifo_single_thread():
    q = CMPQueue(window=32, reclaim_period=8, min_batch=2)
    q.enqueue_many(range(1, 101))
    q.enqueue(101)
    q.enqueue_many([102, 103, 104])
    got = q.dequeue_many(60)
    got += [q.dequeue()]
    got += q.dequeue_many(100)
    assert got == list(range(1, 105))
    assert q.dequeue_many(5) == []
    assert q.dequeue() is None
    q.check_quiesced()


def test_batched_mixed_with_scalar_interleaved():
    q = CMPQueue(window=16, reclaim_period=4, min_batch=1)
    out, n = [], 0
    for round_ in range(40):
        batch = list(range(n, n + random.Random(round_).randint(1, 9)))
        n += len(batch)
        if round_ % 2:
            q.enqueue_many(batch)
        else:
            for x in batch:
                q.enqueue(x)
        k = random.Random(round_ + 7).randint(0, 6)
        out.extend(q.dequeue_many(k))
    out.extend(q.dequeue_many(10**6))
    assert out == list(range(n))


def test_batched_mpmc_per_producer_fifo():
    """Multi-producer *batched* enqueue, one batched consumer: batches stay
    contiguous and per-producer order is preserved (the batch holds one
    contiguous cycle range published by a single splice)."""
    q = CMPQueue(window=128, reclaim_period=16, min_batch=4)
    per, P, B = 600, 3, 8
    consumed = []

    def prod(pid):
        for start in range(0, per, B):
            q.enqueue_many((pid, i) for i in range(start, start + B))

    ts = [threading.Thread(target=prod, args=(p,)) for p in range(P)]
    for t in ts:
        t.start()
    while len(consumed) < per * P:
        consumed.extend(q.dequeue_many(16))
    for t in ts:
        t.join()
    assert len(set(consumed)) == per * P
    for p in range(P):
        seq = [i for (pid, i) in consumed if pid == p]
        assert seq == sorted(seq), f"producer {p} order violated"
    q.check_quiesced()


def test_batched_reclamation_stays_bounded():
    w, n = 64, 16
    q = CMPQueue(window=w, reclaim_period=n, min_batch=1)
    for i in range(0, 6000, 4):
        q.enqueue_many(range(i, i + 4))
        assert q.dequeue_many(4) == list(range(i, i + 4))
    assert q.live_nodes() < w + 4 * n + 16
    assert q.stats["reclaimed"] > 4000


def test_batched_ops_fewer_atomics_than_scalar():
    """The point of enqueue_many/dequeue_many: one cycle-range fetch-add, one
    splice, one boundary publish and one cursor advance per *batch* instead
    of per item (DESIGN.md §3)."""
    ops, B = 512, 32

    def measure(batched):
        q = CMPQueue(window=64, reclaim_period=10**9, prealloc=ops + 8)
        q.enqueue(0)
        q.dequeue()
        reset_op_counts()
        for s in range(0, ops, B):
            if batched:
                q.enqueue_many(range(s + 1, s + B + 1))
            else:
                for i in range(s + 1, s + B + 1):
                    q.enqueue(i)
        enq = sum(op_counts().values()) / ops
        reset_op_counts()
        got = []
        for _ in range(0, ops, B):
            if batched:
                got.extend(q.dequeue_many(B))
            else:
                got.extend(q.dequeue() for _ in range(B))
        deq = sum(op_counts().values()) / ops
        assert got == list(range(1, ops + 1))
        return enq, deq

    enq_s, deq_s = measure(batched=False)
    enq_b, deq_b = measure(batched=True)
    assert enq_b < enq_s, (enq_b, enq_s)
    assert deq_b < deq_s, (deq_b, deq_s)
    # the amortized fixed cost should be a real win, not noise
    assert enq_b <= 0.8 * enq_s, (enq_b, enq_s)


def test_batched_matches_scalar_bit_identical_under_chaos():
    """Property test (ISSUE 6): a random op stream applied once through the
    scalar API and once through enqueue_many/dequeue_many delivers the
    bit-identical item sequence, with the chaos hook live on both runs —
    the vectorized fast path keeps the same FIFO and reclaim semantics,
    and still routes every coordination event through the hook."""
    rng = random.Random(42)
    stream = []  # ("enq", [items]) | ("deq", k)
    nxt = 0
    for _ in range(400):
        if rng.random() < 0.55:
            n = rng.randint(1, 37)
            stream.append(("enq", list(range(nxt, nxt + n))))
            nxt += n
        else:
            stream.append(("deq", rng.randint(1, 41)))

    def run(batched):
        hook_kinds = []
        chaos_rng = random.Random(7)

        def hook(kind):
            hook_kinds.append(kind)
            if chaos_rng.random() < 0.002:
                time.sleep(0)  # yield point at an atomic boundary
        set_chaos_hook(hook)
        try:
            q = CMPQueue(window=32, reclaim_period=8, min_batch=2)
            out = []
            for op, arg in stream:
                if op == "enq":
                    if batched:
                        q.enqueue_many(arg)
                    else:
                        for x in arg:
                            q.enqueue(x)
                elif batched:
                    out.extend(q.dequeue_many(arg))
                else:
                    for _ in range(arg):
                        d = q.dequeue()
                        if d is None:
                            break
                        out.append(d)
            # drain the backlog and reclaim: both paths must release
            # everything behind the protection window
            while q.dequeue_many(64):
                pass
            q.reclaim()
            live = q.live_nodes()
        finally:
            set_chaos_hook(None)
        return out, live, len(hook_kinds)

    out_s, live_s, hooks_s = run(batched=False)
    out_b, live_b, hooks_b = run(batched=True)
    assert out_b == out_s, "batched delivery diverged from scalar"
    # with the queue drained, reclaim leaves only window-protected nodes
    assert live_b < 32 + 64 and live_s < 32 + 64, (live_b, live_s)
    # the batched run coordinates less but never silently: every batch op
    # still fires the chaos hook at least once
    assert 0 < hooks_b < hooks_s, (hooks_b, hooks_s)


def test_atomic_array_range_ops_count_once_and_arbitrate_exactly_once():
    """AtomicArray contract (DESIGN.md §12): a range op is ONE counted
    coordination event regardless of width, and per-index arbitration
    (exchange_where) hands each slot to exactly one winner under
    concurrent claimers."""
    from repro.core.atomics import AtomicArray

    arr = AtomicArray(256, init=1)
    reset_op_counts()
    arr.exchange_where(0, 256, 1, 2)
    arr.fill(0, 128, 0)
    arr.load_range(0, 256)
    arr.count_equal(0, 256, 0)
    assert sum(op_counts().values()) == 4, op_counts()
    reset_op_counts()
    arr.fetch_max(3, 17)
    assert op_counts().get("max") == 1, op_counts()

    arr2 = AtomicArray(512, init=1)
    wins = [None] * 8

    def claimer(t):
        wins[t] = arr2.exchange_where(0, 512, 1, 2)  # boolean won-mask
    ts = [threading.Thread(target=claimer, args=(t,)) for t in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    winners_per_slot = [sum(bool(w[i]) for w in wins) for i in range(512)]
    assert winners_per_slot == [1] * 512, "lost or double-claimed slot"
