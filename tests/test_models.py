"""Per-architecture smoke tests (reduced configs, CPU) + full-config param
counts via eval_shape (no allocation) + decode/prefill consistency."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (apply, decode_step, init_cache, init_params,
                          loss_fn, prefill)
from repro.models.frontends import vision_patch_embeds
from repro.training import optimizer as O

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens}
    if cfg.frontend == "vision":
        batch["extra_embeds"] = vision_patch_embeds(cfg, B, 4, KEY)

    logits, aux = apply(params, tokens, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in forward"

    # one full train step on CPU
    opt_cfg = O.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = O.init(params, opt_cfg)
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    new_params, opt_state, om = O.apply_updates(params, grads, opt_state, opt_cfg)
    assert bool(jnp.isfinite(om["grad_norm"]))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0].astype(jnp.float32)
                                               - x[1].astype(jnp.float32)))),
        jax.tree_util.tree_map(lambda a, b: (a, b), params, new_params),
        0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_matches_full_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    B, S = 2, 12
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size, jnp.int32)
    full_logits, _ = apply(params, tokens, cfg)
    cache = init_cache(cfg, B, S + 4)
    lg, cache = prefill(params, tokens[:, :8], cfg, cache)
    errs = [float(jnp.max(jnp.abs(lg - full_logits[:, 7])))]
    for t in range(8, S):
        lg, cache = decode_step(params, tokens[:, t:t + 1], cfg, cache)
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    assert max(errs) < 5e-3, f"{arch}: decode diverges {errs}"


# published sizes (total params) the configs must land near
_EXPECTED_B = {
    "glm4_9b": (9.4, 0.25), "yi_6b": (6.1, 0.25), "phi3_mini": (3.8, 0.3),
    "command_r_35b": (35.0, 0.3), "llama4_maverick": (400.0, 0.3),
    "granite_moe": (3.3, 0.45), "xlstm_125m": (0.125, 0.45),
    "hymba_1_5b": (1.5, 0.45), "llava_next": (7.2, 0.25),
    "musicgen_large": (3.3, 0.6),
}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    cfg = get_config(arch)
    p_struct = jax.eval_shape(lambda k: init_params(cfg, k),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
    n = sum(math.prod(x.shape) for x in jax.tree_util.tree_leaves(p_struct))
    expected, tol = _EXPECTED_B[arch]
    assert abs(n / 1e9 - expected) / expected < tol, (
        f"{arch}: {n/1e9:.2f}B params vs published ~{expected}B")


def test_vlm_frontend_stub_path():
    cfg = get_config("llava_next", smoke=True)
    params = init_params(cfg, KEY)
    B, S, NI = 2, 8, 4
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size, jnp.int32)
    embeds = vision_patch_embeds(cfg, B, NI, KEY)
    loss, metrics = loss_fn(params, {"tokens": tokens, "extra_embeds": embeds}, cfg)
    assert bool(jnp.isfinite(loss))
    logits, _ = apply(params, tokens, cfg, extra_embeds=embeds)
    assert logits.shape == (B, NI + S, cfg.vocab_size)


def test_sliding_window_cache_is_ring():
    """Hymba ring cache: memory is O(window), decode still exact (the CMP
    window made literal)."""
    cfg = get_config("hymba_1_5b", smoke=True)
    params = init_params(cfg, KEY)
    B, S = 1, 24
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size, jnp.int32)
    full_logits, _ = apply(params, tokens, cfg)
    cache = init_cache(cfg, B, cfg.sliding_window)  # ring of window size
    # SWA prefill must proceed in <=window chunks (single-shot prefill past
    # the ring would drop keys that intermediate positions still need —
    # standard SWA-serving constraint, noted in DESIGN.md)
    lg, cache = prefill(params, tokens[:, :cfg.sliding_window], cfg, cache)
    kv_t = cache["blocks"]["0"][0].k.shape[2]
    assert kv_t == cfg.sliding_window  # ring never grows
    err = float(jnp.max(jnp.abs(lg - full_logits[:, cfg.sliding_window - 1])))
    for t in range(cfg.sliding_window, S):
        lg, cache = decode_step(params, tokens[:, t:t + 1], cfg, cache)
        err = max(err, float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    assert err < 5e-3


def test_moe_dispatch_capacity_and_fifo():
    from repro.models.moe import assign_slots
    ids = jnp.asarray(np.array([0, 1, 0, 0, 1, 2, 0], np.int32))
    slot, keep = assign_slots(ids, num_experts=3, capacity=2)
    # expert 0 requests at positions 0,2,3,6 -> first two kept (FIFO), rest drop
    assert bool(keep[0]) and bool(keep[2]) and not bool(keep[3]) and not bool(keep[6])
    assert int(slot[0]) == 0 and int(slot[2]) == 1
    # expert 1: positions 1,4 both kept
    assert bool(keep[1]) and bool(keep[4])


def test_mlstm_state_decode_equals_scan():
    from repro.models.ssm import mlstm_scan
    B, H, S, d = 2, 2, 10, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, d), jnp.float32)
    i = jax.random.normal(ks[3], (B, H, S), jnp.float32)
    f = jax.random.normal(ks[4], (B, H, S), jnp.float32) + 2.0
    h_all, _ = mlstm_scan(q, k, v, i, f)
    # step-by-step with carried state
    state = None
    outs = []
    for t in range(S):
        h_t, state = mlstm_scan(q[:, :, t:t+1], k[:, :, t:t+1], v[:, :, t:t+1],
                                i[:, :, t:t+1], f[:, :, t:t+1], state=state)
        outs.append(h_t)
    h_inc = jnp.concatenate(outs, axis=2)
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(h_inc),
                               atol=1e-5, rtol=1e-5)


def test_ssd_chunked_matches_stepwise():
    from repro.models.ssm import ssd_chunked, ssd_decode_step
    B, S, H, P, N = 1, 12, 2, 4, 3
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    b = jax.random.normal(ks[1], (B, S, H, N), jnp.float32)
    c = jax.random.normal(ks[2], (B, S, H, N), jnp.float32)
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H), jnp.float32))
    y_chunk, hf = ssd_chunked(x, b, c, la, chunk=4)
    state = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        y_t, state = ssd_decode_step(x[:, t], b[:, t], c[:, t], la[:, t], state)
        ys.append(y_t[:, None])
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(state),
                               atol=1e-4, rtol=1e-4)
