"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import ref_claim, ref_flash_attention, ref_paged_attention

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 4, 4, 128, 32),    # MHA
    (2, 8, 2, 256, 64),    # GQA 4:1
    (1, 16, 1, 192, 64),   # MQA, ragged S
    (2, 4, 2, 100, 16),    # non-multiple of block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, KV, S, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    ref = ref_flash_attention(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    B, H, KV, S, hd = 2, 4, 2, 256, 32
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, sliding_window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = ref_flash_attention(q, k, v, causal=True, sliding_window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 64, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                          interpret=True)
    ref = ref_flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("B,H,KV,hd,page,P,pps", [
    (2, 4, 2, 32, 8, 16, 4),
    (3, 8, 8, 64, 16, 32, 6),   # MHA pages
    (1, 16, 2, 64, 32, 8, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, H, KV, hd, page, P, pps, dtype):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (P, KV, page, hd), dtype)
    vp = jax.random.normal(ks[2], (P, KV, page, hd), dtype)
    bt = jax.random.randint(ks[3], (B, pps), 0, P, jnp.int32)
    sl = jax.random.randint(ks[4], (B,), 1, pps * page + 1, jnp.int32)
    out = ops.paged_attention(q, kp, vp, bt, sl)
    ref = ref_paged_attention(q, kp, vp, bt, sl)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("n,k", [(16, 1), (64, 5), (128, 16)])
def test_claim_kernel_sweep(n, k):
    rng = np.random.default_rng(n * 1000 + k)
    state = jnp.asarray(rng.choice([0, 1, 2], size=n).astype(np.int32))
    cycle = jnp.asarray(rng.permutation(n).astype(np.int32))
    ns, ids = ops.claim(state, cycle, k=k)
    rs, rids, _ = ref_claim(state, cycle, k)
    assert np.array_equal(np.asarray(ns), np.asarray(rs))
    assert np.array_equal(np.asarray(ids), np.asarray(rids))


def test_claim_kernel_empty_pool():
    state = jnp.full((32,), 2, jnp.int32)  # everything CLAIMED
    cycle = jnp.arange(32, dtype=jnp.int32)
    ns, ids = ops.claim(state, cycle, k=4)
    assert np.all(np.asarray(ids) == 32)  # all invalid
    assert np.array_equal(np.asarray(ns), np.asarray(state))


def test_model_ref_matches_pallas_attention():
    """The model's self_attention with impl='pallas' equals impl='ref'."""
    from repro.models.layers import self_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 32), jnp.float32)  # [B,S,H,hd]
    k = jax.random.normal(ks[1], (2, 128, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 128, 2, 32), jnp.float32)
    a = self_attention(q, k, v, impl="ref")
    b = self_attention(q, k, v, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# tiled claim kernel: pools spanning multiple grid blocks (DESIGN.md §6)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k,block_n", [
    (300, 7, 128),    # 3 blocks, ragged tail
    (257, 4, 64),     # 5 blocks, tail of 1
    (1024, 16, 256),  # exact multiple
    (129, 3, 128),    # 2 blocks, minimal spill
])
def test_claim_kernel_tiled_matches_ref(n, k, block_n):
    rng = np.random.default_rng(n * 7 + k)
    state = jnp.asarray(rng.choice([0, 1, 2], size=n).astype(np.int32))
    cycle = jnp.asarray(rng.permutation(n).astype(np.int32))
    ns, ids = ops.claim(state, cycle, k=k, block_n=block_n)
    rs, rids, _ = ref_claim(state, cycle, k)
    assert np.array_equal(np.asarray(ns), np.asarray(rs))
    assert np.array_equal(np.asarray(ids), np.asarray(rids))


@pytest.mark.parametrize("n,k", [(384, 5), (500, 9)])
def test_claim_kernel_tiled_matches_fused(n, k):
    """Tiled grid path == single-block fused path (interpret mode) on the
    same input: the cross-block merge is exact, not approximate."""
    rng = np.random.default_rng(n + k)
    state = jnp.asarray(rng.choice([0, 1, 2], size=n).astype(np.int32))
    cycle = jnp.asarray(rng.permutation(n).astype(np.int32))
    ns_t, ids_t = ops.claim(state, cycle, k=k, block_n=128)   # 3-4 blocks
    ns_f, ids_f = ops.claim(state, cycle, k=k, block_n=n)     # single block
    assert np.array_equal(np.asarray(ns_t), np.asarray(ns_f))
    assert np.array_equal(np.asarray(ids_t), np.asarray(ids_f))


def test_claim_kernel_tiled_sparse_and_empty_blocks():
    """Blocks with zero AVAILABLE slots must not contribute candidates."""
    n, k, bn = 512, 6, 128
    state = np.zeros(n, np.int32)
    state[130] = 1   # block 1
    state[400] = 1   # block 3
    cycle = np.arange(n, dtype=np.int32)
    ns, ids = ops.claim(jnp.asarray(state), jnp.asarray(cycle), k=k, block_n=bn)
    got = np.asarray(ids)
    assert got[0] == 130 and got[1] == 400
    assert np.all(got[2:] == n)  # only two claimable slots exist
    assert np.asarray(ns)[130] == 2 and np.asarray(ns)[400] == 2


def test_claim_kernel_tiled_ties_break_by_lowest_id():
    """Equal cycles across different blocks: lowest slot id wins, exactly as
    lax.top_k and the fused cascade break ties."""
    n, bn = 256, 64
    state = np.ones(n, np.int32)
    cycle = np.full(n, 5, np.int32)  # all tied
    ns, ids = ops.claim(jnp.asarray(state), jnp.asarray(cycle), k=4, block_n=bn)
    assert np.asarray(ids).tolist() == [0, 1, 2, 3]


def test_slotpool_claim_dispatches_to_tiled_kernel():
    """slotpool.claim goes through kernels/ops.py for pools larger than one
    block and still claims the earliest cycles with a correct boundary."""
    from repro.core import slotpool as sp
    pool = sp.make(3000)  # > default block (2048) => tiled path
    pool, _, _ = sp.produce(pool, 12)
    pool, ids, valid = sp.claim(pool, 5)
    assert np.asarray(ids).tolist() == [0, 1, 2, 3, 4]
    assert bool(np.asarray(valid).all())
    assert int(pool.deque_cycle) == 5  # monotone max-publish of claimed cycles


# ---------------------------------------------------------------------------
# fused admission-ring step (kernels/cmp_ring.py) vs ref.ref_ring_step
# ---------------------------------------------------------------------------


def _ring_trajectory(step_fn, n, k, window, reqs):
    state = jnp.zeros((n,), jnp.int32)
    cycle = jnp.zeros((n,), jnp.int32)
    meta = jnp.zeros((2,), jnp.int32)
    outs = []
    for push_n, want in reqs:
        req = jnp.asarray([push_n, want], jnp.int32)
        state, cycle, meta, claimed = step_fn(state, cycle, meta, req)
        outs.append((np.asarray(state), np.asarray(cycle),
                     np.asarray(meta), np.asarray(claimed)))
    return outs


@pytest.mark.parametrize("n,k", [(16, 4), (32, 8), (64, 4)])
def test_ring_kernel_matches_oracle(n, k):
    """The Pallas ring kernel (interpret mode) and the jit'd oracle are
    bit-identical over random reachable trajectories — every array, every
    step: reclaim recycling, contiguous-prefix accept, ascending-cycle
    claim order and the monotone frontier."""
    from repro.kernels.cmp_ring import cmp_ring_step
    from repro.kernels.ref import ref_ring_step

    rng = np.random.default_rng(n * 31 + k)
    window = n // 4
    reqs = [(int(rng.integers(0, n)), int(rng.integers(0, k + 1)))
            for _ in range(8)]

    def pallas_step(s, c, m, r):
        return cmp_ring_step(s, c, m, r, k=k, window=window, interpret=True)

    def oracle_step(s, c, m, r):
        return ref_ring_step(s, c, m, r, k=k, window=window)

    got = _ring_trajectory(pallas_step, n, k, window, reqs)
    want = _ring_trajectory(oracle_step, n, k, window, reqs)
    for step, (g, w) in enumerate(zip(got, want)):
        for name, a, b in zip(("state", "cycle", "meta", "claimed"), g, w):
            assert (a == b).all(), (step, name, a, b)


def test_ring_kernel_recycles_and_rejects():
    """Deterministic ring-protocol checks through the public ops wrapper
    (oracle path): a full ring accepts only the contiguous FREE prefix,
    claimed slots recycle once the frontier moves a window past them, and
    claim order is always ascending cycle."""
    n, k, window = 16, 4, 4
    s = jnp.zeros((n,), jnp.int32)
    c = jnp.zeros((n,), jnp.int32)
    m = jnp.zeros((2,), jnp.int32)

    # fill the ring completely; second push must be rejected wholesale
    s, c, m, cl = ops.ring_step(s, c, m, jnp.asarray([n, 0], jnp.int32),
                                k=k, window=window, use_pallas=False)
    assert int(m[0]) == n and int((cl >= 0).sum()) == 0
    s, c, m, cl = ops.ring_step(s, c, m, jnp.asarray([5, 0], jnp.int32),
                                k=k, window=window, use_pallas=False)
    assert int(m[0]) == n, "push into a full ring must reject"

    # claim in k-chunks: ascending cycles 1..n, frontier follows the max
    seen = []
    for _ in range(n // k):
        s, c, m, cl = ops.ring_step(s, c, m, jnp.asarray([0, k], jnp.int32),
                                    k=k, window=window, use_pallas=False)
        seen += [int(x) for x in np.asarray(cl) if x >= 0]
    assert seen == list(range(1, n + 1))
    assert int(m[1]) == n

    # frontier is n: slots with cycle < n - window recycle, so a fresh push
    # accepts exactly those freed slots and no more
    s, c, m, cl = ops.ring_step(s, c, m, jnp.asarray([n, 0], jnp.int32),
                                k=k, window=window, use_pallas=False)
    accepted = int(m[0]) - n
    assert accepted == n - window - 1, accepted
