"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import ref_claim, ref_flash_attention, ref_paged_attention

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 4, 4, 128, 32),    # MHA
    (2, 8, 2, 256, 64),    # GQA 4:1
    (1, 16, 1, 192, 64),   # MQA, ragged S
    (2, 4, 2, 100, 16),    # non-multiple of block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, KV, S, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    ref = ref_flash_attention(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    B, H, KV, S, hd = 2, 4, 2, 256, 32
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, sliding_window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = ref_flash_attention(q, k, v, causal=True, sliding_window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 64, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                          interpret=True)
    ref = ref_flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("B,H,KV,hd,page,P,pps", [
    (2, 4, 2, 32, 8, 16, 4),
    (3, 8, 8, 64, 16, 32, 6),   # MHA pages
    (1, 16, 2, 64, 32, 8, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, H, KV, hd, page, P, pps, dtype):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (P, KV, page, hd), dtype)
    vp = jax.random.normal(ks[2], (P, KV, page, hd), dtype)
    bt = jax.random.randint(ks[3], (B, pps), 0, P, jnp.int32)
    sl = jax.random.randint(ks[4], (B,), 1, pps * page + 1, jnp.int32)
    out = ops.paged_attention(q, kp, vp, bt, sl)
    ref = ref_paged_attention(q, kp, vp, bt, sl)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("n,k", [(16, 1), (64, 5), (128, 16)])
def test_claim_kernel_sweep(n, k):
    rng = np.random.default_rng(n * 1000 + k)
    state = jnp.asarray(rng.choice([0, 1, 2], size=n).astype(np.int32))
    cycle = jnp.asarray(rng.permutation(n).astype(np.int32))
    ns, ids = ops.claim(state, cycle, k=k)
    rs, rids, _ = ref_claim(state, cycle, k)
    assert np.array_equal(np.asarray(ns), np.asarray(rs))
    assert np.array_equal(np.asarray(ids), np.asarray(rids))


def test_claim_kernel_empty_pool():
    state = jnp.full((32,), 2, jnp.int32)  # everything CLAIMED
    cycle = jnp.arange(32, dtype=jnp.int32)
    ns, ids = ops.claim(state, cycle, k=4)
    assert np.all(np.asarray(ids) == 32)  # all invalid
    assert np.array_equal(np.asarray(ns), np.asarray(state))


def test_model_ref_matches_pallas_attention():
    """The model's self_attention with impl='pallas' equals impl='ref'."""
    from repro.models.layers import self_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 32), jnp.float32)  # [B,S,H,hd]
    k = jax.random.normal(ks[1], (2, 128, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 128, 2, 32), jnp.float32)
    a = self_attention(q, k, v, impl="ref")
    b = self_attention(q, k, v, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
