"""Serving engine: paged decode correctness, FIFO admission, preemption
recovery via the CMP window, page-pool accounting."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serving.engine import Engine

KEY = jax.random.PRNGKey(0)


def _ref_generate(cfg, params, prompt, n):
    cache = init_cache(cfg, 1, 256)
    lg, cache = prefill(params, jnp.asarray([prompt], jnp.int32), cfg, cache)
    out = [int(jnp.argmax(lg[0]))]
    for _ in range(n - 1):
        lg, cache = decode_step(params, jnp.asarray([[out[-1]]], jnp.int32), cfg, cache)
        out.append(int(jnp.argmax(lg[0])))
    return out


@pytest.fixture(scope="module")
def dense_model():
    cfg = get_config("yi_6b", smoke=True)
    return cfg, init_params(cfg, KEY)


def test_engine_matches_reference(dense_model):
    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=2, page_size=8, num_pages=32,
                 window=2, max_seq=64)
    prompts = [[5, 17, 200, 3], [9, 9, 42], [100, 2, 7, 7, 1], [11] * 9]
    uids = [eng.submit(p, max_new_tokens=5) for p in prompts]
    done = eng.run_until_idle()
    for p, u in zip(prompts, uids):
        assert done[u].output == _ref_generate(cfg, params, p, 5)


def test_engine_moe(dense_model):
    cfg = get_config("granite_moe", smoke=True)
    params = init_params(cfg, KEY)
    eng = Engine(cfg, params, max_batch=2, page_size=8, num_pages=16,
                 window=2, max_seq=32)
    u = eng.submit([3, 1, 4, 1, 5], max_new_tokens=4)
    done = eng.run_until_idle()
    assert done[u].output == _ref_generate(cfg, params, [3, 1, 4, 1, 5], 4)


def test_fifo_admission_order(dense_model):
    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=1, page_size=8, num_pages=32,
                 window=1, max_seq=32)
    uids = [eng.submit([i + 1, i + 2], max_new_tokens=2) for i in range(5)]
    completion_order = []
    seen = set()
    for _ in range(200):
        eng.step()
        for u in eng.completed:
            if u not in seen:
                seen.add(u)
                completion_order.append(u)
        if len(seen) == 5:
            break
    assert completion_order == uids  # strict FIFO service with max_batch=1


def test_preemption_recovers_and_completes(dense_model):
    """Pool too small for all requests: engine preempts, pages recycle after
    the window, everything still completes with correct outputs."""
    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=3, page_size=4, num_pages=10,
                 window=2, max_seq=24)
    prompts = [[5, 17, 200, 3], [9, 9, 42], [100, 2, 7, 7, 1]]
    uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    done = eng.run_until_idle(max_steps=400)
    assert set(done) >= set(uids), "not all requests completed"
    for p, u in zip(prompts, uids):
        assert done[u].output == _ref_generate(cfg, params, p, 6)


def test_pages_recycle_after_window(dense_model):
    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=2, page_size=8, num_pages=16,
                 window=3, max_seq=32)
    eng.submit([1, 2, 3], max_new_tokens=3)
    eng.run_until_idle()
    used_after_done = eng.pool.free_pages()
    for _ in range(eng.pool.window + 2):
        eng.step()
    # all pages except the reserved scratch page are FREE again
    assert eng.pool.free_pages() == eng.pool.num_pages - 1
    assert eng.pool.free_pages() >= used_after_done


def test_engine_rejects_ssm_archs():
    cfg = get_config("xlstm_125m", smoke=True)
    params = init_params(cfg, KEY)
    with pytest.raises(AssertionError):
        Engine(cfg, params)


def test_concurrent_submitters_strict_fifo(dense_model):
    """The admission queue is the paper's queue: multiple submitter threads,
    strict global FIFO service order (max_batch=1 makes order observable)."""
    import threading
    import time

    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=1, page_size=8, num_pages=32,
                 window=2, max_seq=32)
    submitted = []
    lock = threading.Lock()

    def submitter(tid):
        for i in range(3):
            with lock:  # serialize just the uid recording, not the queue
                uid = eng.submit([tid * 10 + i + 1, 2, 3], max_new_tokens=2)
                submitted.append(uid)
            time.sleep(0.001)

    ts = [threading.Thread(target=submitter, args=(t,)) for t in range(3)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    completion = []
    seen = set()
    for _ in range(400):
        eng.step()
        for u in eng.completed:
            if u not in seen:
                seen.add(u)
                completion.append(u)
        if len(seen) == len(submitted):
            break
    # service order == global arrival order across submitter threads
    assert completion == submitted


def test_class_aware_preemption_evicts_lowest_class_first(dense_model):
    """Under pool exhaustion the engine preempts the lowest class first, and
    the preempted request re-enters *its own* class queue at its original
    cycle (served before anything younger in that class)."""
    from repro.sched import QueueClass

    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=2, page_size=4, num_pages=7,
                 window=2, max_seq=24,
                 classes=[QueueClass("background", priority=0),
                          QueueClass("interactive", priority=2)],
                 policy="strict")
    # Fill both lanes with background work (3 pages each incl. growth room).
    bg = [eng.submit([1, 2, 3, 4, 5], max_new_tokens=8, qclass="background")
          for _ in range(2)]
    eng.step()
    assert all(r is not None for r in eng.active)
    # Interactive arrival under a dry pool must evict a background lane...
    hi = eng.submit([9, 9, 9, 9], max_new_tokens=2, qclass="interactive")
    eng.step()
    admitted = {r.uid for r in eng.active if r is not None} | set(eng.completed)
    assert hi in admitted, "interactive was not admitted"
    done = eng.run_until_idle(max_steps=400)
    assert set(done) >= {hi, *bg}
    # ...and the victim was a background request, never the interactive one.
    assert done[hi].preemptions == 0
    assert sum(done[u].preemptions for u in bg) >= 1
    # outputs stay correct through evict -> requeue -> re-prefill
    assert done[hi].output == _ref_generate(cfg, params, [9, 9, 9, 9], 2)
    snap = eng.class_stats()
    assert snap["background"]["requeued"] >= 1
    assert snap["interactive"]["requeued"] == 0


def test_preempted_request_keeps_class_fifo_seat(dense_model):
    """Same-class preemption: the victim is the *youngest* class cycle, and
    on requeue it is re-served before every later submission of its class —
    FIFO position by original cycle, not by preemption time."""
    from repro.sched import QueueClass

    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=1, page_size=4, num_pages=4,
                 window=1, max_seq=16,
                 classes=[QueueClass("default", priority=0)])
    uids = [eng.submit([i + 1, i + 2], max_new_tokens=2) for i in range(4)]
    completion = []
    seen = set()
    for _ in range(300):
        eng.step()
        for u in eng.completed:
            if u not in seen:
                seen.add(u)
                completion.append(u)
        if len(seen) == 4:
            break
    # strict within-class FIFO end to end, preemptions or not
    assert completion == uids


def test_priority_inversion_never_happens(dense_model):
    """A lower class arriving later can never evict a higher-class lane."""
    from repro.sched import QueueClass

    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=2, page_size=4, num_pages=7,
                 window=2, max_seq=24,
                 classes=[QueueClass("lo", priority=0),
                          QueueClass("hi", priority=1)])
    hi = [eng.submit([5, 6, 7, 8], max_new_tokens=6, qclass="hi")
          for _ in range(2)]
    eng.step()
    lo = eng.submit([1, 2, 3], max_new_tokens=2, qclass="lo")
    done = eng.run_until_idle(max_steps=400)
    assert set(done) >= {lo, *hi}
    for u in hi:
        assert done[u].preemptions == 0, "higher class was evicted by lower"


def test_growth_starved_lane_self_evicts_not_corrupts(dense_model):
    """max_batch=1: when page growth fails (the previous request's retired
    pages are still inside the protection window) and there is nobody less
    entitled to evict, the growing lane preempts *itself* (clean requeue at
    its cycle seat) instead of decoding into the scratch page — outputs must
    still match the reference exactly."""
    cfg, params = dense_model
    # 3 usable pages (1 reserved scratch). Request A completes holding 2
    # pages, which stay window-protected for W=2 steps; request B admits on
    # the 1 remaining page, then its first growth finds the pool dry with
    # itself as the only (least-entitled) lane.
    eng = Engine(cfg, params, max_batch=1, page_size=4, num_pages=4,
                 window=2, max_seq=12)
    prompts = [[5, 17, 200, 3], [9, 9, 42, 7]]
    uids = [eng.submit(p, max_new_tokens=4) for p in prompts]
    done = eng.run_until_idle(max_steps=400)
    assert set(done) >= set(uids)
    assert done[uids[1]].preemptions >= 1, \
        "starved lane was never self-evicted"
    for p, u in zip(prompts, uids):
        assert done[u].output == _ref_generate(cfg, params, p, 4)


def test_admission_window_backpressure_on_engine(dense_model):
    """A class with a finite admit_window rejects the overflow (submit
    returns None) instead of growing without bound, and recovers once the
    backlog drains."""
    from repro.sched import QueueClass

    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=2, page_size=8, num_pages=32,
                 window=2, max_seq=32,
                 classes=[QueueClass("default", admit_window=4)])
    uids = [eng.submit([i + 1, 2], max_new_tokens=2) for i in range(6)]
    assert sum(u is not None for u in uids) == 4
    assert uids[4] is None and uids[5] is None
    done = eng.run_until_idle(max_steps=200)
    assert set(done) == {u for u in uids if u is not None}
    assert eng.pending == 0
    assert eng.submit([7, 7], max_new_tokens=2) is not None  # window freed


def test_overload_burst_drains_pending_counter(dense_model):
    """Batched admission under a pool too small for the burst: every request
    still completes AND the pending counter drains to exactly zero (the
    park-at-backlog path must not double-count)."""
    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=3, page_size=4, num_pages=8,
                 window=2, max_seq=16)
    uids = eng.submit_many([[i + 1, i + 2] for i in range(7)],
                           max_new_tokens=3)
    done = eng.run_until_idle(max_steps=300)
    assert set(done) >= set(uids)
    assert eng.pending == 0
    assert all(r is None for r in eng.active)
    # idle detection must actually fire (pending leak would burn max_steps)
    before = eng.step_count
    eng.run_until_idle(max_steps=50)
    assert eng.step_count == before + 1  # one probe step, then idle exit


def test_engine_replica_group_serves_and_recovers(dense_model):
    """DESIGN.md §9 end to end: 2 engine replicas (partitioned lane+page
    budgets, shared compiled forward) serve a 2-class wave; a mid-wave
    exact-seat checkpoint restores into a fresh group and every admitted
    request is served exactly once across the crash."""
    from repro.sched import QueueClass
    from repro.serving.engine import EngineReplicaGroup

    cfg, params = dense_model

    def classes():
        return [QueueClass("hi", priority=1, weight=4.0, num_shards=2,
                           window=64, reclaim_period=32),
                QueueClass("lo", priority=0, weight=1.0, num_shards=2,
                           window=64, reclaim_period=32)]

    grp = EngineReplicaGroup(cfg, params, num_replicas=2, max_batch=4,
                             page_size=8, num_pages=32, window=2, max_seq=64,
                             classes=classes())
    uids = [grp.submit([i + 1, 2, 3], max_new_tokens=3, qclass="hi")
            for i in range(3)]
    uids += grp.submit_many([[9, 9 + i] for i in range(3)],
                            max_new_tokens=3, qclass="lo")
    done = grp.run_until_idle(max_steps=200)
    assert all(u in done for u in uids)
    assert grp.idle()
    # each replica really owns a partitioned budget
    assert [e.max_batch for e in grp.engines] == [2, 2]
    assert sum(e.pool.num_pages for e in grp.engines) == 32

    # ---- checkpoint mid-wave, crash the group, restore, finish ----
    grp2 = EngineReplicaGroup(cfg, params, num_replicas=2, max_batch=4,
                              page_size=8, num_pages=32, window=2,
                              max_seq=64, classes=classes(),
                              forward_fn=grp._fwd)
    wave = []
    for i in range(4):
        wave.append(grp2.submit([5 + i, 1], max_new_tokens=3, qclass="hi"))
        wave.append(grp2.submit([7 + i, 2], max_new_tokens=3, qclass="lo"))
    grp2.step()
    grp2.step()
    import json
    state = json.loads(json.dumps(grp2.sched_state()))
    done_before = dict(grp2.completed)
    del grp2  # crash: laned requests and staged claims die with the group
    grp3 = EngineReplicaGroup.from_sched_state(
        cfg, params, state, max_batch=4, page_size=8, num_pages=32,
        max_seq=64, forward_fn=grp._fwd)
    done_after = grp3.run_until_idle(max_steps=300)
    assert not (set(done_before) & set(done_after)), "served twice"
    assert set(done_before) | set(done_after) >= set(wave), "lost a tenant"
    # uid continuity: new submissions never collide with pre-crash uids
    assert grp3.submit([3, 3], max_new_tokens=2, qclass="hi") not in wave


# ---------------------------------------------------------------------------
# device-resident admission (serving/admission.py, DESIGN.md §12)
# ---------------------------------------------------------------------------


def test_device_admission_ring_fifo_and_lookahead():
    from repro.serving.admission import DeviceAdmissionRing

    ring = DeviceAdmissionRing(k=4, claim_block=16)
    entries = [("q", i) for i in range(40)]
    out = []
    i = 0
    while len(out) < 40:
        push, i = entries[i:i + 8], min(i + 8, 40)
        claimed, rejected = ring.step(push, 4)
        assert not rejected
        out.extend(claimed)
    assert out == entries, "ring admission reordered the FIFO"
    # look-ahead actually amortized: far fewer kernel calls than steps
    assert ring.stats["kernel_calls"] < ring.stats["steps"]
    assert ring.pending == 0


def test_device_admission_ring_flush_is_exact_and_reusable():
    from repro.serving.admission import DeviceAdmissionRing

    ring = DeviceAdmissionRing(k=2, claim_block=8)
    entries = [("q", i) for i in range(20)]
    claimed, _ = ring.step(entries, 2)
    assert claimed == entries[:2]
    # flush returns the rest: claim-buffered first, then unclaimed, in
    # exact cycle (submission) order
    assert ring.flush() == entries[2:]
    assert ring.pending == 0 and ring.flush() == []
    # ring survives the flush: cycles stay monotone, admission continues
    more = [("q", i) for i in range(20, 30)]
    claimed, rejected = ring.step(more, 2)
    assert not rejected
    while len(claimed) < 10:
        got, rejected = ring.step([], 2)
        assert got and not rejected
        claimed.extend(got)
    assert claimed == more


def test_device_admission_ring_rejects_past_capacity():
    from repro.serving.admission import DeviceAdmissionRing

    ring = DeviceAdmissionRing(k=2, claim_block=2, capacity=8, window=2)
    entries = [("q", i) for i in range(12)]
    claimed, rejected = ring.step(entries, 0)
    assert claimed == []
    # contiguous-prefix accept: whatever fits stays FIFO, the suffix comes
    # back for the host to requeue — nothing is dropped
    assert claimed == [] and entries == entries[:12 - len(rejected)] + rejected
    assert ring.pending + len(rejected) == 12


def test_engine_device_admission_matches_host(dense_model):
    """The ISSUE 6 exactness bar: admission routed through the device ring
    serves the same requests to the same outputs as the host path."""
    cfg, params = dense_model
    outs = {}
    for device_admission in (False, True):
        eng = Engine(cfg, params, max_batch=2, page_size=8, num_pages=32,
                     window=2, max_seq=64, device_admission=device_admission)
        prompts = [[5, 17, 200, 3], [9, 9, 42], [100, 2, 7], [11] * 5]
        uids = [eng.submit(p, max_new_tokens=4) for p in prompts]
        done = eng.run_until_idle()
        outs[device_admission] = [done[u].output for u in uids]
        if device_admission:
            assert eng._dev_admit.stats["kernel_calls"] > 0, \
                "ring path never exercised"
            assert eng.ring_pending == 0
    assert outs[True] == outs[False]
