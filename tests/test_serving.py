"""Serving engine: paged decode correctness, FIFO admission, preemption
recovery via the CMP window, page-pool accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serving.engine import Engine

KEY = jax.random.PRNGKey(0)


def _ref_generate(cfg, params, prompt, n):
    cache = init_cache(cfg, 1, 256)
    lg, cache = prefill(params, jnp.asarray([prompt], jnp.int32), cfg, cache)
    out = [int(jnp.argmax(lg[0]))]
    for _ in range(n - 1):
        lg, cache = decode_step(params, jnp.asarray([[out[-1]]], jnp.int32), cfg, cache)
        out.append(int(jnp.argmax(lg[0])))
    return out


@pytest.fixture(scope="module")
def dense_model():
    cfg = get_config("yi_6b", smoke=True)
    return cfg, init_params(cfg, KEY)


def test_engine_matches_reference(dense_model):
    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=2, page_size=8, num_pages=32,
                 window=2, max_seq=64)
    prompts = [[5, 17, 200, 3], [9, 9, 42], [100, 2, 7, 7, 1], [11] * 9]
    uids = [eng.submit(p, max_new_tokens=5) for p in prompts]
    done = eng.run_until_idle()
    for p, u in zip(prompts, uids):
        assert done[u].output == _ref_generate(cfg, params, p, 5)


def test_engine_moe(dense_model):
    cfg = get_config("granite_moe", smoke=True)
    params = init_params(cfg, KEY)
    eng = Engine(cfg, params, max_batch=2, page_size=8, num_pages=16,
                 window=2, max_seq=32)
    u = eng.submit([3, 1, 4, 1, 5], max_new_tokens=4)
    done = eng.run_until_idle()
    assert done[u].output == _ref_generate(cfg, params, [3, 1, 4, 1, 5], 4)


def test_fifo_admission_order(dense_model):
    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=1, page_size=8, num_pages=32,
                 window=1, max_seq=32)
    uids = [eng.submit([i + 1, i + 2], max_new_tokens=2) for i in range(5)]
    completion_order = []
    seen = set()
    for _ in range(200):
        eng.step()
        for u in eng.completed:
            if u not in seen:
                seen.add(u)
                completion_order.append(u)
        if len(seen) == 5:
            break
    assert completion_order == uids  # strict FIFO service with max_batch=1


def test_preemption_recovers_and_completes(dense_model):
    """Pool too small for all requests: engine preempts, pages recycle after
    the window, everything still completes with correct outputs."""
    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=3, page_size=4, num_pages=10,
                 window=2, max_seq=24)
    prompts = [[5, 17, 200, 3], [9, 9, 42], [100, 2, 7, 7, 1]]
    uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    done = eng.run_until_idle(max_steps=400)
    assert set(done) >= set(uids), "not all requests completed"
    for p, u in zip(prompts, uids):
        assert done[u].output == _ref_generate(cfg, params, p, 6)


def test_pages_recycle_after_window(dense_model):
    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=2, page_size=8, num_pages=16,
                 window=3, max_seq=32)
    eng.submit([1, 2, 3], max_new_tokens=3)
    eng.run_until_idle()
    used_after_done = eng.pool.free_pages()
    for _ in range(eng.pool.window + 2):
        eng.step()
    # all pages except the reserved scratch page are FREE again
    assert eng.pool.free_pages() == eng.pool.num_pages - 1
    assert eng.pool.free_pages() >= used_after_done


def test_engine_rejects_ssm_archs():
    cfg = get_config("xlstm_125m", smoke=True)
    params = init_params(cfg, KEY)
    with pytest.raises(AssertionError):
        Engine(cfg, params)


def test_concurrent_submitters_strict_fifo(dense_model):
    """The admission queue is the paper's queue: multiple submitter threads,
    strict global FIFO service order (max_batch=1 makes order observable)."""
    import threading
    import time

    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=1, page_size=8, num_pages=32,
                 window=2, max_seq=32)
    submitted = []
    lock = threading.Lock()

    def submitter(tid):
        for i in range(3):
            with lock:  # serialize just the uid recording, not the queue
                uid = eng.submit([tid * 10 + i + 1, 2, 3], max_new_tokens=2)
                submitted.append(uid)
            time.sleep(0.001)

    ts = [threading.Thread(target=submitter, args=(t,)) for t in range(3)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    completion = []
    seen = set()
    for _ in range(400):
        eng.step()
        for u in eng.completed:
            if u not in seen:
                seen.add(u)
                completion.append(u)
        if len(seen) == len(submitted):
            break
    # service order == global arrival order across submitter threads
    assert completion == submitted


def test_overload_burst_drains_pending_counter(dense_model):
    """Batched admission under a pool too small for the burst: every request
    still completes AND the pending counter drains to exactly zero (the
    park-at-backlog path must not double-count)."""
    cfg, params = dense_model
    eng = Engine(cfg, params, max_batch=3, page_size=4, num_pages=8,
                 window=2, max_seq=16)
    uids = eng.submit_many([[i + 1, i + 2] for i in range(7)],
                           max_new_tokens=3)
    done = eng.run_until_idle(max_steps=300)
    assert set(done) >= set(uids)
    assert eng.pending == 0
    assert all(r is None for r in eng.active)
    # idle detection must actually fire (pending leak would burn max_steps)
    before = eng.step_count
    eng.run_until_idle(max_steps=50)
    assert eng.step_count == before + 1  # one probe step, then idle exit
