"""Scheduler fabric (DESIGN.md §8-9): per-class strict FIFO (under
concurrent producers AND stealers), window-based admission, drain policies,
work stealing, zero-atomic telemetry, sharded scheduler replicas with
seat-steal rebalancing and exact-seat frontier checkpointing."""

import json
import threading
import time

import pytest

from repro.sched import (ClassFifo, QueueClass, ReplicaSet, Scheduler,
                         ShardConsumer, ShardSet, StrictPriority,
                         WeightedFair, make_policy, queue_depth, rebalance,
                         steal_into)


# ---------------------------------------------------------------------------
# QueueClass: frontier drain = exact class-cycle FIFO
# ---------------------------------------------------------------------------


def test_class_fifo_across_shards_single_thread():
    qc = QueueClass("a", num_shards=4, window=64)
    for i in range(300):
        qc.submit(i)
    got = [e.payload for e in qc.drain(120)]
    got += [e.payload for e in qc.drain(1000)]
    assert got == list(range(300))
    assert qc.pending() == 0


def test_class_batched_submit_interleaves_with_scalar():
    qc = QueueClass("a", num_shards=3, window=64)
    qc.submit(0)
    qc.submit_many(list(range(1, 40)))
    qc.submit(40)
    out = [e.payload for e in qc.drain(100)]
    assert out == list(range(41))


def test_admission_window_rejects_then_recovers():
    qc = QueueClass("a", admit_window=8)
    envs = [qc.submit(i) for i in range(12)]
    assert sum(e is not None for e in envs) == 8
    assert qc.stats.rejected == 4
    qc.drain(8)  # frontier advances -> room again
    assert qc.submit(99) is not None


def test_admission_window_batched_partial():
    qc = QueueClass("a", admit_window=10)
    envs = qc.submit_many(list(range(15)))
    assert sum(e is not None for e in envs) == 10
    assert envs[10:] == [None] * 5  # rejected suffix, accepted prefix


def test_requeue_restores_original_cycle_position():
    qc = QueueClass("a", num_shards=2)
    for i in range(10):
        qc.submit(i)
    first = qc.drain(4)  # cycles 0..3
    qc.requeue(first[3])
    qc.requeue(first[1])
    # requeued seats come back first, oldest cycle first, then the frontier
    assert [e.payload for e in qc.drain(5)] == [1, 3, 4, 5, 6]


def test_class_fifo_under_concurrent_producers_and_stealers():
    """THE ordering theorem of the fabric (ISSUE acceptance): with concurrent
    producers and concurrent stealers migrating items between shards, the
    delivered class-cycle sequence is exactly 0,1,2,... — order within a
    class never inverts, nothing is lost or duplicated. The scheduler
    relaxes ordering only across classes, never within one."""
    qc = QueueClass("mt", num_shards=4, window=256)
    per, P = 400, 3
    stop = threading.Event()

    def prod(pid):
        for i in range(per):
            qc.submit((pid, i))

    def stealer():
        while not stop.is_set():
            rebalance(qc.shards, max_items=4)

    ts = [threading.Thread(target=prod, args=(p,)) for p in range(P)]
    ss = [threading.Thread(target=stealer) for _ in range(2)]
    for t in ts + ss:
        t.start()
    delivered = []
    while len(delivered) < per * P:
        delivered.extend(qc.drain(16))
    stop.set()
    for t in ts + ss:
        t.join()
    seqs = [e.seq for e in delivered]
    assert seqs == list(range(per * P)), "class cycle order inverted"
    # per-producer payload order is a corollary (submit linearizes at seq)
    for p in range(P):
        mine = [i for (pid, i) in (e.payload for e in delivered) if pid == p]
        assert mine == sorted(mine)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


def _filled_scheduler(policy):
    hi = QueueClass("hi", priority=2, weight=4.0)
    mid = QueueClass("mid", priority=1, weight=2.0)
    lo = QueueClass("lo", priority=0, weight=1.0)
    s = Scheduler([lo, mid, hi], policy=policy)  # declaration order != rank
    for i in range(12):
        for name in ("lo", "mid", "hi"):
            s.submit(name, (name, i))
    return s


def test_strict_priority_drains_high_first():
    s = _filled_scheduler("strict")
    batch = s.drain(12)
    assert [qc.name for qc, _ in batch] == ["hi"] * 12
    batch = s.drain(14)
    assert [qc.name for qc, _ in batch].count("mid") == 12
    assert [qc.name for qc, _ in batch].count("lo") == 2


def test_weighted_fair_matches_weights():
    s = _filled_scheduler("wfq")
    counts = {"hi": 0, "mid": 0, "lo": 0}
    batch = s.drain(14)
    for qc, _ in batch:
        counts[qc.name] += 1
    # 4:2:1 weights -> hi=8, mid=4, lo=2 over two DRR rounds
    assert counts["hi"] > counts["mid"] > counts["lo"] >= 1
    assert counts["hi"] == pytest.approx(4 * counts["lo"], abs=2)


def test_weighted_fair_preserves_within_class_fifo():
    s = _filled_scheduler("wfq")
    seen = {"hi": [], "mid": [], "lo": []}
    for _ in range(6):
        for qc, env in s.drain(6):
            seen[qc.name].append(env.seq)
    for name, seqs in seen.items():
        assert seqs == sorted(seqs), f"{name} class order inverted"


def test_fifo_across_classes_merges_by_arrival_stamp():
    a, b = QueueClass("a"), QueueClass("b")
    s = Scheduler([a, b], policy="fifo")
    order = []
    for i in range(30):
        name = "a" if i % 3 else "b"
        s.submit(name, i)
        order.append(i)
    assert [env.payload for _, env in s.drain(30)] == order


def test_make_policy_accepts_instance_and_rejects_unknown():
    assert isinstance(make_policy("strict"), StrictPriority)
    assert isinstance(make_policy("wfq"), WeightedFair)
    assert isinstance(make_policy("fifo"), ClassFifo)
    p = WeightedFair(quantum=2.0)
    assert make_policy(p) is p
    with pytest.raises(ValueError):
        make_policy("nope")


# ---------------------------------------------------------------------------
# stealing
# ---------------------------------------------------------------------------


def test_steal_into_is_exactly_once():
    shards = ShardSet(2, window=64)
    shards.queues[0].enqueue_many(list(range(50)))
    moved = steal_into(shards.queues[0], shards.queues[1], max_items=20)
    assert moved == 20
    a = shards.queues[0].dequeue_many(100)
    b = shards.queues[1].dequeue_many(100)
    assert sorted(a + b) == list(range(50))
    assert len(set(a) | set(b)) == 50


def test_shard_consumer_steals_from_deepest_sibling():
    shards = ShardSet(4, window=64)
    shards.queues[2].enqueue_many(list(range(40)))  # all load off-home
    c = ShardConsumer(shards, home=0, steal_batch=8)
    got = []
    while len(got) < 40:
        got.extend(c.take(8))
    assert sorted(got) == list(range(40))
    assert c.steals > 0 and c.stolen_items == 40


@pytest.mark.slow
def test_concurrent_shard_consumers_no_loss_no_dup():
    """4 workers, skewed producers, stealing on: every item claimed exactly
    once across home drains and steals (the claim CAS is the whole proof)."""
    shards = ShardSet(4, window=256)
    per, P = 500, 2
    done = threading.Event()
    consumed, lock = [], threading.Lock()

    def prod(pid):
        for i in range(per):
            # skew: 75% of load lands on shard 0
            s = 0 if i % 4 else (pid + i) % 4
            shards.queues[s].enqueue((pid, i))

    def worker(home):
        c = ShardConsumer(shards, home=home, steal_batch=8)
        while not done.is_set():
            got = c.take(4)
            if not got:
                time.sleep(0)
                continue
            with lock:
                consumed.extend(got)
                if len(consumed) == per * P:
                    done.set()

    ts = [threading.Thread(target=prod, args=(p,)) for p in range(P)]
    ts += [threading.Thread(target=worker, args=(h,)) for h in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert len(consumed) == per * P
    assert len(set(consumed)) == per * P


def test_rebalance_reduces_imbalance():
    shards = ShardSet(3, window=64)
    shards.queues[0].enqueue_many(list(range(60)))
    assert queue_depth(shards.queues[0]) == 60
    for _ in range(8):
        rebalance(shards, max_items=8)
    depths = shards.depths()
    assert max(depths) - min(depths) < 60
    assert sum(depths) == 60  # migration conserves items


# ---------------------------------------------------------------------------
# scheduler replicas (DESIGN.md §9)
# ---------------------------------------------------------------------------


def _three_class_replicas(num_replicas, *, num_shards=4, per_class=120,
                          min_steal=1):
    classes = [QueueClass(n, priority=p, weight=w, num_shards=num_shards,
                          window=4096)
               for n, p, w in (("hi", 2, 4.0), ("mid", 1, 2.0),
                               ("lo", 0, 1.0))]
    sched = Scheduler(classes, policy="strict")
    rs = ReplicaSet(sched, num_replicas, min_steal=min_steal)
    for i in range(per_class):
        for n in ("hi", "mid", "lo"):
            sched.submit(n, (n, i))
    return rs


def _drain_all(rs, *, k=8, steal=False, collect=None, max_rounds=10000):
    """Round-robin every replica until the fabric is empty; returns
    per-(class, replica) seq streams."""
    streams = collect if collect is not None else {}
    rounds = 0
    while rs.pending() > 0:
        rounds += 1
        assert rounds < max_rounds, "fabric did not drain"
        for r in rs.replicas:
            for v, env in r.drain(k):
                streams.setdefault((v.name, r.rid), []).append(env.seq)
            if steal:
                r.steal_if_starved()
    return streams


def test_replica_partition_delivers_exact_class_cycle_order():
    """ISSUE acceptance: with 4 replicas each owning a seat subset, every
    class's replica streams are seat-monotone and merge (by seat) to exactly
    0,1,2,... — nothing lost, duplicated, or reordered within a run."""
    rs = _three_class_replicas(4, per_class=120)
    streams = _drain_all(rs)
    for name in ("hi", "mid", "lo"):
        merged = sorted(s for (n, rid), ss in streams.items()
                        for s in ss if n == name)
        assert merged == list(range(120)), f"{name}: inexact merge"
        for rid in range(4):
            mine = streams.get((name, rid), [])
            assert mine == sorted(mine), \
                f"{name}@r{rid}: stream not seat-monotone"


def test_replica_policies_act_per_replica():
    """Each replica runs its own policy over its own seats: a strict drain
    still empties the highest class first, per replica."""
    rs = _three_class_replicas(2, per_class=40)
    for r in rs.replicas:
        first = r.drain(10)
        assert all(v.name == "hi" for v, _ in first)


def test_replica_steal_is_one_cas_and_keeps_run_order():
    """A starved replica claims whole cycle-runs from stalled peers (one
    owner-CAS per run). Per-run delivery order survives stealing; the merge
    stays exact."""
    rs = _three_class_replicas(4, per_class=100, min_steal=1)
    r0 = rs.replicas[0]
    out = []
    rounds = 0
    while len(out) < 300:  # replicas 1-3 stalled: r0 must steal everything
        rounds += 1
        assert rounds < 50000
        got = r0.drain(8)
        if not got:
            r0.steal_if_starved()
            continue
        out.extend((v.name, env.seq) for v, env in got)
    assert r0.steals > 0
    for name in ("hi", "mid", "lo"):
        seqs = [s for n, s in out if n == name]
        assert sorted(seqs) == list(range(100))
        for shard in range(4):  # within every stolen run: exact order
            run = [s for s in seqs if s % 4 == shard]
            assert run == sorted(run)
    # all seats ended under the only live replica (host-addressed owners)
    assert all(seat.owner.load().rid == 0
               for seats in rs.seats.values() for seat in seats)


def test_replica_concurrent_drains_no_loss_no_dup():
    """4 replica threads draining + stealing concurrently: the claim CAS and
    seat-cursor arithmetic keep every class's delivery exactly-once."""
    rs = _three_class_replicas(4, per_class=200, min_steal=2)
    lock = threading.Lock()
    got = {n: [] for n in ("hi", "mid", "lo")}
    total = [0]
    done = threading.Event()

    def work(rid):
        r = rs.replicas[rid]
        while not done.is_set():
            batch = r.drain(8)
            if not batch:
                r.steal_if_starved()
                time.sleep(0)
                continue
            with lock:
                for v, env in batch:
                    got[v.name].append(env.seq)
                total[0] += len(batch)
                if total[0] >= 600:
                    done.set()

    ts = [threading.Thread(target=work, args=(rid,)) for rid in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert total[0] == 600
    for name, seqs in got.items():
        assert sorted(seqs) == list(range(200)), f"{name}: lost/dup"


def test_queueclass_state_roundtrip_resumes_exact_seat():
    """Single-drain checkpointing: drain part of a class, snapshot
    (including a preempted seat), restore through JSON, and the remaining
    delivery is byte-identical to an uninterrupted run."""
    def build():
        qc = QueueClass("t", num_shards=3, admit_window=256, window=512)
        for i in range(60):
            qc.submit(i)
        head = qc.drain(10)
        qc.requeue(head[7])  # a preempted seat rides the checkpoint
        return qc

    uninterrupted = build()
    expected = [e.payload for e in uninterrupted.drain(100)]

    qc = build()
    state = json.loads(json.dumps(qc.state()))
    assert state["seq"] == 60 and state["frontier"] == 10
    assert state["gaps"] == 0 and len(state["requeue"]) == 1
    restored = QueueClass.from_state(state, window=512)
    assert [e.payload for e in restored.drain(100)] == expected
    assert restored.pending() == 0
    # admission window occupancy survived: seats freed by the pre-ckpt drain
    # are available again, the rest still count
    assert restored.submit(99) is not None


def test_replica_kill_and_restore_chaos():
    """ISSUE satellite: run a 3-class wave on 4 replicas, checkpoint
    mid-wave, kill a replica (its staged claims die with it), restore the
    fabric from the snapshot, and finish: per-tenant delivery is identical
    to an uninterrupted run — every tenant resumed at its exact FIFO seat."""
    per_class = 90

    def run(interrupt):
        rs = _three_class_replicas(4, per_class=per_class)
        streams = {}
        for _ in range(4):  # partial wave, all replicas delivering
            for r in rs.replicas:
                for v, env in r.drain(3):
                    streams.setdefault((v.name, r.rid), []).append(env.seq)
        if interrupt:
            state = json.loads(json.dumps(rs.state()))
            # kill: drop the whole live fabric (replica 2 "crashes" holding
            # whatever it had staged; the snapshot is the recovery truth)
            del rs
            rs = ReplicaSet.from_state(state, window=4096)
        _drain_all(rs, k=3, collect=streams)
        return streams

    base = run(interrupt=False)
    recovered = run(interrupt=True)
    for name in ("hi", "mid", "lo"):
        for rid in range(4):
            assert base.get((name, rid)) == recovered.get((name, rid)), \
                f"{name}@r{rid}: delivery diverged across kill+restore"
        merged = sorted(s for (n, rid), ss in recovered.items()
                        for s in ss if n == name)
        assert merged == list(range(per_class))


def test_replica_checkpoint_captures_policy_held_heads():
    """A fifo-merge policy buffers one head per class between drains; its
    seat cursor has already advanced, so the checkpoint must record it (as
    a requeued seat) or the tenant would vanish across a restore."""
    classes = [QueueClass(n, num_shards=2, window=256) for n in ("a", "b")]
    sched = Scheduler(classes, policy="fifo")
    rs = ReplicaSet(sched, 2, policy="fifo")
    for i in range(10):
        sched.submit("a", ("a", i))
        sched.submit("b", ("b", i))
    # k=1 drains force ClassFifo to hold the other class's head
    delivered = []
    for r in rs.replicas:
        delivered += [(v.name, e.seq) for v, e in r.drain(1)]
    assert sum(r.policy.held() for r in rs.replicas) > 0
    state = json.loads(json.dumps(rs.state()))
    rs2 = ReplicaSet.from_state(state, policy="fifo", window=256)
    rounds = 0
    while rs2.pending() > 0 and rounds < 1000:
        rounds += 1
        for r in rs2.replicas:
            delivered += [(v.name, e.seq) for v, e in r.drain(4)]
    for name in ("a", "b"):
        seqs = sorted(s for n, s in delivered if n == name)
        assert seqs == list(range(10)), \
            f"{name}: policy-held head lost across checkpoint"


def test_replica_set_rejects_too_few_shards():
    sched = Scheduler([QueueClass("a", num_shards=2)])
    with pytest.raises(AssertionError):
        ReplicaSet(sched, 4)


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------


def test_stats_snapshot_shapes_and_latency():
    qc = QueueClass("t", num_shards=2, admit_window=64)
    qc.submit_many(list(range(20)))
    qc.drain(10)
    snap = qc.snapshot()
    assert snap["submitted"] == 20 and snap["delivered"] == 10
    assert snap["pending"] == 10
    assert len(snap["shard_depths"]) == 2
    assert snap["admit_p50_ms"] is not None
    assert snap["admit_p99_ms"] >= snap["admit_p50_ms"] >= 0.0


def test_latency_window_ring_percentiles():
    from repro.sched.stats import LatencyWindow
    w = LatencyWindow(capacity=100)
    assert w.percentile(99) is None
    for i in range(250):  # wraps the ring
        w.record(float(i))
    assert w.count == 250
    assert 150 <= w.percentile(0) <= 249
    assert w.percentile(99) >= w.percentile(50)


def test_scheduler_snapshot_covers_all_classes():
    s = _filled_scheduler("strict")
    s.drain(10)
    snap = s.snapshot()
    assert set(snap) == {"hi", "mid", "lo"}
    assert s.pending() == 36 - 10


# ---------------------------------------------------------------------------
# bulk-drain fast path (DESIGN.md §12): order + telemetry equivalence
# ---------------------------------------------------------------------------


def test_latency_window_record_many_matches_scalar():
    """record_many's slice-assigned wraparound keeps exactly the same
    most-recent-N multiset (and percentiles) as N scalar records, across
    random batch patterns that land on every wraparound case."""
    import random
    from repro.sched.stats import LatencyWindow

    rng = random.Random(11)
    for trial in range(30):
        cap = rng.choice([4, 7, 32])
        a, b = LatencyWindow(cap), LatencyWindow(cap)
        feed = []
        for _ in range(rng.randint(1, 12)):
            batch = [rng.random() for _ in range(rng.randint(0, 3 * cap))]
            feed.extend(batch)
            for x in batch:
                a.record(x)
            b.record_many(batch)
        assert a.count == b.count == len(feed)
        assert sorted(a._buf) == sorted(b._buf), (trial, cap)
        for p in (0, 50, 99, 100):
            assert a.percentile(p) == b.percentile(p)


def test_percentile_linear_interpolation():
    """percentile() interpolates between ranks (numpy-style 'linear'), not
    nearest-rank: the p50 of an even-count reservoir is the midpoint."""
    from repro.sched.stats import LatencyWindow
    w = LatencyWindow(capacity=16)
    w.record_many([0.0, 10.0, 20.0, 30.0])
    assert w.percentile(50) == 15.0
    assert w.percentile(25) == 7.5
    assert w.percentile(0) == 0.0
    assert w.percentile(100) == 30.0
    assert w.samples() == [0.0, 10.0, 20.0, 30.0]


def test_aggregate_class_snapshots_pools_samples_exactly():
    """Merging per-replica snapshots pools the raw reservoirs: the merged
    percentiles equal a single window fed every sample, not the min/max
    pick of the per-replica percentiles."""
    from repro.sched.stats import (ClassStats, LatencyWindow,
                                   aggregate_class_snapshots)
    a, b = ClassStats("x"), ClassStats("x")
    a.latency.record_many([0.001 * i for i in range(10)])
    b.latency.record_many([0.010 * i for i in range(7)])
    merged = aggregate_class_snapshots([a.snapshot(), b.snapshot()])
    ref = LatencyWindow(64)
    ref.record_many(a.latency.samples() + b.latency.samples())
    assert merged["admit_p50_ms"] == ref.percentile(50) * 1e3
    assert merged["admit_p99_ms"] == ref.percentile(99) * 1e3
    assert sorted(merged["latency_samples"]) == sorted(ref.samples())


def test_aggregate_class_snapshots_empty_and_legacy():
    """No latency anywhere -> None percentiles; a legacy snapshot carrying
    percentiles but no raw samples forces the conservative whole-merge
    fallback (worst p99, best p50) instead of an under-weighted pool."""
    from repro.sched.stats import ClassStats, aggregate_class_snapshots
    empty = [ClassStats("x").snapshot() for _ in range(3)]
    merged = aggregate_class_snapshots(empty)
    assert merged["admit_p50_ms"] is None
    assert merged["admit_p99_ms"] is None
    assert merged["latency_samples"] is None

    fresh = ClassStats("x")
    fresh.latency.record_many([0.002, 0.004])
    legacy = ClassStats("x")
    legacy.latency.record_many([0.5])
    legacy_snap = legacy.snapshot()
    del legacy_snap["latency_samples"]  # deserialized pre-PR-7 aggregate
    merged = aggregate_class_snapshots([fresh.snapshot(), legacy_snap])
    assert merged["admit_p99_ms"] == 500.0  # worst replica's p99
    assert merged["admit_p50_ms"] == pytest.approx(3.0)  # best replica's p50


def test_drain_bulk_matches_drain_order_and_stats():
    """Scheduler.drain_bulk (the device-admission feeder) delivers the
    identical envelope order as repeated policy drains on the eligible
    shape (single class, no held heads), and keeps delivery telemetry."""
    qa = QueueClass("a", window=4096)
    sched_bulk = Scheduler([qa])
    sched_ref = Scheduler([QueueClass("a", window=4096)])
    for s in (sched_bulk, sched_ref):
        s.submit_many("a", list(range(500)))
    via_bulk = [env.payload for _, env in sched_bulk.drain_bulk(400)]
    via_bulk += [env.payload for _, env in sched_bulk.drain_bulk(400)]
    via_ref = []
    while len(via_ref) < 500:
        got = sched_ref.drain(64)
        assert got
        via_ref.extend(env.payload for _, env in got)
    assert via_bulk == via_ref == list(range(500))
    stats = qa.stats
    assert stats.delivered == 500
    assert stats.latency.count == 500
    assert stats.latency.percentile(50) is not None


def test_drain_bulk_falls_back_with_held_heads_or_multiclass():
    """Outside the fast path's preconditions, drain_bulk must route through
    the policy drain — cross-class order is a policy decision."""
    hi = QueueClass("hi", priority=2, weight=4.0)
    lo = QueueClass("lo", priority=0, weight=1.0)
    sched = Scheduler([hi, lo], policy="strict")
    sched.submit_many("lo", list(range(10)))
    sched.submit_many("hi", list(range(100, 110)))
    got = [env.payload for _, env in sched.drain_bulk(20)]
    assert got[:10] == list(range(100, 110)), \
        "bulk drain bypassed strict priority"
