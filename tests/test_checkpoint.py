"""Checkpointing: roundtrip, integrity, async window-bounded lag, and exact
failure-recovery resume equivalence."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as C
from repro.configs import get_config
from repro.data.pipeline import synth_batch
from repro.training.optimizer import OptConfig
from repro.training.train_loop import Trainer


def _tree_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def test_save_restore_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
             "step_meta": {"x": jnp.int32(7)}}
    C.save(str(tmp_path), 3, state)
    step, restored = C.restore(str(tmp_path), state)
    assert step == 3 and _tree_equal(state, restored)


def test_integrity_check_detects_corruption(tmp_path):
    state = {"w": jnp.ones((8, 8))}
    path = C.save(str(tmp_path), 1, state)
    victim = os.path.join(path, "leaf_00000.npy")
    with open(victim, "r+b") as f:
        f.seek(64)
        f.write(b"\xde\xad\xbe\xef")
    with pytest.raises(IOError):
        C.restore(str(tmp_path), state)


def test_async_checkpointer_bounded_lag(tmp_path):
    ck = C.AsyncCheckpointer(str(tmp_path), window=2)
    big = {"w": jnp.ones((256, 256))}
    accepted = sum(ck.submit(i, big) for i in range(12))
    assert accepted <= 12  # some may drop if writer lags
    ck.drain()
    assert ck.written, "nothing was written"
    # training was never blocked; retained-snapshot count never exceeded W
    assert ck.dropped == 12 - accepted
    ck.close()


def _data_iter(batches):
    i = 0
    while True:
        yield batches[i % len(batches)]
        i += 1


def test_failure_recovery_resume_is_exact(tmp_path):
    """Train 6 steps straight vs train 4 + crash + restore + 2: identical."""
    cfg = get_config("yi_6b", smoke=True)
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    batches = [synth_batch(0, i, 2, 16, cfg.vocab_size) for i in range(8)]

    trA = Trainer(cfg, opt, ckpt_dir=None, seed=3)
    trA.fit(_data_iter(batches), 6)

    d = str(tmp_path / "ck")
    trB = Trainer(cfg, opt, ckpt_dir=d, ckpt_every=4, seed=3)
    trB.fit(_data_iter(batches), 4)
    trB.async_ckpt.drain()

    # "crash" -> new process: fresh trainer restores and continues
    trC = Trainer(cfg, opt, ckpt_dir=d, ckpt_every=100, seed=999)  # wrong seed on purpose
    assert trC.try_restore()
    assert trC.step == 4
    it = _data_iter(batches)
    for _ in range(4):  # advance data iterator to where trB stopped
        next(it)
    trC.fit(it, 2)

    for a, b in zip(jax.tree_util.tree_leaves(trA.params),
                    jax.tree_util.tree_leaves(trC.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_aux_frontier_rides_the_checkpoint(tmp_path):
    """The aux (frontier) side-channel saves atomically with its step and
    restores as plain JSON — scheduler seats + pipeline cursors resume."""
    state = {"w": jnp.ones((4, 4))}
    aux = {"sched": {"classes": {"a": {"seq": 7, "frontier": 3}}},
           "pipeline": {"cursors": [4, 5], "seed": 0}}
    C.save(str(tmp_path), 2, state, aux=aux)
    step, got = C.restore_aux(str(tmp_path))
    assert step == 2 and got == aux
    # a step saved without aux reports None (not an error)
    C.save(str(tmp_path), 3, state)
    step, got = C.restore_aux(str(tmp_path))
    assert step == 3 and got is None


def test_async_checkpointer_aux_snapshot_is_decoupled(tmp_path):
    """AsyncCheckpointer deep-copies aux at submit: the caller mutating its
    live scheduler state afterwards cannot tear the written snapshot."""
    ck = C.AsyncCheckpointer(str(tmp_path), window=2)
    aux = {"frontier": [1, 2, 3]}
    assert ck.submit(1, {"w": jnp.zeros((8,))}, aux=aux)
    aux["frontier"].append(999)  # live state moves on
    ck.drain()
    ck.close()
    step, got = C.restore_aux(str(tmp_path), 1)
    assert got == {"frontier": [1, 2, 3]}


def test_async_checkpointer_bad_aux_does_not_leak_window_slot(tmp_path):
    """A non-JSON-able aux raises at submit — and must not burn a window
    reservation, or checkpointing would silently die after W failures."""
    ck = C.AsyncCheckpointer(str(tmp_path), window=1)
    for _ in range(3):  # more failures than the window holds
        with pytest.raises(TypeError):
            ck.submit(1, {"w": jnp.zeros((4,))}, aux={"bad": object()})
    assert ck.submit(2, {"w": jnp.zeros((4,))}, aux={"ok": [1, 2]})
    ck.drain()
    ck.close()
    assert C.restore_aux(str(tmp_path), 2)[1] == {"ok": [1, 2]}


def test_elastic_remesh_restore(tmp_path):
    """A checkpoint restores under a different device layout (here: the host
    restore path used for re-mesh; shardings arg re-lays-out leaves)."""
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    C.save(str(tmp_path), 1, state)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    step, restored = C.restore(str(tmp_path), state,
                               shardings={"w": sh})
    assert restored["w"].sharding == sh
    assert np.array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
