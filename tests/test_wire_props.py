"""Property tests for the wire codec and binary framing (CI slow lane;
hypothesis is not part of the runtime deps, so the whole module skips
where it is missing).

Two invariants carry the transport's exactness argument:

* ``wire_decode(wire_encode(envs))`` is the identity on (seq, stamp,
  payload) for any JSON-able payload mix — the frontier checkpoint format
  IS the wire format, so a byte flip here would corrupt checkpoints too;
* the frame decoder reassembles any chunking of any frame sequence —
  TCP may split or coalesce anywhere.
"""

import json

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.net.framing import (KIND_REQ, KIND_RESP, FrameDecoder,
                               pack_frame)  # noqa: E402
from repro.sched.classes import Envelope  # noqa: E402
from repro.sched.transport import (decode_owner, wire_decode,
                                   wire_encode)  # noqa: E402

pytestmark = pytest.mark.slow

_scalars = (st.none() | st.booleans() | st.integers(-2**40, 2**40)
            | st.floats(allow_nan=False, allow_infinity=False, width=32)
            | st.text(max_size=12))
_payloads = st.recursive(
    _scalars,
    lambda kids: st.lists(kids, max_size=4)
    | st.dictionaries(st.text(max_size=6), kids, max_size=4),
    max_leaves=8)


@st.composite
def _envelopes(draw):
    n = draw(st.integers(0, 12))
    seqs = draw(st.lists(st.integers(0, 2**31), min_size=n, max_size=n,
                         unique=True))
    return [Envelope(seq, draw(st.integers(0, 2**31)),
                     float(i) * 0.5, draw(_payloads))
            for i, seq in enumerate(seqs)]


@given(_envelopes())
@settings(max_examples=200, deadline=None)
def test_wire_codec_roundtrip_is_exact(envs):
    stamps = [e.t_submit for e in sorted(envs)]
    back = wire_decode(wire_encode(envs), t_submit=stamps)
    assert [(e.seq, e.stamp, e.payload) for e in back] == \
        [(e.seq, e.stamp, e.payload) for e in sorted(envs)]
    assert [e.t_submit for e in back] == stamps
    # and the blob really is the checkpoint record list
    assert json.loads(wire_encode(envs)) == \
        [[e.seq, e.stamp, e.payload] for e in sorted(envs)]


@given(st.one_of(
    st.integers(0, 2**31),                       # legacy bare replica index
    st.tuples(st.integers(0, 64), st.integers(0, 2**31))))
@settings(max_examples=100, deadline=None)
def test_decode_owner_accepts_legacy_and_pair_forms(rec):
    host, rid = decode_owner(list(rec) if isinstance(rec, tuple) else rec)
    if isinstance(rec, tuple):
        assert (host, rid) == rec
    else:
        assert (host, rid) == (0, rec)


@given(st.lists(st.tuples(st.sampled_from([KIND_REQ, KIND_RESP]), _payloads
                          .filter(lambda p: isinstance(p, dict))),
                max_size=8),
       st.data())
@settings(max_examples=150, deadline=None)
def test_frame_decoder_reassembles_any_chunking(frames, data):
    stream = b"".join(pack_frame(k, b) for k, b in frames)
    dec = FrameDecoder()
    got = []
    i = 0
    while i < len(stream):
        j = data.draw(st.integers(i + 1, len(stream)), label="chunk_end")
        got.extend(dec.feed(stream[i:j]))
        i = j
    assert got == frames
    assert dec.pending == 0


@given(st.lists(st.tuples(st.sampled_from([KIND_REQ, KIND_RESP]),
                          st.dictionaries(st.text(max_size=4), _scalars,
                                          max_size=3)),
                min_size=1, max_size=4),
       st.integers(1, 200))
@settings(max_examples=100, deadline=None)
def test_truncated_stream_never_yields_a_phantom_frame(frames, cut):
    """A prefix of a valid stream yields only the complete frames it
    contains — truncation starves the decoder, it never fabricates."""
    stream = b"".join(pack_frame(k, b) for k, b in frames)
    cut = min(cut, len(stream))
    dec = FrameDecoder()
    got = list(dec.feed(stream[:cut]))
    assert got == frames[:len(got)]  # a prefix, byte-exact
    whole = sum(len(pack_frame(k, b)) for k, b in frames[:len(got)])
    assert whole <= cut  # only frames fully inside the prefix surfaced
