"""Ten-thousand-tenant fabric (ISSUE 10, DESIGN.md §16): hashed
tenant->group routing, the active-set index, lazy per-tenant stats,
page-quota admission with 429-style shedding, the hierarchical drain,
and the policy/telemetry hot-path caches that keep every step O(active).

Everything here is deterministic (FNV routing, single-threaded drains);
the randomized TenantMap properties live in test_tenant_props.py behind
a hypothesis importorskip."""

import json

import pytest

from repro.fabric import Fabric, FabricConfig, TenantSpec
from repro.fabric.config import FabricConfigError
from repro.sched import (ActiveSet, ClassFifo, HierarchicalWFQ, QueueClass,
                         StrictPriority, TenantMap, TenantQuotaLedger,
                         TenantRouter, TenantStatsTable, TIERS,
                         group_class_name, make_policy, split_class_name,
                         tenant_hash)
from repro.sched.stats import LatencyWindow
from repro.sched.tenants import split_hosted


# ---------------------------------------------------------------------------
# tenant_hash / TenantMap: deterministic routing onto the bounded grid
# ---------------------------------------------------------------------------


def test_tenant_hash_is_process_stable():
    # FNV-1a, not builtin hash(): these values must never change — a
    # routing change strands snapshot-restored backlogs in the wrong class.
    assert tenant_hash("t0") == tenant_hash("t0")
    assert tenant_hash("t0") != tenant_hash("t1")
    assert tenant_hash("t0", salt=1) != tenant_hash("t0", salt=2)
    assert tenant_hash(42) == tenant_hash("42")  # str() canonicalization


def test_tenant_map_routes_whole_grid_and_restores():
    m = TenantMap(num_tenants=10000, num_groups=16, salt=7)
    assert len(m.class_names()) == 16 * len(TIERS)
    routed = {t: m.class_of(f"t{t}", "batch") for t in range(500)}
    # deterministic: a restored map routes every tenant identically
    m2 = TenantMap.from_state(json.loads(json.dumps(m.state())))
    assert all(m2.class_of(f"t{t}", "batch") == name
               for t, name in routed.items())
    gid = m.group_of("t3")
    assert split_class_name(m.class_of("t3", "interactive")) == \
        (f"g{gid:03d}", "interactive")
    with pytest.raises(KeyError):
        m.class_of("t3", "premium")


def test_tenant_map_memo_cap_does_not_change_routing():
    m = TenantMap(num_tenants=100000, num_groups=8)
    before = [m.group_of(f"t{t}") for t in range(3)]
    for t in range(2 * TenantMap.CACHE_CAP):  # force a wholesale clear
        m.group_of(f"x{t}")
    assert [m.group_of(f"t{t}") for t in range(3)] == before
    assert len(m._group_memo) <= TenantMap.CACHE_CAP


def test_host_affinity_follows_group():
    m = TenantMap(num_tenants=1000, num_groups=12)
    for t in range(200):
        assert m.host_of(f"t{t}", 4) == m.group_of(f"t{t}") % 4


def test_split_hosted_is_even_and_exact():
    assert split_hosted(10, 3) == [4, 3, 3]
    assert split_hosted(2, 4, min_per=1) == [1, 1, 1, 1]  # floor holds
    assert sum(split_hosted(1000, 7)) == 1000


# ---------------------------------------------------------------------------
# ActiveSet: the O(active) index
# ---------------------------------------------------------------------------


def test_active_set_mark_discard_restore():
    a = ActiveSet()
    a.mark("g001:batch")
    a.mark("g000:interactive")
    a.mark("g001:batch")  # idempotent
    assert len(a) == 2 and "g001:batch" in a
    a.discard("g001:batch")
    a.discard("missing")  # no-op
    assert a.names() == ["g000:interactive"]
    b = ActiveSet()
    b.restore(a.state())
    assert b.names() == a.names()


# ---------------------------------------------------------------------------
# TenantStatsTable: lazy, bounded, exact totals
# ---------------------------------------------------------------------------


def test_stats_table_evicts_idle_but_never_backlogged():
    t = TenantStatsTable(capacity=4)
    for i in range(4):
        t.note_submit(f"t{i}")
    t.note_deliver("t0")
    t.note_deliver("t1")  # t0/t1 idle, t2/t3 backlogged
    t.note_submit("t9")   # over capacity -> evict an idle record
    assert t.tracked() < 5
    totals = t.totals()
    assert totals["submitted"] == 5 and totals["delivered"] == 2
    assert totals["tenants"] == 5  # evicted tenants still counted
    top = t.top_by_backlog()
    assert all(row["backlog"] > 0 for row in top)
    assert {row["tenant"] for row in top} >= {"t2", "t3"}


def test_stats_table_state_roundtrip():
    t = TenantStatsTable(capacity=8)
    t.note_submit("a", 3)
    t.note_deliver("a")
    t.note_shed("b")
    t.note_reject("c")
    t2 = TenantStatsTable(capacity=8)
    t2.restore(json.loads(json.dumps(t.state())))
    assert t2.totals() == t.totals()
    assert t2.snapshot() == t.snapshot()


# ---------------------------------------------------------------------------
# TenantQuotaLedger: per-tenant + per-host caps
# ---------------------------------------------------------------------------


def test_ledger_denies_over_tenant_quota_and_credits_back():
    led = TenantQuotaLedger(per_tenant=4, total=100, num_hosts=1)
    assert led.charge("a", 0, 3)
    assert not led.charge("a", 0, 2)   # 3+2 > 4
    assert led.charge("a", 0, 1)
    led.credit("a", 0, 4)
    assert led.used("a") == 0 and led.host_used(0) == 0
    assert led.charge("a", 0, 4)


def test_ledger_host_cap_binds_before_tenant_quota():
    led = TenantQuotaLedger(per_tenant=100, total=10, num_hosts=2)
    assert led.host_caps == [5, 5]
    assert led.charge("a", 0, 5)
    assert not led.charge("b", 0, 1)  # host 0 full, b's quota untouched
    assert led.charge("b", 1, 5)      # other host has room


def test_ledger_rehost_conserves_totals():
    led = TenantQuotaLedger(per_tenant=100, total=12, num_hosts=3)
    led.charge("a", 0, 4)
    led.charge("b", 1, 2)
    led.rehost(2)
    assert sum(led.host_caps) == 12
    assert sum(led.host_used(h) for h in range(2)) == 6
    assert led.used("a") == 4  # per-tenant usage untouched
    led2 = TenantQuotaLedger.from_state(json.loads(json.dumps(led.state())))
    assert led2.state() == led.state()


# ---------------------------------------------------------------------------
# TenantRouter: admission keys, shed/reject split, snapshot
# ---------------------------------------------------------------------------


def _router(**ledger_kw):
    tmap = TenantMap(num_tenants=100, num_groups=4)
    led = TenantQuotaLedger(**ledger_kw) if ledger_kw else None
    return TenantRouter(tmap, TenantStatsTable(capacity=32), led)


def test_router_attributes_deliveries_without_ledger():
    r = _router()
    r.note_admit("a", ("g000:batch", 0), pages=0)
    r.note_admit("a", ("g000:batch", 1), pages=0)
    r.on_done(("g000:batch", 0))
    assert r.outstanding() == 1
    assert r.stats.totals()["delivered"] == 1
    snap = r.snapshot()
    assert snap["totals"]["submitted"] == 2 and "quota" not in snap


def test_router_shed_only_on_last_tier():
    r = _router()
    assert r.sheddable(TIERS[-1]) and not r.sheddable(TIERS[0])
    r.note_shed("a", "g000:background")
    r.note_reject("b")
    assert r.shed_total == 1
    assert r.shed_by_class == {"g000:background": 1}
    assert r.stats.totals()["rejected"] == 1


def test_router_state_roundtrip_preserves_tuple_keys():
    r = _router(per_tenant=8, total=64, num_hosts=2)
    assert r.try_charge("a", 3)
    r.note_admit("a", ("g001:batch", 5), pages=3)
    r.note_admit("b", "uid-7", pages=0)
    r.note_shed("c", "g002:background")
    r2 = TenantRouter.from_state(json.loads(json.dumps(r.state())))
    assert r2.outstanding() == 2 and r2.shed_total == 1
    r2.on_done(("g001:batch", 5))  # tuple key survived JSON
    assert r2.ledger.used("a") == 0
    assert r2.stats.totals()["delivered"] == 1


# ---------------------------------------------------------------------------
# HierarchicalWFQ: fair across groups, strict within, work-conserving
# ---------------------------------------------------------------------------


def _grid(groups, per_tier):
    classes = []
    for g in range(groups):
        for pri, tier in enumerate(reversed(TIERS)):
            qc = QueueClass(group_class_name(g, tier), priority=pri)
            for i in range(per_tier):
                qc.submit((g, tier, i))
            classes.append(qc)
    return classes


def test_hier_shares_split_evenly_across_groups():
    classes = _grid(groups=4, per_tier=20)
    pol = HierarchicalWFQ()
    got = pol.drain(classes, 40)
    by_group = {}
    for qc, _ in got:
        by_group[split_class_name(qc.name)[0]] = \
            by_group.get(split_class_name(qc.name)[0], 0) + 1
    assert len(got) == 40
    assert set(by_group.values()) == {10}  # equal group shares


def test_hier_strict_priority_within_group():
    classes = _grid(groups=1, per_tier=5)
    got = [split_class_name(qc.name)[1] for qc, _ in
           HierarchicalWFQ().drain(classes, 15)]
    assert got == (["interactive"] * 5 + ["batch"] * 5 + ["background"] * 5)


def test_hier_work_conserving_single_hot_group():
    # 32 groups offered, one holds all the work: the re-credit loop must
    # still fill k instead of capping the hot group at its burst cap.
    classes = _grid(groups=32, per_tier=0)
    hot = classes[0]
    for i in range(100):
        hot.submit(i)
    got = HierarchicalWFQ().drain(classes, 48)
    assert len(got) == 48
    assert all(qc.name == hot.name for qc, _ in got)


def test_hier_makes_progress_with_fractional_deficits():
    # many groups, k=1: every per-call share is fractional, the largest-
    # creditor fallback must still emit one item per call.
    classes = _grid(groups=8, per_tier=1)
    pol = HierarchicalWFQ()
    total = sum(len(pol.drain(classes, 1)) for _ in range(8 * len(TIERS)))
    assert total == 8 * len(TIERS)


def test_make_policy_knows_hier():
    assert isinstance(make_policy("hier"), HierarchicalWFQ)


# ---------------------------------------------------------------------------
# satellite caches: StrictPriority order, ClassFifo heap, LatencyWindow
# ---------------------------------------------------------------------------


def test_strict_priority_order_cache_tracks_class_set():
    a = QueueClass("a", priority=1)
    b = QueueClass("b", priority=5)
    for i in range(3):
        a.submit(i)
        b.submit(i)
    pol = StrictPriority()
    assert [qc.name for qc, _ in pol.drain([a, b], 6)] == ["b"] * 3 + ["a"] * 3
    # same set again: cached order (identity key) still drains correctly
    a.submit(9)
    assert [qc.name for qc, _ in pol.drain([a, b], 2)] == ["a"]
    # changed set: cache must rebuild, not serve the stale order
    c = QueueClass("c", priority=9)
    c.submit(0)
    a.submit(1)
    assert [qc.name for qc, _ in pol.drain([a, c], 2)] == ["c", "a"]


def test_class_fifo_heap_merges_by_stamp_after_take_held():
    a, b = QueueClass("a"), QueueClass("b")
    for i in range(6):  # global arrival stamps interleave the classes
        (a if i % 2 else b).submit(i, stamp=i)
    pol = ClassFifo()
    first = pol.drain([a, b], 2)
    assert [e.payload for _, e in first] == [0, 1]
    assert pol.held() == 2  # one buffered head per class
    # take_held simulates a reseat: buffered heads leave the policy and
    # ride to the new seat owner; the next drain continues the merge
    held = pol.take_held()
    assert sorted(e.payload for _, e in held) == [2, 3]
    assert pol.held() == 0
    rest = pol.drain([a, b], 10)
    assert [e.payload for _, e in rest] == [4, 5]
    assert [e.stamp for _, e in rest] == sorted(e.stamp for _, e in rest)


def test_latency_window_percentiles_with_cached_sort():
    w = LatencyWindow(capacity=8)
    assert w.percentile(50) is None
    for v in (5.0, 1.0, 3.0):
        w.record(v)
    assert w.percentile(0) == 1.0 and w.percentile(100) == 5.0
    p50_a = w.percentile(50)
    assert w.percentile(50) == p50_a  # cached view, same answer
    w.record_many([10.0] * 12)  # wraparound overwrite invalidates cache
    assert w.percentile(0) == 10.0 and w.percentile(100) == 10.0
    assert w.count == 15


# ---------------------------------------------------------------------------
# Fabric integration: tenant submit/step/shed/quota/snapshot
# ---------------------------------------------------------------------------


def _tenant_fabric(**spec_kw):
    spec = dict(num_tenants=200, num_groups=4)
    spec.update(spec_kw)
    return Fabric.open(FabricConfig(tenants=TenantSpec(**spec),
                                    queue_window=256, drain_k=16))


def test_fabric_per_tenant_fifo_and_attribution():
    fab = _tenant_fabric()
    for i in range(30):
        assert fab.submit(("a", i), tenant="alice", tier="batch") is not None
        assert fab.submit(("b", i), tenant="bob", tier="batch") is not None
    got = []
    while len(got) < 60:
        got.extend(fab.step())
    per = {"alice": [], "bob": []}
    for view, env in got:
        per["alice" if env.payload[0] == "a" else "bob"].append(env.payload[1])
    assert per["alice"] == list(range(30))  # strict per-tenant FIFO
    assert per["bob"] == list(range(30))
    tv = fab.stats_view().tenants
    assert tv["totals"]["submitted"] == 60
    assert tv["totals"]["delivered"] == 60
    assert fab.tenants.outstanding() == 0
    fab.close()


def test_fabric_sheds_only_lowest_tier_under_group_pressure():
    fab = _tenant_fabric(num_groups=1, group_window=12)
    shed = sum(fab.submit(i, tenant="t0", tier="background") is None
               for i in range(40))
    assert shed > 0
    sv = fab.stats_view()
    shed_classes = [n for n, c in sv.classes.items() if c.shed > 0]
    assert shed_classes == [group_class_name(0, TIERS[-1])]
    # higher tiers under the same pressure reject, never shed
    denied = sum(fab.submit(i, tenant="t0", tier="interactive") is None
                 for i in range(40))
    assert denied > 0
    assert sv.tenants["shed_total"] == shed
    assert fab.stats_view().tenants["totals"]["rejected"] == denied
    fab.close()


def test_fabric_quota_denies_then_recovers_on_delivery():
    fab = _tenant_fabric(num_groups=1, page_quota=5)
    admitted = [fab.submit(i, tenant="t0", tier="interactive")
                for i in range(8)]
    assert sum(e is not None for e in admitted) == 5  # quota binds
    done = 0
    while done < 5:
        done += len(fab.step())
    assert fab.submit(99, tenant="t0", tier="interactive") is not None
    fab.close()


def test_fabric_tenant_snapshot_roundtrip():
    fab = _tenant_fabric(num_groups=2, page_quota=50)
    for i in range(20):
        fab.submit(i, tenant=f"t{i % 5}", tier=TIERS[i % 3])
    snap = json.loads(json.dumps(fab.snapshot()))
    fab.close(final_checkpoint=False)
    fab2 = Fabric.from_snapshot(snap)
    got = []
    while True:
        batch = fab2.step()
        if not batch:
            break
        got.extend(batch)
    assert len(got) == 20  # backlog survived, nothing stranded
    tv = fab2.stats_view().tenants
    assert tv["totals"]["delivered"] == 20
    assert fab2.tenants.outstanding() == 0
    # routing identity in the restored process
    assert fab2.tenants.map.group_of("t3") == \
        TenantMap(200, 2).group_of("t3")
    fab2.close(final_checkpoint=False)


def test_fabric_rejects_tenant_submit_without_tenant_spec():
    fab = Fabric.open(FabricConfig(queue_window=64))
    with pytest.raises(FabricConfigError):
        fab.submit(1, tenant="t0")
    fab.close()


def test_kv_pool_meters_pages_through_attached_ledger():
    jnp = pytest.importorskip("jax.numpy")
    from repro.configs import get_config
    from repro.serving.kv_cache import PagedKVPool

    pool = PagedKVPool(get_config("yi_6b", smoke=True), num_pages=16,
                       page_size=8, window=2)
    led = TenantQuotaLedger(per_tenant=6, total=16, num_hosts=1)
    pool.attach_ledger(led)
    ids, valid = pool.alloc_for("a", 4)
    assert int(jnp.sum(valid)) == 4 and led.used("a") == 4
    denied, _ = pool.alloc_for("a", 3)  # 4+3 > 6: denied before the pool
    assert denied.shape == (0,)
    assert pool.free_pages() == 12  # the denial consumed nothing
    pool.retire_for("a", ids)
    assert led.used("a") == 0  # credit on retire
    # without a ledger the tenant paths are exactly alloc/retire
    pool.ledger = None
    ids2, valid2 = pool.alloc_for("b", 2)
    assert int(jnp.sum(valid2)) == 2 and led.used("b") == 0


def test_fabric_stats_view_walks_only_active_classes():
    fab = _tenant_fabric(num_groups=64)  # 192-class declared grid
    fab.submit(1, tenant="t0", tier="interactive")
    for _ in range(10):  # past the amortized retire-sweep cadence
        fab.step()
    sv = fab.stats_view()
    assert sv.tenants["active_classes"] <= 2
    # the view reports the active subset, not the declared grid
    assert len(sv.classes) < 192
    fab.close()
