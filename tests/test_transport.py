"""The seat-protocol transport layer (DESIGN.md §11): host-addressed
ownership, the wire codec (= the frontier checkpoint format), LocalTransport
/ SimHostTransport equivalence, chaos (drop/delay/reorder) invariance,
host-loss recovery, and cross-transport snapshot restore."""

import json
import threading
import time

import pytest

from repro.fabric import ClassSpec, Fabric, FabricConfig, FabricConfigError
from repro.sched import (HostAddr, QueueClass, ReplicaSet, Scheduler,
                         SchedulerReplica, ShardSeat, SimHostTransport,
                         decode_owner, make_transport)
from repro.sched.classes import Envelope
from repro.sched.transport import wire_decode, wire_encode

# ---------------------------------------------------------------------------
# addressing + wire codec
# ---------------------------------------------------------------------------


def test_host_addr_json_roundtrip_and_legacy_decode():
    a = HostAddr(1, 5)
    assert decode_owner(json.loads(json.dumps(list(a)))) == (1, 5)
    # PR-3/4 snapshots recorded a bare replica index (single-host)
    assert decode_owner(3) == (0, 3)


def test_wire_codec_is_the_checkpoint_format():
    envs = [Envelope(3, 7, time.monotonic(), {"k": [1, 2]}),
            Envelope(1, 5, time.monotonic(), "x")]
    blob = wire_encode(envs)
    # the wire records ARE encode_envelopes' checkpoint records
    assert json.loads(blob) == [[1, 5, "x"], [3, 7, {"k": [1, 2]}]]
    stamps = [e.t_submit for e in sorted(envs)]
    back = wire_decode(blob, t_submit=stamps)
    assert [(e.seq, e.stamp, e.payload) for e in back] == \
        [(1, 5, "x"), (3, 7, {"k": [1, 2]})]
    assert [e.t_submit for e in back] == stamps  # latency telemetry honest


def test_make_transport_validation():
    assert make_transport("local").kind == "local"
    assert make_transport("sim", 3).num_hosts == 3
    with pytest.raises(ValueError, match="unknown transport"):
        make_transport("tcp")
    with pytest.raises(AssertionError):
        SimHostTransport(2, drop=1.0)


def test_config_validates_transport_fields():
    with pytest.raises(FabricConfigError, match="single-host"):
        FabricConfig(hosts=2)  # local transport can't be multi-host
    with pytest.raises(FabricConfigError, match="no wire"):
        FabricConfig(transport_drop=0.1)
    with pytest.raises(FabricConfigError, match="drains nothing"):
        FabricConfig(transport="sim", hosts=4, replicas=2, max_replicas=2,
                     shards_per_class=4)
    with pytest.raises(FabricConfigError, match="transport_drop"):
        FabricConfig(transport="sim", hosts=1, transport_drop=2.0)
    cfg = FabricConfig(transport="sim", hosts=2, replicas=2,
                       shards_per_class=2)
    assert json.loads(json.dumps(cfg.to_json()))["hosts"] == 2
    assert FabricConfig.from_json(cfg.to_json()) == cfg


# ---------------------------------------------------------------------------
# sched-only fabrics over the sim transport
# ---------------------------------------------------------------------------


def _fab(**kw):
    base = dict(classes=(ClassSpec("hi", priority=1, weight=4.0),
                         ClassSpec("lo", priority=0, weight=1.0)),
                shards_per_class=4, replicas=4, max_replicas=4,
                queue_window=4096, drain_k=6)
    base.update(kw)
    return Fabric.open(FabricConfig(**base))


def _wave(fab, per_class):
    for name in ("hi", "lo"):
        fab.submit_many([(name, i) for i in range(per_class)], qclass=name)


def _drain_streams(fab, per_class, max_rounds=50000):
    streams = {"hi": [], "lo": []}
    rounds = 0
    while sum(map(len, streams.values())) < 2 * per_class:
        rounds += 1
        assert rounds < max_rounds, "fabric did not drain"
        for v, env in fab.step():
            streams[v.name].append(env.seq)
    return streams


def _assert_exact(streams, per_class, shards=4):
    """The PR-3/4 exact-seat acceptance: per class the union is exactly
    0..n-1 and every shard cycle-run is delivered in order."""
    for name, seqs in streams.items():
        assert sorted(seqs) == list(range(per_class)), \
            f"{name}: lost/duplicated seats ({len(seqs)} of {per_class})"
        for s in range(shards):
            run = [q for q in seqs if q % shards == s]
            assert run == sorted(run), f"{name} run {s} reordered"


def test_sim_lossless_delivers_identically_to_local():
    """With a clean wire, the host split is invisible: same per-class
    delivery streams as the local transport, envelope for envelope."""
    per_class = 120
    fab_l = _fab()
    _wave(fab_l, per_class)
    local = _drain_streams(fab_l, per_class)
    fab_s = _fab(transport="sim", hosts=2)
    _wave(fab_s, per_class)
    sim = _drain_streams(fab_s, per_class)
    assert sim == local
    _assert_exact(sim, per_class)


def test_sim_chaos_preserves_exact_order():
    """Message drop + delay + batch reorder cost latency, never exactness:
    the seat cursor, not arrival order, drives delivery."""
    per_class = 150
    fab = _fab(transport="sim", hosts=2, replicas=3,
               transport_drop=0.3, transport_delay=0.2,
               transport_reorder=True, transport_seed=17)
    _wave(fab, per_class)
    streams = _drain_streams(fab, per_class)
    _assert_exact(streams, per_class)
    ts = fab.stats_view().transport
    assert ts["drops"] > 0 and ts["delayed"] > 0 and ts["reordered"] > 0
    assert ts["remote_bytes"] > 0  # the cross-host hops were serialized


def test_schedonly_codec_hooks_preserve_payload_types():
    """Scheduler-only fabrics default to a plain JSON wire (tuples come
    back lists on cross-host hops); Fabric.open(codec=...) supplies the
    payload encode/decode pair and types survive every hop."""
    per_class = 120
    cfg = FabricConfig(
        classes=(ClassSpec("hi", priority=1), ClassSpec("lo")),
        shards_per_class=4, replicas=3, max_replicas=3, queue_window=4096,
        drain_k=6, transport="sim", hosts=2)
    fab = Fabric.open(cfg, codec=(list, tuple))
    _wave(fab, per_class)
    payloads = []
    rounds = 0
    while len(payloads) < 2 * per_class:
        rounds += 1
        assert rounds < 50000
        payloads.extend(env.payload for _, env in fab.step())
    assert all(isinstance(p, tuple) for p in payloads), \
        "payload type lost on a cross-host hop"
    assert fab.stats_view().transport["remote_msgs"] > 0


def test_steal_is_one_claim_rpc_through_the_transport():
    """A cross-host steal is exactly one ownership-claim message; a dropped
    claim is retried next round and the run is never lost."""
    classes = [QueueClass("a", num_shards=4, window=1024)]
    sched = Scheduler(classes)
    tp = SimHostTransport(2, drop=0.5, seed=3)
    rs = ReplicaSet(sched, 2, min_steal=1, transport=tp)
    for i in range(40):
        sched.submit("a", i)
    thief = rs.replicas[0]
    got = []
    rounds = 0
    while len(got) < 40:  # replica 1 stalled: thief must claim its runs
        rounds += 1
        assert rounds < 50000
        batch = thief.drain(8)
        if not batch:
            thief.steal_if_starved()
            continue
        got.extend(env.seq for _, env in batch)
    assert sorted(got) == list(range(40))
    assert thief.steals > 0
    assert tp.remote_claims > 0  # the steals crossed hosts as claim RPCs


def test_fail_host_recovers_staged_and_requeued_seats():
    """Kill a host whose replicas hold staged claims, requeued seats and
    policy-held heads: the survivors replay its frontier state through the
    wire codec and delivery stays exact — nothing lost, nothing twice."""
    per_class = 80
    fab = _fab(transport="sim", hosts=2, policy="fifo", drain_k=1)
    _wave(fab, per_class)
    streams = {"hi": [], "lo": []}
    for _ in range(6):  # partial drains: fifo heads held, stages populated
        for v, env in fab.step():
            streams[v.name].append(env.seq)
    # manufacture a requeued seat on a host-1 replica (odd rids live there)
    victim = fab.replicas[1]
    view = victim.by_name["hi"]
    if streams["hi"]:
        seq = streams["hi"].pop()
        view.requeue(Envelope(seq, 0, time.monotonic(), ("hi", seq)))
    moved = fab.fail_host(1)
    assert moved > 0
    assert not fab.replicas[1].alive and not fab.replicas[3].alive
    # recovery spreads the dead host's seats across DISTINCT survivors
    # (one shared round-robin cycle, not one hoarder per class)
    new_owners = {seat.owner.load().rid
                  for seats in fab.replica_set.seats.values()
                  for seat in seats}
    assert new_owners == {0, 2}, f"recovery concentrated seats: {new_owners}"
    stall = 0
    while fab.pending() > 0 and stall < 10000:
        got = fab.step()
        for v, env in got:
            streams[v.name].append(env.seq)
        stall = 0 if got else stall + 1
    merged = streams
    for n in ("hi", "lo"):
        assert sorted(merged[n]) == list(range(per_class)), f"{n}: lost seats"
    with pytest.raises(AssertionError, match="last live host"):
        fab.fail_host(0)


def test_snapshot_roundtrips_across_transports():
    """ISSUE satellite: a frontier snapshot written under LocalTransport
    restores under SimHostTransport (and back) — owners re-address by
    replica, delivery continues at the exact seats."""
    per_class = 60
    fab = _fab()
    _wave(fab, per_class)
    prefix = [(v.name, e.seq) for v, e in fab.step()]
    snap = json.loads(json.dumps(fab.snapshot()))
    assert snap["sched"]["transport"]["kind"] == "local"

    fab2 = Fabric.from_snapshot(snap, overrides={"transport": "sim",
                                                 "hosts": 2})
    assert fab2.transport.kind == "sim" and fab2.transport.num_hosts == 2
    hosts = {seat.owner.load().host
             for seats in fab2.replica_set.seats.values() for seat in seats}
    assert hosts == {0, 1}  # owners really landed on both hosts
    streams = {"hi": [s for n, s in prefix if n == "hi"],
               "lo": [s for n, s in prefix if n == "lo"]}
    for v, e in fab2.drain():
        streams[v.name].append(e.seq)
    _assert_exact(streams, per_class)

    # and back: sim snapshot -> local restore
    fab3 = _fab(transport="sim", hosts=2)
    _wave(fab3, per_class)
    fab3.step()
    snap3 = json.loads(json.dumps(fab3.snapshot()))
    fab4 = Fabric.from_snapshot(snap3, overrides={"transport": "local",
                                                  "hosts": 1})
    assert fab4.transport.kind == "local"
    assert fab4.pending() > 0
    fab4.drain()
    assert fab4.pending() == 0


def test_legacy_int_owner_snapshot_restores():
    """A PR-3/4 frontier snapshot (bare-int seat owners) restores under the
    host-addressed fabric."""
    fab = _fab()
    _wave(fab, 40)
    snap = json.loads(json.dumps(fab.snapshot()))
    for cs in snap["sched"]["classes"].values():
        cs["owners"] = [rid for _, rid in cs["owners"]]  # legacy format
    del snap["sched"]["transport"]
    fab2 = Fabric.from_snapshot(snap)
    streams = {"hi": [], "lo": []}
    for v, e in fab2.drain():
        streams[v.name].append(e.seq)
    _assert_exact(streams, 40)


def test_standalone_scheduler_replica_default_transport():
    """SchedulerReplica constructed outside a ReplicaSet (exported API)
    gets a bound LocalTransport and drains."""
    sched = Scheduler([QueueClass("a", num_shards=2, window=256)])
    seats = {"a": [ShardSeat(HostAddr(0, 0), s) for s in range(2)]}
    r = SchedulerReplica(0, sched, seats)
    sched.submit("a", "x")
    assert [e.payload for _, e in r.drain(4)] == ["x"]


def test_hosted_budget_split_honors_serving_minimums():
    """The host-first budget split never pushes a replica below the
    serving minimum (1 lane; 2 pages = scratch + one live), even when
    replicas spread unevenly over hosts."""
    from repro.serving.engine import _split_budget, _split_budget_hosted
    # the case that used to yield [2, 3, 1]: a one-page engine can't serve
    assert _split_budget_hosted(6, [0, 1, 0], min_per=2) == [2, 2, 2]
    assert all(b >= 2 for b in _split_budget_hosted(7, [0, 1, 0],
                                                    min_per=2))
    # single host degenerates to the flat split
    assert _split_budget_hosted(5, [0, 0, 0]) == _split_budget(5, 3)
    assert _split_budget_hosted(64, [0, 0]) == _split_budget(64, 2)
    # even spread: equal hardware share per host
    assert _split_budget_hosted(64, [0, 1, 0, 1], min_per=2) == \
        [16, 16, 16, 16]
    assert sum(_split_budget_hosted(33, [0, 1, 0], min_per=2)) == 33


def test_resize_respects_hosts():
    """Fabric.resize over a sim transport re-splits seats across the host
    layout: every live host keeps one seat share per class."""
    fab = _fab(transport="sim", hosts=2, replicas=2)
    _wave(fab, 60)
    fab.resize(4)
    owners = {seat.owner.load()
              for seats in fab.replica_set.seats.values() for seat in seats}
    assert owners == {HostAddr(0, 0), HostAddr(1, 1),
                      HostAddr(0, 2), HostAddr(1, 3)}
    streams = _drain_streams(fab, 60)
    _assert_exact(streams, 60)


@pytest.mark.slow
def test_chaos_host_loss_matches_uninterrupted_single_host_run():
    """ISSUE acceptance: SimHostTransport(drop=0.05, reorder=True), kill
    one simulated host mid-run under concurrent producers and drain
    threads; per-class delivery order is identical to an uninterrupted
    single-host run — the exact-seat acceptance (union exact, every
    cycle-run in order), PR-3/4 assertion style."""
    per_class, shards = 300, 4

    def run(chaos: bool):
        kw = dict(transport="sim", hosts=2, replicas=4,
                  transport_drop=0.05, transport_reorder=True,
                  transport_seed=5) if chaos else {}
        fab = _fab(**kw)
        stop = threading.Event()

        def produce(name):
            for i in range(per_class):
                fab.submit((name, i), qclass=name)
                if i % 97 == 0:
                    time.sleep(0)

        producers = [threading.Thread(target=produce, args=(n,))
                     for n in ("hi", "lo")]
        streams = {"hi": [], "lo": []}
        lock = threading.Lock()

        def drainer(rid):
            r = fab.replicas[rid]
            while not stop.is_set():
                got = r.drain(6)
                if not got:
                    r.steal_if_starved()
                    time.sleep(0)
                    continue
                with lock:
                    for v, env in got:
                        streams[v.name].append(env.seq)

        drainers = [threading.Thread(target=drainer, args=(rid,))
                    for rid in range(4)]
        for t in producers + drainers:
            t.start()
        if chaos:
            while True:
                with lock:
                    if sum(map(len, streams.values())) >= per_class // 2:
                        break
                time.sleep(0.001)
            fab.fail_host(1)  # mid-run host loss; drainers 1/3 go idle
        deadline = time.time() + 60
        while time.time() < deadline:
            with lock:
                if sum(map(len, streams.values())) >= 2 * per_class:
                    break
            time.sleep(0.005)
        stop.set()
        for t in producers + drainers:
            t.join(timeout=10)
        return streams

    base = run(chaos=False)
    chaotic = run(chaos=True)
    _assert_exact(base, per_class, shards)
    _assert_exact(chaotic, per_class, shards)
    # identical per-class delivery *order* within every cycle-run: both
    # runs deliver each run in dense cycle order, so the per-run streams
    # must be equal, not merely sorted
    for name in ("hi", "lo"):
        for s in range(shards):
            run_c = [q for q in chaotic[name] if q % shards == s]
            run_b = [q for q in base[name] if q % shards == s]
            assert run_c == run_b, \
                f"{name} run {s}: chaos delivery diverged from base"
