"""The real wire transport (DESIGN.md §15): binary framing, the per-host
worker processes, prefetch credit, batched reseat frames, chaos
(drop/delay/RTT) invariance over real localhost sockets, and the RTT
telemetry export path."""

import json
import random
import time

import pytest

from repro.fabric import ClassSpec, Fabric, FabricConfig, FabricConfigError
from repro.net import (FrameDecoder, FrameError, KIND_REQ, KIND_RESP,
                       MAX_FRAME, WireTransport, pack_frame, unpack_frames)
from repro.sched import SimHostTransport, make_transport

# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------


def test_frame_pack_unpack_roundtrip():
    bodies = [{"op": "fetch", "id": 1}, {"envs": "[]", "t": []}, {}]
    data = b"".join(pack_frame(KIND_REQ if i % 2 == 0 else KIND_RESP, b)
                    for i, b in enumerate(bodies))
    out = unpack_frames(data)
    assert [b for _, b in out] == bodies
    assert [k for k, _ in out] == [KIND_REQ, KIND_RESP, KIND_REQ]


def test_frame_decoder_survives_arbitrary_chunking():
    """A TCP stream can split/coalesce frames anywhere; the incremental
    decoder must reassemble exactly the sent frame sequence."""
    rng = random.Random(0)
    bodies = [{"op": "publish", "n": i, "blob": "x" * rng.randrange(200)}
              for i in range(50)]
    data = b"".join(pack_frame(KIND_REQ, b) for b in bodies)
    for _ in range(20):
        dec = FrameDecoder()
        got = []
        i = 0
        while i < len(data):
            j = min(len(data), i + rng.randrange(1, 64))
            got.extend(dec.feed(data[i:j]))
            i = j
        assert [b for _, b in got] == bodies
        assert dec.pending == 0


def test_frame_decoder_rejects_garbage():
    with pytest.raises(FrameError, match="unknown frame kind"):
        list(FrameDecoder().feed(b"\x00\x00\x00\x02\x7f{}"))
    with pytest.raises(FrameError, match="exceeds"):
        list(FrameDecoder().feed(
            (MAX_FRAME + 1).to_bytes(4, "big") + bytes([KIND_REQ])))
    with pytest.raises(FrameError, match="undecodable frame body"):
        list(FrameDecoder().feed(pack_frame(KIND_REQ, {})[:-2] + b"!!"))
    with pytest.raises(FrameError, match="trailing"):
        unpack_frames(pack_frame(KIND_REQ, {}) + b"\x00")


# ---------------------------------------------------------------------------
# sched-only fabrics over real worker processes
# ---------------------------------------------------------------------------


def _fab(**kw):
    base = dict(classes=(ClassSpec("hi", priority=1, weight=4.0),
                         ClassSpec("lo", priority=0, weight=1.0)),
                shards_per_class=4, replicas=4, max_replicas=4,
                queue_window=4096, drain_k=6)
    base.update(kw)
    return Fabric.open(FabricConfig(**base))


def _wave(fab, per_class):
    for name in ("hi", "lo"):
        fab.submit_many([(name, i) for i in range(per_class)], qclass=name)


def _drain_streams(fab, per_class, max_rounds=50000):
    streams = {"hi": [], "lo": []}
    rounds = 0
    while sum(map(len, streams.values())) < 2 * per_class:
        rounds += 1
        assert rounds < max_rounds, "fabric did not drain"
        for v, env in fab.step():
            streams[v.name].append(env.seq)
    return streams


def _assert_exact(streams, per_class, shards=4):
    for name, seqs in streams.items():
        assert sorted(seqs) == list(range(per_class)), \
            f"{name}: lost/duplicated seats ({len(seqs)} of {per_class})"
        for s in range(shards):
            run = [q for q in seqs if q % shards == s]
            assert run == sorted(run), f"{name} run {s} reordered"


def test_wire_lossless_delivers_identically_to_local():
    """Over real sockets and worker processes, a clean wire is invisible:
    the same per-class delivery streams as the in-process transport."""
    per_class = 80
    fab_l = _fab()
    _wave(fab_l, per_class)
    local = _drain_streams(fab_l, per_class)
    fab_w = _fab(transport="wire", hosts=2)
    try:
        _wave(fab_w, per_class)
        wire = _drain_streams(fab_w, per_class)
        ts = fab_w.stats_view().transport
    finally:
        fab_w.close(final_checkpoint=False)
    assert wire == local
    _assert_exact(wire, per_class)
    assert ts["kind"] == "wire" and ts["remote_bytes"] > 0


def test_wire_chaos_preserves_exact_order():
    """Dropped requests, parked fetch batches and injected RTT cost
    latency, never exactness — the ack-before-state-change rule means a
    timed-out request changed nothing and its retry is the recovery."""
    per_class = 90
    fab = _fab(transport="wire", hosts=2, replicas=3,
               transport_drop=0.25, transport_delay=0.2,
               transport_rtt_ms=0.3, transport_seed=17)
    try:
        _wave(fab, per_class)
        streams = _drain_streams(fab, per_class)
        ts = fab.stats_view().transport
    finally:
        fab.close(final_checkpoint=False)
    _assert_exact(streams, per_class)
    assert ts["drops"] > 0 or ts["delayed"] > 0, "chaos never fired"


def test_wire_credit_one_is_synchronous_and_exact():
    """credit=1 disables pipelining (the bench baseline) but changes no
    semantics."""
    per_class = 40
    fab = _fab(transport="wire", hosts=2, transport_credit=1)
    try:
        _wave(fab, per_class)
        streams = _drain_streams(fab, per_class)
        assert fab.stats_view().transport["credit"] == 1
    finally:
        fab.close(final_checkpoint=False)
    _assert_exact(streams, per_class)


def test_wire_fail_host_recovers_and_batches_reseat():
    """Losing a host mid-wave reseats its replicas' seats onto survivors
    (one batched reseat frame per surviving host) and the wave still
    drains exactly once; the dead host's worker process stays up as the
    durable substrate for the shards homed on it."""
    per_class = 60
    fab = _fab(transport="wire", hosts=2, replicas=4)
    try:
        _wave(fab, per_class)
        streams = {"hi": [], "lo": []}
        for _ in range(3):  # partial drain: leave staged + unreached seats
            for v, env in fab.step():
                streams[v.name].append(env.seq)
        assert sum(map(len, streams.values())) < 2 * per_class
        fab.fail_host(1)
        rounds = 0
        while sum(map(len, streams.values())) < 2 * per_class:
            rounds += 1
            assert rounds < 50000, "fabric did not drain after fail_host"
            for v, env in fab.step():
                streams[v.name].append(env.seq)
        ts = fab.stats_view().transport
    finally:
        fab.close(final_checkpoint=False)
    _assert_exact(streams, per_class)
    assert ts["dead_hosts"] == [1]


def test_wire_snapshot_roundtrips_to_local():
    """The frontier checkpoint format is the wire format: a snapshot taken
    over the wire transport restores on the local transport and delivers
    the remaining seats exactly."""
    per_class = 50
    fab = _fab(transport="wire", hosts=2)
    try:
        _wave(fab, per_class)
        done = {"hi": [], "lo": []}
        for _ in range(2):  # partial drain: the snapshot is a live frontier
            for v, env in fab.step():
                done[v.name].append(env.seq)
        assert sum(map(len, done.values())) < 2 * per_class
        snap = fab.snapshot()
    finally:
        fab.close(final_checkpoint=False)
    fab2 = Fabric.from_snapshot(json.loads(json.dumps(snap)))
    try:
        assert fab2.transport.kind == "wire"  # restored onto a fresh fleet
        streams = {n: list(s) for n, s in done.items()}
        rounds = 0
        while sum(map(len, streams.values())) < 2 * per_class:
            rounds += 1
            assert rounds < 50000, "restored fabric did not drain"
            for v, env in fab2.step():
                streams[v.name].append(env.seq)
    finally:
        fab2.close(final_checkpoint=False)
    _assert_exact(streams, per_class)


def test_wire_steals_route_through_claim_frames():
    """A starved replica steals a seat via one claim CAS against the
    seat's home worker; the transport counts it."""
    fab = _fab(transport="wire", hosts=2, replicas=4, drain_k=4)
    try:
        _wave(fab, 40)
        streams = _drain_streams(fab, 40)
        view = fab.stats_view()
        steals = sum(rs["steals"] for rs in view.replicas.values())
        ts = view.transport
    finally:
        fab.close(final_checkpoint=False)
    _assert_exact(streams, 40)
    if steals:  # steals are load-dependent; when they happen, they're RPC
        assert ts["remote_claims"] >= 0


def test_wire_rejects_reorder_and_add_host():
    with pytest.raises(FabricConfigError, match="reorder"):
        FabricConfig(transport="wire", hosts=2, replicas=2,
                     shards_per_class=2, transport_reorder=True)
    with pytest.raises(AssertionError):
        make_transport("wire", 2, reorder=True)
    tr = WireTransport(2)
    with pytest.raises(NotImplementedError):
        tr.add_host()
    tr.close()


def test_wire_close_is_idempotent_and_kills_workers():
    fab = _fab(transport="wire", hosts=2)
    procs = list(fab.transport._procs)
    fab.close(final_checkpoint=False)
    fab.close(final_checkpoint=False)
    for p in procs:
        assert p.poll() is not None, "worker process survived close()"


# ---------------------------------------------------------------------------
# sim RTT knob (the sim-at-RTT baseline) + config fields
# ---------------------------------------------------------------------------


def test_sim_rtt_knob_sleeps_per_op():
    tr = SimHostTransport(2, rtt=0.01)
    assert tr.spec()["rtt_ms"] == pytest.approx(10.0)
    with pytest.raises(AssertionError):
        SimHostTransport(2, rtt=-0.1)
    assert make_transport("sim", 2, rtt_ms=2.5).rtt == pytest.approx(0.0025)
    # end-to-end: an rtt'd sim fabric still drains exactly, just slower
    t0 = time.perf_counter()
    fab = _fab(transport="sim", hosts=2, transport_rtt_ms=1.0)
    assert fab.transport.rtt == pytest.approx(0.001)
    _wave(fab, 12)
    streams = _drain_streams(fab, 12)
    _assert_exact(streams, 12)
    assert time.perf_counter() - t0 > 0.001  # the injected RTT was paid


def test_config_roundtrips_new_transport_fields():
    cfg = FabricConfig(transport="wire", hosts=2, replicas=2,
                       shards_per_class=2, transport_rtt_ms=0.5,
                       transport_credit=8)
    back = FabricConfig.from_json(json.loads(json.dumps(cfg.to_json())))
    assert back == cfg
    assert back.transport_rtt_ms == 0.5 and back.transport_credit == 8
    with pytest.raises(FabricConfigError, match="transport_rtt_ms"):
        FabricConfig(transport="sim", hosts=2, replicas=2,
                     shards_per_class=2, transport_rtt_ms=-1.0)
    with pytest.raises(FabricConfigError, match="transport_credit"):
        FabricConfig(transport="wire", hosts=2, replicas=2,
                     shards_per_class=2, transport_credit=0)
    with pytest.raises(FabricConfigError, match="rtt"):
        FabricConfig(transport_rtt_ms=3.0)  # local transport has no wire


# ---------------------------------------------------------------------------
# RTT telemetry export
# ---------------------------------------------------------------------------


def test_rtt_percentiles_export_to_stats_and_prometheus():
    from repro.obs import ObsConfig, prometheus_text
    fab = _fab(transport="wire", hosts=2, obs=ObsConfig(trace_rate=0.0))
    try:
        _wave(fab, 40)
        _drain_streams(fab, 40)
        view = fab.stats_view()
    finally:
        fab.close(final_checkpoint=False)
    rtt = view.transport.get("rtt_ms")
    assert rtt, "no per-host RTT percentiles in the transport section"
    for host, pct in rtt.items():
        assert set(pct) >= {"p50", "p99", "count"} and pct["count"] > 0
        assert pct["p99"] >= pct["p50"] >= 0.0
    text = prometheus_text(view)
    assert 'repro_transport_rtt_ms{host="' in text
    assert 'quantile="p99"' in text
    assert 'repro_transport_rtt_count{host="' in text
    for line in text.splitlines():
        if line and not line.startswith("#"):
            assert line.startswith("repro_")
            float(line.rsplit(" ", 1)[1])
