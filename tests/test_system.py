"""End-to-end behaviour: training converges through the full stack (CMP data
pipeline -> train loop -> checkpointing) and the serving engine answers
batched requests through the CMP paged-KV path."""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataPipeline
from repro.models import init_params
from repro.serving.engine import Engine
from repro.training.optimizer import OptConfig
from repro.training.train_loop import Trainer


def test_train_loss_decreases_through_full_stack(tmp_path):
    cfg = get_config("yi_6b", smoke=True)
    opt = OptConfig(lr=2e-3, warmup_steps=3, total_steps=100)
    pipe = DataPipeline(batch=4, seq=32, vocab=cfg.vocab_size,
                        num_producers=2, window=16)
    tr = Trainer(cfg, opt, ckpt_dir=str(tmp_path), ckpt_every=10)
    res = tr.fit(iter(pipe), 25, data_pipe=pipe)
    pipe.close()
    first = sum(tr.history[:5]) / 5
    last = sum(tr.history[-5:]) / 5
    assert last < first - 0.2, f"loss did not decrease: {first} -> {last}"
    assert res["ckpt_dropped"] == 0 or res["ckpt_dropped"] < 3


def test_serving_end_to_end():
    cfg = get_config("glm4_9b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(1))
    eng = Engine(cfg, params, max_batch=4, page_size=8, num_pages=64,
                 window=4, max_seq=64)
    uids = [eng.submit([i + 1, (i * 7) % 50 + 1, 3], max_new_tokens=4)
            for i in range(8)]
    done = eng.run_until_idle()
    assert set(done) == set(uids)
    for u in uids:
        assert len(done[u].output) == 4
        assert all(0 <= t < cfg.vocab_size for t in done[u].output)


def test_train_then_serve_same_params(tmp_path):
    """The checkpoint written by training serves correctly."""
    from repro.checkpoint import checkpointer as C
    cfg = get_config("yi_6b", smoke=True)
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    pipe = DataPipeline(batch=2, seq=16, vocab=cfg.vocab_size,
                        num_producers=1, window=8)
    tr = Trainer(cfg, opt, ckpt_dir=str(tmp_path), ckpt_every=5)
    tr.fit(iter(pipe), 6, data_pipe=pipe)
    pipe.close()
    step, state = C.restore(str(tmp_path),
                            {"params": tr.params, "opt_state": tr.opt_state,
                             "data_state": pipe.state()})
    eng = Engine(cfg, jax.tree_util.tree_map(jnp.asarray, state["params"]),
                 max_batch=2, page_size=8, num_pages=32, window=2, max_seq=48)
    u = eng.submit([1, 2, 3], max_new_tokens=3)
    done = eng.run_until_idle()
    assert len(done[u].output) == 3
