"""Sharding rules + a small-mesh pjit train step (subprocess: needs >1 host
device, while the main pytest process keeps 1 device per the assignment)."""

import subprocess
import sys
import textwrap

import jax

from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh  # noqa: F401 (import sanity)
from repro.parallel.sharding import param_spec, param_specs
from repro.models import init_params


def test_param_rules_cover_every_leaf():
    import jax.numpy as jnp
    for arch in ("glm4_9b", "llama4_maverick", "xlstm_125m", "hymba_1_5b"):
        cfg = get_config(arch, smoke=True)
        p = jax.eval_shape(lambda k: init_params(cfg, k),
                           jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = param_specs(p)
        flat_p = jax.tree_util.tree_leaves(p)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            assert len([a for a in spec if a is not None]) <= leaf.ndim


def test_big_matrices_are_2d_sharded():
    assert tuple(param_spec("blocks/0/attn/wq")) == (None, "data", "model")
    assert tuple(param_spec("blocks/0/mlp/wd")) == (None, "model", "data")
    assert tuple(param_spec("blocks/1/moe/wg")) == (None, "model", "data", None)
    assert tuple(param_spec("embed")) == ("model", "data")
    assert tuple(param_spec("blocks/0/ln1/scale")) in ((), (None,))


_SMALL_MESH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import sys; sys.path.insert(0, "src")
    from jax.sharding import NamedSharding, PartitionSpec as P
    import dataclasses
    from repro.configs import get_config
    from repro.models import model as M
    from repro.models import init_params
    from repro.parallel import sharding as S
    from repro.training import optimizer as O

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = dataclasses.replace(get_config("yi_6b", smoke=True),
                              batch_axes=("data",))
    opt_cfg = O.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    params = init_params(cfg, jax.random.PRNGKey(0))
    p_shard = S.param_shardings(params, mesh)
    params = jax.tree_util.tree_map(jax.device_put, params, p_shard)
    opt_state = O.init(params, opt_cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0,
                                cfg.vocab_size, jnp.int32)
    tokens = jax.device_put(tokens, NamedSharding(mesh, P("data", None)))

    def step(p, o, b):
        (loss, mets), grads = jax.value_and_grad(M.loss_fn, has_aux=True)(p, b, cfg)
        p, o, _ = O.apply_updates(grads=grads, params=p, state=o, cfg=opt_cfg)
        return p, o, loss

    with mesh:
        p2, o2, loss = jax.jit(step)(params, opt_state, {"tokens": tokens})
    assert jnp.isfinite(loss), loss
    # distributed result == single-device result
    p_host = jax.device_get(params)
    loss_ref = M.loss_fn(p_host, {"tokens": jax.device_get(tokens)}, cfg)[0]
    assert abs(float(loss) - float(loss_ref)) < 1e-3, (loss, loss_ref)
    print("MESH_OK", float(loss))
""")


def test_small_mesh_train_step_matches_single_device():
    r = subprocess.run([sys.executable, "-c", _SMALL_MESH],
                       capture_output=True, text=True, timeout=600)
    assert "MESH_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


_COMPRESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    import sys; sys.path.insert(0, "src")
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.collectives import cross_pod_grad_reduce

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    g = {"w": jnp.linspace(-1.0, 1.0, 64).reshape(8, 8)}
    e = {"w": jnp.zeros((8, 8))}
    out, err = cross_pod_grad_reduce(g, e, mesh)
    # identical per-pod grads -> mean == original, int8 quantization error small
    ref = np.asarray(g["w"])
    got = np.asarray(out["w"])
    assert np.max(np.abs(got - ref)) < 1.5 / 127, np.max(np.abs(got - ref))
    # error feedback captured the residual
    assert np.max(np.abs(np.asarray(err["w"]))) > 0
    print("COMPRESS_OK")
""")


def test_int8_error_feedback_grad_reduce():
    r = subprocess.run([sys.executable, "-c", _COMPRESS],
                       capture_output=True, text=True, timeout=600)
    assert "COMPRESS_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


_REMESH = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    import sys; sys.path.insert(0, "src")
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import checkpointer as C

    d = tempfile.mkdtemp()
    # save under a 4x2 mesh layout
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                       NamedSharding(mesh_a, P("data", "model")))
    C.save(d, 1, {"w": w})
    # restore under a 2x4 mesh (elastic re-mesh)
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    sh = {"w": NamedSharding(mesh_b, P("data", "model"))}
    step, state = C.restore(d, {"w": w}, shardings=sh)
    assert state["w"].sharding == sh["w"]
    assert np.array_equal(np.asarray(state["w"]), np.arange(64.0).reshape(8, 8))
    print("REMESH_OK")
""")


def test_elastic_remesh_across_mesh_shapes():
    r = subprocess.run([sys.executable, "-c", _REMESH],
                       capture_output=True, text=True, timeout=600)
    assert "REMESH_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
